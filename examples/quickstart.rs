//! Quickstart: the PerLLM public API in ~60 lines.
//!
//! 1. Describe a diverse-service workload (streamed, never materialized).
//! 2. Build the paper's edge-cloud cluster.
//! 3. Schedule it with CS-UCB and with the cloud-only baseline — each run
//!    streams a fresh cursor over the same seeded request sequence.
//! 4. Compare success rate, throughput, and energy.
//!
//! Run: cargo run --release --example quickstart

use perllm::scheduler::{csucb::CsUcb, fineinfer::FineInfer};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate_stream;
use perllm::util::stats::ratio;
use perllm::workload::generator::{WorkloadConfig, WorkloadGen};

fn main() {
    // 1. A reproducible workload: 2 000 services, deadlines in [2 s, 6 s].
    //    `WorkloadGen` is a pull-based ArrivalSource — the engine prefetches
    //    one arrival at a time, so the event heap stays bounded no matter
    //    how long the trace is.
    let workload = WorkloadConfig::default()
        .with_requests(2_000)
        .with_deadline_range(2.0, 6.0)
        .with_seed(7);
    println!("workload: {} requests (streamed)", workload.n_requests);

    // 2. The paper's testbed: five edge servers + one cloud server.
    let cluster = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);

    // 3. Schedule with the paper's CS-UCB and the cloud-only baseline.
    //    Schedulers return Actions (Assign / Defer / Shed); the engine
    //    accounts sheds into RunReport::dropped.
    let mut perllm_sched = CsUcb::with_defaults(cluster.n_servers());
    let perllm_run =
        simulate_stream(&cluster, &mut WorkloadGen::new(&workload), &mut perllm_sched);

    let mut cloud_only = FineInfer::new(cluster.cloud_index());
    let baseline_run =
        simulate_stream(&cluster, &mut WorkloadGen::new(&workload), &mut cloud_only);

    // 4. Compare.
    println!("\n{}", baseline_run.summary_row());
    println!("{}", perllm_run.summary_row());
    println!(
        "\nPerLLM vs cloud-only: {:.2}x throughput, {:.1}% vs {:.1}% success, \
         {:.0} vs {:.0} J per successful service",
        ratio(perllm_run.throughput_tok_s, baseline_run.throughput_tok_s),
        perllm_run.success_rate * 100.0,
        baseline_run.success_rate * 100.0,
        perllm_run.energy_per_success_j,
        baseline_run.energy_per_success_j,
    );
    println!(
        "dropped: {} (policy sheds {}) — event-heap peak {} (≪ {} requests)",
        perllm_run.dropped,
        perllm_run.dropped_by_policy,
        perllm_run.peak_event_queue_len,
        workload.n_requests,
    );
    for (k, v) in &perllm_run.diagnostics {
        if k == "cum_regret" || k == "regret_bound" {
            println!("  CS-UCB {k}: {v:.1}");
        }
    }
}
