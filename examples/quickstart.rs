//! Quickstart: the PerLLM public API in ~60 lines.
//!
//! 1. Generate a diverse-service workload.
//! 2. Build the paper's edge-cloud cluster.
//! 3. Schedule it with CS-UCB and with the cloud-only baseline.
//! 4. Compare success rate, throughput, and energy.
//!
//! Run: cargo run --release --example quickstart

use perllm::scheduler::{csucb::CsUcb, fineinfer::FineInfer, Scheduler};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::util::stats::ratio;
use perllm::workload::generator::{generate, WorkloadConfig};

fn main() {
    // 1. A reproducible trace: 2 000 services, deadlines in [2 s, 6 s].
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(2_000)
            .with_deadline_range(2.0, 6.0)
            .with_seed(7),
    );
    println!(
        "workload: {} requests, first arrival {:.2}s, last {:.2}s",
        trace.len(),
        trace.first().unwrap().arrival,
        trace.last().unwrap().arrival
    );

    // 2. The paper's testbed: five edge servers + one cloud server.
    let cluster = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);

    // 3. Schedule with the paper's CS-UCB and the cloud-only baseline.
    let mut perllm_sched = CsUcb::with_defaults(cluster.n_servers());
    let perllm_run = simulate(&cluster, &trace, &mut perllm_sched);

    let mut cloud_only = FineInfer::new(cluster.cloud_index());
    let baseline_run = simulate(&cluster, &trace, &mut cloud_only);

    // 4. Compare.
    println!("\n{}", baseline_run.summary_row());
    println!("{}", perllm_run.summary_row());
    println!(
        "\nPerLLM vs cloud-only: {:.2}x throughput, {:.1}% vs {:.1}% success, \
         {:.0} vs {:.0} J per successful service",
        ratio(perllm_run.throughput_tok_s, baseline_run.throughput_tok_s),
        perllm_run.success_rate * 100.0,
        baseline_run.success_rate * 100.0,
        perllm_run.energy_per_success_j,
        baseline_run.energy_per_success_j,
    );
    for (k, v) in &perllm_run.diagnostics {
        if k == "cum_regret" || k == "regret_bound" {
            println!("  CS-UCB {k}: {v:.1}");
        }
    }
}
