//! Ablation study over CS-UCB's design choices (DESIGN.md §9):
//!
//! * constraint filter off (pure UCB over all servers)
//! * exploration weight δ sweep
//! * constraint-slack margin sweep
//! * penalty term θ on/off (Eq. 6/7)
//! * vs the clairvoyant oracle (regret denominator)
//!
//! Run: cargo run --release --example ablation [-- --requests N]

use perllm::bench::Table;
use perllm::scheduler::csucb::{CsUcb, CsUcbParams};
use perllm::scheduler::oracle::Oracle;
use perllm::scheduler::Scheduler;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate_stream;
use perllm::workload::generator::{WorkloadConfig, WorkloadGen};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);

    // Streamed workload: every variant gets a fresh cursor over the same
    // seeded request sequence (nothing is materialized).
    let workload = WorkloadConfig::default()
        .with_requests(n)
        .with_deadline_range(2.0, 6.0)
        .with_seed(123);
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);

    let mut table = Table::new(
        format!("CS-UCB ablations ({n} requests, fluctuating bandwidth)"),
        &["variant", "success%", "mean s", "thpt tok/s", "J/succ", "regret"],
    );

    let mut run = |name: &str, sched: &mut dyn Scheduler| {
        let mut source = WorkloadGen::new(&workload);
        let rep = simulate_stream(&cfg, &mut source, sched);
        let regret = rep
            .diagnostics
            .iter()
            .find(|(k, _)| k == "cum_regret")
            .map(|(_, v)| format!("{v:.0}"))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            name.to_string(),
            format!("{:.1}", rep.success_rate * 100.0),
            format!("{:.2}", rep.mean_processing_s),
            format!("{:.0}", rep.throughput_tok_s),
            format!("{:.1}", rep.energy_per_success_j),
            regret,
        ]);
    };

    let d = CsUcbParams::default();

    run("cs-ucb (paper defaults)", &mut CsUcb::new(6, d));
    run(
        "no slack margin",
        &mut CsUcb::new(6, CsUcbParams { slack_margin: 0.0, ..d }),
    );
    run(
        "shedding on (threshold 2)",
        &mut CsUcb::new(
            6,
            CsUcbParams {
                shed_threshold: 2.0,
                ..d
            },
        ),
    );
    run(
        "no penalty (θ=0)",
        &mut CsUcb::new(6, CsUcbParams { theta: 0.0, ..d }),
    );
    run(
        "no constraint weight (λ=0)",
        &mut CsUcb::new(6, CsUcbParams { lambda: 0.0, ..d }),
    );
    for delta in [0.05, 0.25, 1.0, 3.0] {
        run(
            Box::leak(format!("δ = {delta}").into_boxed_str()),
            &mut CsUcb::new(6, CsUcbParams { delta, ..d }),
        );
    }
    run("oracle (clairvoyant)", &mut Oracle::new());

    println!("{}", table.render());
}
