//! End-to-end serving driver (the repository's E2E validation, recorded in
//! EXPERIMENTS.md): load the real AOT-compiled models (edge + cloud
//! deployment sizes), serve a batched stream of diverse requests through
//! the CS-UCB router, and report latency/throughput — all three layers
//! composing on the request path with Python nowhere in sight.
//!
//! Run: make artifacts && cargo run --release --example serve_model
//!      [-- --requests N] [--edge-workers K] [--max-new-tokens T]

use std::time::{Duration, Instant};

use perllm::coordinator::server::{ServeRequest, ServingCluster};
use perllm::runtime::{cpu_client, default_artifact_dir, Artifacts, ModelEngine};
use perllm::scheduler::csucb::CsUcb;
use perllm::sim::server::ServerKind;
use perllm::util::rng::Rng;
use perllm::workload::service::ServiceClass;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let n: usize = arg("--requests", "48").parse()?;
    let edge_workers: usize = arg("--edge-workers", "2").parse()?;
    let max_new: usize = arg("--max-new-tokens", "32").parse()?;
    let art_dir = default_artifact_dir();

    println!("== PerLLM end-to-end serving driver ==");
    println!("artifacts: {art_dir:?}");
    let arts = Artifacts::discover(&art_dir)?;
    for (name, meta) in &arts.models {
        println!(
            "  model {name}: d_model {} layers {} heads {} max_seq {}",
            meta.d_model, meta.n_layers, meta.n_heads, meta.max_seq
        );
    }

    // Engines load inside their worker threads (PJRT handles are !Send).
    type Factory = Box<dyn FnOnce() -> anyhow::Result<ModelEngine> + Send>;
    let mut engines: Vec<(ServerKind, Factory)> = Vec::new();
    for _ in 0..edge_workers {
        let dir = art_dir.clone();
        engines.push((
            ServerKind::Edge,
            Box::new(move || {
                ModelEngine::load(&cpu_client()?, &Artifacts::discover(&dir)?, "edge")
            }),
        ));
    }
    let dir = art_dir.clone();
    engines.push((
        ServerKind::Cloud,
        Box::new(move || {
            ModelEngine::load(&cpu_client()?, &Artifacts::discover(&dir)?, "cloud")
        }),
    ));
    let n_workers = engines.len();
    let scheduler = Box::new(CsUcb::with_defaults(n_workers));
    let mut cluster = ServingCluster::start(engines, scheduler, 42)?;
    println!("workers: {edge_workers} edge + 1 cloud, scheduler cs-ucb (PerLLM)\n");

    // Diverse prompts drawn from the training corpus (the tiny char-LMs
    // memorize it, so continuations are visibly non-random).
    let prompts: [(&str, ServiceClass); 4] = [
        ("Edge-cloud collaboration ", ServiceClass::Chat),
        ("The cloud offers ", ServiceClass::Summarize),
        ("PerLLM schedules each request ", ServiceClass::Translate),
        ("Diverse services ask for ", ServiceClass::Code),
    ];
    let mut rng = Rng::new(9);
    let t0 = Instant::now();
    let mut sent: Vec<&str> = Vec::with_capacity(n);
    let mut replies = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        let (p, class) = prompts[rng.index(prompts.len())];
        sent.push(p);
        let outcome = cluster.submit(ServeRequest {
            id: i as u64,
            prompt: p.to_string(),
            max_new_tokens: max_new,
            deadline_s: rng.uniform(10.0, 30.0),
            // Interactive classes carry their default TTFT bound scaled to
            // CPU-testbed speeds; batch classes stay completion-only.
            ttft_slo_s: class.default_ttft().map(|t| t * 20.0),
            class,
            temperature: 0.0, // greedy: reproducible output
            top_k: 1,
        })?;
        // Policy sheds resolve immediately: no completion will arrive.
        if outcome.worker().is_none() {
            shed += 1;
        }
        // Open-loop pacing: drain completions as they arrive.
        while let Some(r) = cluster.recv_completion(Duration::from_millis(1)) {
            replies.push(r);
        }
    }
    while replies.len() + shed < n {
        let Some(r) = cluster.recv_completion(Duration::from_secs(120)) else {
            anyhow::bail!("timed out: {}/{} done", replies.len(), n);
        };
        replies.push(r);
    }
    if shed > 0 {
        println!("{shed} requests shed by the scheduling policy");
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("sample generations:");
    for r in replies.iter().take(4) {
        println!(
            "  [worker {}] {:?} → {:?}",
            r.worker,
            sent[r.id as usize],
            r.text.chars().take(56).collect::<String>()
        );
    }

    let per_worker: Vec<usize> = (0..n_workers)
        .map(|w| replies.iter().filter(|r| r.worker == w).count())
        .collect();
    let met = replies.iter().filter(|r| r.met_deadline()).count();
    println!("\n{}", cluster.metrics.report());
    println!("wall time: {wall:.2}s");
    println!("placement per worker: {per_worker:?}");
    println!("deadline success: {:.1}%", 100.0 * met as f64 / n as f64);
    for (k, v) in cluster.diagnostics() {
        println!("  {k}: {v:.2}");
    }
    cluster.shutdown();
    Ok(())
}
