//! Paper-scale scheduling experiment: replay the paper's evaluation
//! (§4.2-4.4) — 10,000 diverse services, four schedulers, stable and
//! fluctuating bandwidth — and print Table-1/Figure-4/5/6-style rows.
//!
//! Usage: cargo run --release --example paper_scale_sim [-- --requests N]
//!                   [--model yi-6b|llama2-7b|llama3-8b|yi-9b] [--seed S]

use perllm::scheduler::{
    agod::Agod, csucb::CsUcb, fineinfer::FineInfer, rewardless::RewardlessGuidance, Scheduler,
};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |flag: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| default.to_string())
    };
    let n: usize = get("--requests", "10000").parse().expect("bad --requests");
    let model = get("--model", "llama2-7b");
    let seed: u64 = get("--seed", "42").parse().expect("bad --seed");

    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate: 15.0 })
            .with_deadline_range(2.0, 6.0)
            .with_seed(seed),
    );

    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        println!("\n=== edge model {model}, {mode:?} bandwidth, {n} requests ===");
        let cfg = ClusterConfig::paper(&model, mode);
        let cloud = cfg.cloud_index();
        let ns = cfg.n_servers();

        let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FineInfer::new(cloud)),
            Box::new(Agod::new(ns, seed)),
            Box::new(RewardlessGuidance::new(ns)),
            Box::new(CsUcb::with_defaults(ns)),
        ];
        let mut baseline_thpt = None;
        for s in schedulers.iter_mut() {
            let rep = simulate(&cfg, &trace, s.as_mut());
            println!("{}", rep.summary_row());
            println!(
                "    dropped {} late {} unfinished {}",
                rep.dropped, rep.late, rep.unfinished
            );
            if baseline_thpt.is_none() {
                baseline_thpt = Some(rep.throughput_tok_s);
            } else {
                let r = rep.throughput_tok_s / baseline_thpt.unwrap();
                println!("    throughput vs FineInfer: {r:.2}x");
            }
            for (k, v) in rep.diagnostics {
                if k == "cum_regret" || k == "regret_bound" || k == "fallback_decisions" {
                    println!("    {k}: {v:.1}");
                }
            }
        }
    }
}
