//! Paper-scale scheduling experiment: replay the paper's evaluation
//! (§4.2-4.4) — 10,000 diverse services by default, four schedulers,
//! stable and fluctuating bandwidth — and print Table-1/Figure-4/5/6-style
//! rows plus the DES's own throughput (events/s and stale-event ratio).
//!
//! The virtual-time simulation core makes million-request sweeps
//! practical; for the 1M acceptance run use:
//!
//! ```text
//! cargo run --release --example paper_scale_sim -- \
//!     --requests 1000000 --schedulers cs-ucb --modes stable
//! ```
//!
//! Usage: cargo run --release --example paper_scale_sim [-- --requests N]
//!                   [--model yi-6b|llama2-7b|llama3-8b|yi-9b] [--seed S]
//!                   [--schedulers fineinfer,agod,rewardless,cs-ucb]
//!                   [--modes stable|fluctuating|both]

use perllm::scheduler::{
    agod::Agod, csucb::CsUcb, fineinfer::FineInfer, rewardless::RewardlessGuidance, Scheduler,
};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |flag: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| default.to_string())
    };
    let n: usize = get("--requests", "10000").parse().expect("bad --requests");
    let model = get("--model", "llama2-7b");
    let seed: u64 = get("--seed", "42").parse().expect("bad --seed");
    let schedulers: Vec<String> = get("--schedulers", "fineinfer,agod,rewardless,cs-ucb")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let modes: Vec<BandwidthMode> = match get("--modes", "both").as_str() {
        "stable" => vec![BandwidthMode::Stable],
        "fluctuating" | "fluct" => vec![BandwidthMode::Fluctuating],
        "both" => vec![BandwidthMode::Stable, BandwidthMode::Fluctuating],
        other => panic!("bad --modes {other}"),
    };

    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate: 15.0 })
            .with_deadline_range(2.0, 6.0)
            .with_seed(seed),
    );

    for mode in modes {
        println!("\n=== edge model {model}, {mode:?} bandwidth, {n} requests ===");
        let cfg = ClusterConfig::paper(&model, mode);
        let cloud = cfg.cloud_index();
        let ns = cfg.n_servers();

        let mut throughputs: Vec<(String, f64)> = Vec::new();
        for name in &schedulers {
            let mut s: Box<dyn Scheduler> = match name.as_str() {
                "fineinfer" => Box::new(FineInfer::new(cloud)),
                "agod" => Box::new(Agod::new(ns, seed)),
                "rewardless" => Box::new(RewardlessGuidance::new(ns)),
                "cs-ucb" => Box::new(CsUcb::with_defaults(ns)),
                other => panic!("unknown scheduler {other}"),
            };
            let rep = simulate(&cfg, &trace, s.as_mut());
            println!("{}", rep.summary_row());
            println!(
                "    dropped {} late {} unfinished {}",
                rep.dropped, rep.late, rep.unfinished
            );
            println!(
                "    DES: {} events in {:.2}s wall = {:.0} events/s, \
                 stale ratio {:.4} ({} stale)",
                rep.events_processed,
                rep.wall_s,
                rep.events_per_sec,
                rep.stale_ratio,
                rep.stale_events
            );
            throughputs.push((name.clone(), rep.throughput_tok_s));
            for (k, v) in rep.diagnostics {
                if k == "cum_regret" || k == "regret_bound" || k == "fallback_decisions" {
                    println!("    {k}: {v:.1}");
                }
            }
        }
        // Ratios as a post-pass so the FineInfer baseline applies no matter
        // where (or whether) it appears in --schedulers.
        if let Some((_, base)) = throughputs.iter().find(|(n, _)| n == "fineinfer") {
            let base = *base;
            for (name, thpt) in &throughputs {
                if name != "fineinfer" {
                    println!("    {name} throughput vs FineInfer: {:.2}x", thpt / base);
                }
            }
        }
    }
}
