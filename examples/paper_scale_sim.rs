//! Paper-scale scheduling experiment: replay the paper's evaluation
//! (§4.2-4.4) — 10,000 diverse services by default, four schedulers,
//! stable and fluctuating bandwidth — and print Table-1/Figure-4/5/6-style
//! rows plus the DES's own throughput (events/s, stale-event ratio, and
//! the event-heap high-water mark).
//!
//! The workload is *streamed* through the engine (`ArrivalSource`): each
//! run constructs a fresh `WorkloadGen` from the same seed, so no trace is
//! ever materialized and the event heap stays bounded by in-flight
//! concurrency — the 1M acceptance run no longer pre-pushes 1M arrival
//! events:
//!
//! ```text
//! cargo run --release --example paper_scale_sim -- \
//!     --requests 1000000 --schedulers cs-ucb --modes stable
//! ```
//!
//! Usage: cargo run --release --example paper_scale_sim [-- --requests N]
//!                   [--model yi-6b|llama2-7b|llama3-8b|yi-9b] [--seed S]
//!                   [--topology paper|edgeshard-10x|edgeshard-100x]
//!                   [--service-model ps|token-batch|token-batch-edge]
//!                   [--mix single|tiered] [--sessions]
//!                   [--slo completion-only|per-class] [--gate]
//!                   [--rate R]
//!                   [--schedulers fineinfer,agod,rewardless,cs-ucb,cs-ucb-slo,
//!                                 cs-ucb-sw,cs-ucb-disc,cs-ucb-affinity]
//!                   [--modes stable|fluctuating|both]
//!                   [--faults off|crash|generative] [--mttf S] [--mttr S]
//!                   [--scenario none|regional-failover]
//!                   [--shards N|auto|weighted|weighted:N]
//!                   [--min-success F] [--min-events-per-sec F]
//!                   [--min-gate-sheds N] [--min-recovered-attainment F]
//!                   [--min-cache-hit-rate F] [--require-affinity-uplift]
//!
//! `--topology` swaps the paper's 6-server testbed for an EdgeShard-style
//! multi-tier preset (60 / 600 servers); the Poisson arrival rate then
//! defaults to the paper's 15 req/s scaled by the topology's capacity, so
//! offered load stays comparable across scales (override with `--rate`).
//!
//! `--service-model` selects the token-level server model
//! (`sim::service_model`): `ps` (the historical fluid, default),
//! `token-batch` (discrete-iteration continuous batching on every tier),
//! or `token-batch-edge` (token-batch edge tiers under PS cloud tiers).
//!
//! `--mix tiered` replaces the single fleet-wide class mix with one
//! arrival stream per tier — locality-shaped class weights (edge tiers
//! chat/translate-heavy, cloud summarize/code-heavy) at capacity-
//! proportional rates — k-way merged through `workload::MergedArrivals`:
//! the EdgeShard locality scenario from the CLI.
//!
//! `--slo per-class` swaps the paper's uniform U[2, 6] scalar deadline
//! for class-conditioned **SLO vectors**: chat/translate draw a TTFT
//! bound on top of their class completion range, summarize/code stay
//! completion-bound with their loose class ranges (workload::SloSpec).
//! The default `completion-only` reproduces the pre-PR5 workload byte
//! for byte, which is what keeps the default CS-UCB rows bit-identical
//! to earlier revisions (pinned by `rust/tests/slo_identity.rs`). Per-
//! class runs print an extra SLO row: per-class TTFT/completion
//! attainment and the violation split by constraint family.
//!
//! `--gate` installs `scheduler::admission::TokenBucketGate` in front of
//! every scheduler: requests whose SLO vector is predicted to be violated
//! on every server are shed at the door (a bounded per-class token budget
//! still admits a trickle to keep probing), surfaced as `gate sheds` and
//! gated by `--min-gate-sheds` in CI overload smokes.
//!
//! The 100x fleet-scale acceptance run:
//!
//! ```text
//! cargo run --release --example paper_scale_sim -- \
//!     --topology edgeshard-100x --requests 1000000 \
//!     --schedulers cs-ucb --modes stable
//! ```
//!
//! `--faults` layers the PR-6 chaos subsystem (`sim::faults`) onto every
//! run: `crash` scripts one hard crash of edge server 0 at the midpoint
//! of the arrival horizon, recovering after `--mttr` seconds;
//! `generative` runs a seeded MTTF/MTTR crash-repair process over the
//! whole fleet (`--mttf`/`--mttr`, exponential windows, non-overlapping
//! per server). Both install the default lagged health monitor (probe
//! 1 s, publish 5 s late), so schedulers act on `observed_health`, not
//! ground truth — which is what makes the sliding-window (`cs-ucb-sw`)
//! and discounted (`cs-ucb-disc`) CS-UCB variants earn their keep. The
//! run then prints an extra availability row: incidents, per-phase SLO
//! attainment (pre/during/post), time-to-recover, in-flight casualties,
//! and gate sheds by phase.
//!
//! `--scenario regional-failover` (tiered mix only, ≥ 2 tiers) scripts a
//! regional incident: the first (edge) tier's arrival stream drains to
//! 10% of its rate for `--mttr` seconds starting at the horizon midpoint
//! (`MergedArrivals::with_modulations`, PR 8 machinery) while every
//! server in that tier crashes for the same window — the surviving tiers
//! absorb the failover traffic and the availability row reports the
//! pre/during/post attainment split.
//!
//! `--shards N|auto|weighted[:N]` runs the **sharded parallel DES
//! engine** instead of the sequential one: N per-range engine shards,
//! `auto` = one shard per topology tier **rebalanced by event volume**
//! when the tier split is lopsided, or `weighted[:N]` = the volume-
//! weighted partitioner at tier-count (or N) shards — all synchronized
//! by conservative link-lookahead, bit-identical to the sequential
//! engine at every shard count and plan (pinned by
//! `rust/tests/sharded_identity.rs`) — only the DES perf rows (events/s,
//! wall, the per-shard `shard-perf` telemetry) legitimately change. The
//! fleet-scale scaling run:
//!
//! ```text
//! cargo run --release --example paper_scale_sim -- \
//!     --topology edgeshard-100x --requests 1000000 \
//!     --schedulers cs-ucb --modes stable --shards auto
//! ```
//!
//! `--sessions` (PR 10) replaces the i.i.d. request stream with
//! multi-turn conversation chains (`workload::sessions`): per-class turn
//! counts and think-time gaps, monotonically growing context, and a
//! `SessionRef` on every request. Warm follow-up turns skip the prefill
//! of whatever prefix is still KV-resident on their server
//! (`sim::prefix`), remote turns may instead pay a KV transfer over the
//! link when that is cheaper than recomputing — the run prints an extra
//! `cache:` row (per-class hit rates, prefill tokens saved, KV transfer
//! bytes, evictions). `--requests` counts *turns*, and the session-start
//! rate is derived from `--rate` divided by the mix's mean turn count,
//! so offered token load stays comparable to the sessionless run.
//! Composes with `--mix tiered` (one session stream per tier, merged).
//! The scheduler built for this workload is `cs-ucb-affinity`
//! (`scheduler::csucb::CsUcbAffinity`): CS-UCB with vector SLOs plus a
//! cache-stickiness bonus that decays with the target cache's eviction
//! pressure. The chat-heavy comparison:
//!
//! ```text
//! cargo run --release --example paper_scale_sim -- \
//!     --requests 20000 --sessions --mix tiered --slo per-class \
//!     --schedulers cs-ucb-slo,cs-ucb-affinity --modes stable
//! ```
//!
//! The `--min-*` flags turn the run into a CI gate: if any run's success
//! rate or DES events/s lands below the floor (or the event-heap peak
//! above the cap, or post-recovery attainment below
//! `--min-recovered-attainment` in a faulted run), the process exits 1.
//! With `--sessions`, `--min-cache-hit-rate` floors every run's overall
//! prefix hit rate, and `--require-affinity-uplift` fails the run if
//! `cs-ucb-affinity` does not reach at least `cs-ucb-slo`'s hit rate
//! (both schedulers must be listed).

use perllm::scheduler::admission::{GateParams, TokenBucketGate};
use perllm::scheduler::{
    agod::Agod,
    csucb::{CsUcb, CsUcbAffinity, CsUcbSlo},
    fineinfer::FineInfer,
    rewardless::RewardlessGuidance,
    Scheduler,
};
use perllm::sim::cluster::BandwidthMode;
use perllm::sim::engine::{simulate_stream_faulted, simulate_stream_faulted_sharded};
use perllm::sim::topology::TopologyConfig;
use perllm::sim::{FaultKind, FaultPlan, GenerativeFaults, HealthConfig, ShardCount};
use perllm::workload::generator::{
    ArrivalModulation, ArrivalProcess, SloSampling, WorkloadConfig, WorkloadGen,
};
use perllm::workload::sessions::{SessionConfig, SessionSource};
use perllm::workload::{ArrivalSource, MergedArrivals};

/// Locality-shaped class weights per tier (`--mix tiered`), in
/// `ServiceClass::ALL` order (Chat, Summarize, Translate, Code): edge
/// tiers serve the interactive short-form traffic, hubs the default
/// blend, cloud tiers the long-form heavy classes.
fn tier_class_weights(tier_name: &str) -> [f64; 4] {
    match tier_name {
        "edge" => [0.60, 0.05, 0.30, 0.05],
        "cloud" => [0.15, 0.40, 0.10, 0.35],
        _ => [0.40, 0.20, 0.25, 0.15],
    }
}

/// One workload description per tier: class weights by tier locality,
/// requests and Poisson rate split proportionally to the tier's share of
/// the fleet's batch slots (so total offered load matches the single-mix
/// run), seeds decorrelated per tier.
fn tier_workloads(
    topo: &TopologyConfig,
    n: usize,
    rate: f64,
    seed: u64,
    slo: SloSampling,
) -> Vec<WorkloadConfig> {
    let total_slots = topo.total_slots() as f64;
    let mut out = Vec::with_capacity(topo.tiers.len());
    let mut assigned = 0usize;
    for (i, tier) in topo.tiers.iter().enumerate() {
        let share = (tier.count * tier.server.slots) as f64 / total_slots;
        let tier_n = if i + 1 == topo.tiers.len() {
            // Remainder keeps the total exact; saturating, because the
            // earlier tiers' rounding can overshoot a tiny n.
            n.saturating_sub(assigned)
        } else {
            ((n as f64 * share).round() as usize).min(n.saturating_sub(assigned))
        };
        assigned += tier_n;
        out.push(
            shape_slo(
                WorkloadConfig::default()
                    .with_requests(tier_n)
                    .with_arrivals(ArrivalProcess::Poisson { rate: rate * share }),
                slo,
            )
            .with_class_weights(tier_class_weights(&tier.name))
            .with_seed(seed ^ (0x9E37_79B9 * (i as u64 + 1))),
        );
    }
    out
}

/// Apply the `--slo` mode: completion-only keeps the paper's uniform
/// U[2, 6] scalar deadline (byte-identical pre-PR5 workload); per-class
/// keeps each class's own completion range (tight chat, loose code) and
/// layers the class TTFT bounds on top — genuinely heterogeneous
/// contracts, which is the point of the vector API.
fn shape_slo(cfg: WorkloadConfig, slo: SloSampling) -> WorkloadConfig {
    match slo {
        SloSampling::CompletionOnly => cfg.with_deadline_range(2.0, 6.0),
        SloSampling::PerClass => cfg.with_per_class_slos(),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |flag: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| default.to_string())
    };
    let n: usize = get("--requests", "10000").parse().expect("bad --requests");
    let model = get("--model", "llama2-7b");
    let seed: u64 = get("--seed", "42").parse().expect("bad --seed");
    let topology = get("--topology", "paper");
    let service_model = get("--service-model", "ps");
    let mix = get("--mix", "single");
    assert!(
        mix == "single" || mix == "tiered",
        "bad --mix {mix} (single|tiered)"
    );
    let slo = match get("--slo", "completion-only").as_str() {
        "completion-only" => SloSampling::CompletionOnly,
        "per-class" => SloSampling::PerClass,
        other => panic!("bad --slo {other} (completion-only|per-class)"),
    };
    let gate = args.iter().any(|a| a == "--gate");
    let sessions = args.iter().any(|a| a == "--sessions");
    let schedulers: Vec<String> = get("--schedulers", "fineinfer,agod,rewardless,cs-ucb")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let modes: Vec<BandwidthMode> = match get("--modes", "both").as_str() {
        "stable" => vec![BandwidthMode::Stable],
        "fluctuating" | "fluct" => vec![BandwidthMode::Fluctuating],
        "both" => vec![BandwidthMode::Stable, BandwidthMode::Fluctuating],
        other => panic!("bad --modes {other}"),
    };
    let min_success: f64 = get("--min-success", "0").parse().expect("bad --min-success");
    let min_events: f64 = get("--min-events-per-sec", "0")
        .parse()
        .expect("bad --min-events-per-sec");
    let max_peak_heap: usize = get("--max-peak-event-heap", "0")
        .parse()
        .expect("bad --max-peak-event-heap");
    let min_gate_sheds: u64 = get("--min-gate-sheds", "0")
        .parse()
        .expect("bad --min-gate-sheds");
    let min_recovered: f64 = get("--min-recovered-attainment", "0")
        .parse()
        .expect("bad --min-recovered-attainment");
    let min_cache_hit: f64 = get("--min-cache-hit-rate", "0")
        .parse()
        .expect("bad --min-cache-hit-rate");
    let require_uplift = args.iter().any(|a| a == "--require-affinity-uplift");
    if (min_cache_hit > 0.0 || require_uplift) && !sessions {
        panic!("--min-cache-hit-rate / --require-affinity-uplift need --sessions");
    }
    let faults = get("--faults", "off");
    let mttf: f64 = get("--mttf", "300").parse().expect("bad --mttf");
    let mttr: f64 = get("--mttr", "30").parse().expect("bad --mttr");
    let shards: Option<ShardCount> = match get("--shards", "").as_str() {
        "" => None,
        s => Some(
            ShardCount::parse(s)
                .unwrap_or_else(|| panic!("bad --shards {s} (N|auto|weighted|weighted:N)")),
        ),
    };
    let scenario = get("--scenario", "none");
    assert!(
        scenario == "none" || scenario == "regional-failover",
        "bad --scenario {scenario} (none|regional-failover)"
    );
    if scenario == "regional-failover" {
        assert!(
            mix == "tiered",
            "--scenario regional-failover needs --mix tiered (it drains one tier's stream)"
        );
    }

    // Arrival rate: the paper's 15 req/s scaled by topology capacity
    // unless pinned explicitly — a 60-server fleet at paper load would
    // just idle. The Stable-mode instance doubles as the mode-independent
    // tier-layout reference the failover scenario scripts against.
    let ref_topo = TopologyConfig::by_name(&topology, &model, BandwidthMode::Stable)
        .unwrap_or_else(|| panic!("unknown --topology {topology}"));
    let capacity_scale = ref_topo.capacity_scale();
    let rate: f64 = match get("--rate", "").as_str() {
        "" => 15.0 * capacity_scale,
        r => r.parse().expect("bad --rate"),
    };

    // One workload description; every run streams a fresh cursor from it,
    // so all schedulers and modes see the identical request sequence.
    let workload = shape_slo(
        WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate }),
        slo,
    )
    .with_seed(seed);

    // Chaos layer. The empty plan replays bit-identically to a plan-less
    // run (pinned by rust/tests/faults_identity.rs), so every run goes
    // through the faulted entry point unconditionally.
    let horizon = n as f64 / rate;
    let plan = match faults.as_str() {
        "off" => FaultPlan::default(),
        // One hard crash of edge server 0 at the midpoint of the arrival
        // horizon, repaired after --mttr: the canonical incident the
        // availability row's pre/during/post phases are built around.
        "crash" => FaultPlan::default()
            .with_event(
                0.5 * horizon,
                FaultKind::Crash {
                    server: 0,
                    recover: Some(0.5 * horizon + mttr),
                },
            )
            .with_health(HealthConfig::default()),
        // Seeded fleet-wide MTTF/MTTR crash-repair process.
        "generative" => FaultPlan::default()
            .with_generative(GenerativeFaults {
                mttf_s: mttf,
                mttr_s: mttr,
                horizon_s: horizon,
                targets: Vec::new(),
                kill: true,
            })
            .with_health(HealthConfig::default()),
        other => panic!("bad --faults {other} (off|crash|generative)"),
    };
    // Regional failover: every server of the drained (first) tier crashes
    // for the drain window; the paired arrival drain installs per-run
    // below, on the tier's merged stream. Composes with --faults.
    let fail_at = 0.5 * horizon;
    let plan = if scenario == "regional-failover" {
        assert!(
            ref_topo.tiers.len() >= 2,
            "--scenario regional-failover needs >= 2 tiers (somewhere to fail over to)"
        );
        let mut p = plan;
        for server in 0..ref_topo.tiers[0].count {
            p = p.with_event(
                fail_at,
                FaultKind::Crash {
                    server,
                    recover: Some(fail_at + mttr),
                },
            );
        }
        p.with_health(HealthConfig::default())
    } else {
        plan
    };

    let mut floor_violations = 0usize;
    for mode in modes {
        let topo = TopologyConfig::by_name(&topology, &model, mode)
            .expect("checked above")
            .with_service_model_by_name(&service_model)
            .unwrap_or_else(|| {
                panic!("bad --service-model {service_model} (ps|token-batch|token-batch-edge)")
            });
        let cfg = topo.build();
        println!(
            "\n=== topology {topology} ({} servers, capacity {:.1}x paper), edge model {model}, \
             service model {service_model}, {mix} mix{}, {slo:?} SLOs{}, {mode:?} bandwidth, \
             {n} requests at {rate:.1} req/s (streamed{}) ===",
            cfg.n_servers(),
            capacity_scale,
            if sessions { " (multi-turn sessions)" } else { "" },
            if gate { " + admission gate" } else { "" },
            match shards {
                Some(ShardCount::Auto) => {
                    format!(", sharded engine: auto = {} shards", topo.tiers.len())
                }
                Some(ShardCount::Fixed(k)) => format!(", sharded engine: {k} shards"),
                Some(ShardCount::Weighted(k)) => format!(
                    ", sharded engine: {} volume-weighted shards",
                    if k == 0 { topo.tiers.len() } else { k }
                ),
                None => String::new(),
            },
        );
        if scenario == "regional-failover" {
            println!(
                "    scenario regional-failover: tier '{}' ({} servers) drains to 10% and \
                 crashes over [{fail_at:.1}s, {:.1}s)",
                topo.tiers[0].name,
                topo.tiers[0].count,
                fail_at + mttr,
            );
        }
        let cloud = cfg.cloud_index();
        let ns = cfg.n_servers();

        let mut throughputs: Vec<(String, f64)> = Vec::new();
        let mut hit_rates: Vec<(String, f64)> = Vec::new();
        for name in &schedulers {
            let inner: Box<dyn Scheduler> = match name.as_str() {
                "fineinfer" => Box::new(FineInfer::new(cloud)),
                "agod" => Box::new(Agod::new(ns, seed)),
                "rewardless" => Box::new(RewardlessGuidance::new(ns)),
                "cs-ucb" => Box::new(CsUcb::with_defaults(ns)),
                "cs-ucb-slo" => Box::new(CsUcbSlo::with_defaults(ns)),
                "cs-ucb-sw" => Box::new(CsUcb::windowed(ns, 50)),
                "cs-ucb-disc" => Box::new(CsUcb::discounted(ns, 0.98)),
                "cs-ucb-affinity" => Box::new(CsUcbAffinity::with_defaults(ns)),
                other => panic!("unknown scheduler {other}"),
            };
            let mut s: Box<dyn Scheduler> = if gate {
                Box::new(TokenBucketGate::new(inner, GateParams::default()))
            } else {
                inner
            };
            // The engine entry point: sequential by default, or the
            // sharded parallel engine under --shards (bit-identical — see
            // rust/tests/sharded_identity.rs — so summary rows must match
            // across shard counts).
            let run = |source: &mut dyn ArrivalSource, s: &mut dyn Scheduler| match shards {
                Some(count) => {
                    let splan = topo.shard_plan(count);
                    simulate_stream_faulted_sharded(&cfg, &plan, &splan, source, s)
                }
                None => simulate_stream_faulted(&cfg, &plan, source, s),
            };
            let rep = if mix == "tiered" {
                // One locality-shaped stream per tier, k-way merged: every
                // scheduler still sees the identical merged sequence.
                // Under --sessions each tier's stream is a conversation
                // chain generator derived from the same tier workload.
                let tier_cfgs = tier_workloads(&topo, n, rate, seed, slo);
                let mut gens: Vec<Box<dyn ArrivalSource>> = tier_cfgs
                    .iter()
                    .map(|c| -> Box<dyn ArrivalSource> {
                        if sessions {
                            Box::new(SessionSource::new(&SessionConfig::from_workload(
                                c.clone(),
                            )))
                        } else {
                            Box::new(WorkloadGen::new(c))
                        }
                    })
                    .collect();
                let sources: Vec<&mut dyn ArrivalSource> =
                    gens.iter_mut().map(|g| g.as_mut()).collect();
                let mut source = MergedArrivals::new(sources);
                if scenario == "regional-failover" {
                    // Drain the first tier to 10% of its rate for the
                    // crash window; every other tier keeps its stream
                    // bit-identical (ArrivalModulation::None).
                    let mut mods = vec![ArrivalModulation::None; topo.tiers.len()];
                    mods[0] = ArrivalModulation::FlashCrowd {
                        at_s: fail_at,
                        duration_s: mttr,
                        factor: 0.1,
                    };
                    source = source.with_modulations(mods);
                }
                run(&mut source, s.as_mut())
            } else if sessions {
                let mut source =
                    SessionSource::new(&SessionConfig::from_workload(workload.clone()));
                run(&mut source, s.as_mut())
            } else {
                let mut source = WorkloadGen::new(&workload);
                run(&mut source, s.as_mut())
            };
            println!("{}", rep.summary_row());
            println!(
                "    dropped {} (policy {}) late {} unfinished {}",
                rep.dropped, rep.dropped_by_policy, rep.late, rep.unfinished
            );
            if slo == SloSampling::PerClass || gate {
                println!("    {}", rep.slo_summary_row());
            }
            if sessions {
                println!("    {}", rep.cache_row());
            }
            if let Some(av) = &rep.availability {
                println!("    {}", av.availability_row());
            }
            println!(
                "    DES: {} events in {:.2}s wall = {:.0} events/s, \
                 stale ratio {:.4} ({} stale), peak heap {}",
                rep.events_processed,
                rep.wall_s,
                rep.events_per_sec,
                rep.stale_ratio,
                rep.stale_events,
                rep.peak_event_queue_len
            );
            if let Some(sp) = &rep.shard_perf {
                for line in sp.rows().lines() {
                    println!("    {line}");
                }
            }
            if min_success > 0.0 && rep.success_rate < min_success {
                eprintln!(
                    "FLOOR VIOLATION: {name} success {:.3} < {min_success}",
                    rep.success_rate
                );
                floor_violations += 1;
            }
            if min_events > 0.0 && rep.events_per_sec < min_events {
                eprintln!(
                    "FLOOR VIOLATION: {name} events/s {:.0} < {min_events}",
                    rep.events_per_sec
                );
                floor_violations += 1;
            }
            if max_peak_heap > 0 && rep.peak_event_queue_len > max_peak_heap {
                eprintln!(
                    "FLOOR VIOLATION: {name} peak event heap {} > {max_peak_heap} \
                     (streaming no longer bounds the heap)",
                    rep.peak_event_queue_len
                );
                floor_violations += 1;
            }
            if min_recovered > 0.0 {
                // Only meaningful for a faulted run that actually
                // recovered; a run with no post-recovery outcomes fails
                // the gate loudly rather than vacuously passing.
                let post = rep
                    .availability
                    .as_ref()
                    .map(|av| av.attainment[2])
                    .filter(|a| a.total > 0);
                match post {
                    Some(a) if a.rate() >= min_recovered => {}
                    Some(a) => {
                        eprintln!(
                            "FLOOR VIOLATION: {name} post-recovery attainment {:.3} \
                             < {min_recovered}",
                            a.rate()
                        );
                        floor_violations += 1;
                    }
                    None => {
                        eprintln!(
                            "FLOOR VIOLATION: {name} has no post-recovery outcomes \
                             to hold --min-recovered-attainment against"
                        );
                        floor_violations += 1;
                    }
                }
            }
            if min_gate_sheds > 0 && rep.gate_sheds < min_gate_sheds {
                eprintln!(
                    "FLOOR VIOLATION: {name} gate sheds {} < {min_gate_sheds} \
                     (the admission gate stopped converting predicted misses)",
                    rep.gate_sheds
                );
                floor_violations += 1;
            }
            if sessions {
                let hit = rep.cache.hit_rate().unwrap_or(0.0);
                hit_rates.push((name.clone(), hit));
                if min_cache_hit > 0.0 && hit < min_cache_hit {
                    eprintln!(
                        "FLOOR VIOLATION: {name} cache hit rate {hit:.3} < {min_cache_hit} \
                         (warm turns stopped finding their prefixes)"
                    );
                    floor_violations += 1;
                }
            }
            throughputs.push((name.clone(), rep.throughput_tok_s));
            for (k, v) in rep.diagnostics {
                if k == "cum_regret"
                    || k == "regret_bound"
                    || k == "fallback_decisions"
                    || k == "shed_decisions"
                    || k == "gate_sheds"
                    || k == "gate_token_admissions"
                    || k == "arm_resets"
                {
                    println!("    {k}: {v:.1}");
                }
            }
        }
        // Ratios as a post-pass so the FineInfer baseline applies no matter
        // where (or whether) it appears in --schedulers.
        if let Some((_, base)) = throughputs.iter().find(|(n, _)| n == "fineinfer") {
            let base = *base;
            for (name, thpt) in &throughputs {
                if name != "fineinfer" {
                    println!("    {name} throughput vs FineInfer: {:.2}x", thpt / base);
                }
            }
        }
        // Affinity-vs-SLO cache comparison: the point of the sticky
        // scheduler is a higher prefix hit rate on the same stream.
        let aff = hit_rates.iter().find(|(n, _)| n == "cs-ucb-affinity");
        let slo_hit = hit_rates.iter().find(|(n, _)| n == "cs-ucb-slo");
        if let (Some((_, a)), Some((_, b))) = (aff, slo_hit) {
            println!(
                "    cs-ucb-affinity hit rate {:.3} vs cs-ucb-slo {:.3} ({:+.1} pp)",
                a,
                b,
                (a - b) * 100.0
            );
            if require_uplift && a + 1e-9 < *b {
                eprintln!(
                    "FLOOR VIOLATION: cs-ucb-affinity hit rate {a:.3} fell below \
                     cs-ucb-slo's {b:.3} (stickiness stopped paying)"
                );
                floor_violations += 1;
            }
        } else if require_uplift {
            eprintln!(
                "FLOOR VIOLATION: --require-affinity-uplift needs both cs-ucb-affinity \
                 and cs-ucb-slo in --schedulers"
            );
            floor_violations += 1;
        }
    }
    if floor_violations > 0 {
        eprintln!("{floor_violations} floor violation(s)");
        std::process::exit(1);
    }
}
