"""L1 correctness: Pallas flash-attention kernel vs pure-jnp oracle.

This is the core numeric signal of the compile path: if these pass, the HLO
the Rust runtime executes computes the same attention as the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    flash_attention,
    mha,
    mxu_utilization_estimate,
    vmem_bytes,
)
from compile.kernels.ref import attention_ref, mha_ref

ATOL = 2e-5
RTOL = 2e-5


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


@pytest.mark.parametrize("bh,sq,skv,d", [
    (1, 16, 16, 8),
    (2, 64, 64, 16),
    (4, 64, 128, 16),
    (8, 128, 128, 32),
    (3, 32, 96, 16),
])
def test_prefill_matches_ref(bh, sq, skv, d):
    q, k, v = rand(1, (bh, sq, d)), rand(2, (bh, skv, d)), rand(3, (bh, skv, d))
    qpos = jnp.zeros((bh,), jnp.int32)
    kvlen = jnp.full((bh,), skv, jnp.int32)
    out = flash_attention(q, k, v, qpos, kvlen, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, qpos, kvlen)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("block_q,block_k", [(8, 8), (16, 32), (32, 16), (64, 64)])
def test_block_shape_invariance(block_q, block_k):
    """Result must not depend on tiling — the schedule is semantics-free."""
    bh, s, d = 2, 64, 16
    q, k, v = rand(4, (bh, s, d)), rand(5, (bh, s, d)), rand(6, (bh, s, d))
    qpos = jnp.zeros((bh,), jnp.int32)
    kvlen = jnp.full((bh,), s, jnp.int32)
    out = flash_attention(q, k, v, qpos, kvlen, block_q=block_q, block_k=block_k)
    ref = attention_ref(q, k, v, qpos, kvlen)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)


def test_kv_len_masks_padding():
    """Keys past kv_len must not influence the output at all."""
    bh, s, d = 2, 32, 8
    q = rand(7, (bh, 1, d))
    k, v = rand(8, (bh, s, d)), rand(9, (bh, s, d))
    kvlen = jnp.array([10, 3], jnp.int32)
    qpos = kvlen - 1
    out1 = flash_attention(q, k, v, qpos, kvlen, block_q=1, block_k=8, causal=False)
    # Scribble over the padding region — output must be identical.
    k2 = k.at[:, 10:, :].set(999.0)
    v2 = v.at[:, 10:, :].set(-999.0)
    k2 = k2.at[1, 3:, :].set(123.0)
    v2 = v2.at[1, 3:, :].set(-55.0)
    out2 = flash_attention(q, k2, v2, qpos, kvlen, block_q=1, block_k=8, causal=False)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


def test_causal_mask_exact():
    """Row i must only attend to keys j <= i (absolute positions)."""
    bh, s, d = 1, 16, 8
    q, k, v = rand(10, (bh, s, d)), rand(11, (bh, s, d)), rand(12, (bh, s, d))
    qpos = jnp.zeros((bh,), jnp.int32)
    kvlen = jnp.full((bh,), s, jnp.int32)
    out = flash_attention(q, k, v, qpos, kvlen, block_q=4, block_k=4)
    # Brute-force per-row softmax
    for i in range(s):
        sc = (q[0, i] @ k[0, : i + 1].T) / np.sqrt(d)
        p = np.exp(sc - sc.max())
        p /= p.sum()
        expect = p @ v[0, : i + 1]
        np.testing.assert_allclose(out[0, i], expect, atol=1e-5, rtol=1e-5)


def test_decode_positions():
    """q_len=1 decode at several absolute positions equals the oracle."""
    bh, s, d = 4, 64, 16
    q = rand(13, (bh, 1, d))
    k, v = rand(14, (bh, s, d)), rand(15, (bh, s, d))
    pos = jnp.array([0, 17, 40, 63], jnp.int32)
    out = flash_attention(q, k, v, pos, pos + 1, block_q=1, block_k=16)
    ref = attention_ref(q, k, v, pos, pos + 1)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)


def test_mha_wrapper_matches_ref():
    b, h, s, d = 2, 4, 32, 8
    q, k, v = rand(16, (b, h, s, d)), rand(17, (b, h, s, d)), rand(18, (b, h, s, d))
    qpos = jnp.zeros((b,), jnp.int32)
    kvlen = jnp.full((b,), s, jnp.int32)
    out = mha(q, k, v, qpos, kvlen, block_q=8, block_k=8)
    ref = mha_ref(q, k, v, qpos, kvlen)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)


def test_fully_masked_rows_are_finite():
    """Padding query rows (empty mask) must produce finite output, not NaN."""
    bh, s, d = 1, 8, 4
    q, k, v = rand(19, (bh, s, d)), rand(20, (bh, s, d)), rand(21, (bh, s, d))
    qpos = jnp.zeros((bh,), jnp.int32)
    kvlen = jnp.zeros((bh,), jnp.int32)  # nothing valid
    out = flash_attention(q, k, v, qpos, kvlen, block_q=4, block_k=4, causal=False)
    assert bool(jnp.isfinite(out).all())


@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(1, 4),
    sq_blocks=st.integers(1, 4),
    skv_blocks=st.integers(1, 4),
    d=st.sampled_from([4, 8, 16, 32]),
    block=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(bh, sq_blocks, skv_blocks, d, block, causal, seed):
    """Property: kernel == oracle over a randomized shape/config space."""
    sq, skv = sq_blocks * block, skv_blocks * block
    q = rand(seed, (bh, sq, d))
    k = rand(seed + 1, (bh, skv, d))
    v = rand(seed + 2, (bh, skv, d))
    key = jax.random.PRNGKey(seed + 3)
    kvlen = jax.random.randint(key, (bh,), 1, skv + 1)
    qpos = jnp.zeros((bh,), jnp.int32)
    out = flash_attention(q, k, v, qpos, kvlen, block_q=block, block_k=block, causal=causal)
    ref = attention_ref(q, k, v, qpos, kvlen, causal=causal)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from(["float32", "bfloat16"]), seed=st.integers(0, 1000))
def test_hypothesis_dtype_sweep(dtype, seed):
    """bf16 inputs (MXU-native) stay close to the f32 oracle."""
    dt = jnp.dtype(dtype)
    bh, s, d = 2, 32, 16
    q = rand(seed, (bh, s, d)).astype(dt)
    k = rand(seed + 1, (bh, s, d)).astype(dt)
    v = rand(seed + 2, (bh, s, d)).astype(dt)
    qpos = jnp.zeros((bh,), jnp.int32)
    kvlen = jnp.full((bh,), s, jnp.int32)
    out = flash_attention(q, k, v, qpos, kvlen, block_q=8, block_k=8)
    ref = attention_ref(q, k, v, qpos, kvlen)
    tol = 5e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_indivisible_block_raises():
    q = rand(22, (1, 30, 8))
    k = rand(23, (1, 32, 8))
    v = rand(24, (1, 32, 8))
    with pytest.raises(ValueError):
        flash_attention(q, k, v, jnp.zeros((1,), jnp.int32), jnp.full((1,), 32),
                        block_q=16, block_k=16)


def test_vmem_estimate_under_budget():
    """Shipped configs must fit the 16 MiB VMEM budget (DESIGN.md §8)."""
    for skv, d in [(128, 64), (256, 128)]:
        assert vmem_bytes(64, 64, skv, d) < 16 * 1024 * 1024


def test_mxu_estimate_monotone():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 128, 128) == 0.5
    assert mxu_utilization_estimate(64, 64, 16) < mxu_utilization_estimate(128, 128, 16)
