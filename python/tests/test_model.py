"""L2 correctness: the tiny decoder's KV-cache serving path must equal the
teacher-forcing forward, and training must reduce loss (bwd works)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(name="test", d_model=32, n_layers=2, n_heads=2, d_ff=48, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_param_count_matches_arch(params):
    d, f, v, L = CFG.d_model, CFG.d_ff, CFG.vocab, CFG.n_layers
    expect = v * d + d * v + d + L * (4 * d * d + 3 * d * f + 2 * d)
    assert CFG.param_count(params) == expect


def test_prefill_matches_full_forward(params):
    prompt = jnp.array(bytearray(b"edge cloud"), jnp.int32)
    P = prompt.shape[0]
    tok = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :P].set(prompt)
    logits, kv = M.prefill(CFG, params, tok, jnp.array(P, jnp.int32), use_kernel=True)
    full = M.forward_full(CFG, params, tok[:, :P])
    np.testing.assert_allclose(logits[0], full[0, P - 1], atol=2e-5, rtol=2e-5)
    assert kv.shape == CFG.kv_shape(1)


def test_decode_chain_matches_full_forward(params):
    """Prefill + N decode steps == teacher forcing over the whole string."""
    prompt = jnp.array(bytearray(b"abc"), jnp.int32)
    P = prompt.shape[0]
    tok = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :P].set(prompt)
    logits, kv = M.prefill(CFG, params, tok, jnp.array(P, jnp.int32), use_kernel=True)
    seq = list(np.array(prompt))
    for step in range(5):
        nxt = int(jnp.argmax(logits, -1)[0])
        seq.append(nxt)
        logits, kv = M.decode_step(
            CFG, params,
            jnp.array([nxt], jnp.int32),
            jnp.array([P + step], jnp.int32),
            kv, use_kernel=True,
        )
        full = M.forward_full(CFG, params, jnp.array([seq], jnp.int32))
        np.testing.assert_allclose(
            logits[0], full[0, -1], atol=5e-5, rtol=5e-5,
            err_msg=f"divergence at decode step {step}",
        )


def test_batched_decode_lanes_independent(params):
    """Lanes in a decode batch must not leak into each other."""
    tok1 = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :2].set(jnp.array([65, 66]))
    tok2 = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :3].set(jnp.array([97, 98, 99]))
    l1, kv1 = M.prefill(CFG, params, tok1, jnp.array(2, jnp.int32), use_kernel=True)
    l2, kv2 = M.prefill(CFG, params, tok2, jnp.array(3, jnp.int32), use_kernel=True)
    # Solo decode.
    s1, _ = M.decode_step(CFG, params, jnp.array([1], jnp.int32),
                          jnp.array([2], jnp.int32), kv1, use_kernel=True)
    s2, _ = M.decode_step(CFG, params, jnp.array([2], jnp.int32),
                          jnp.array([3], jnp.int32), kv2, use_kernel=True)
    # Batched decode of both lanes.
    kv = jnp.concatenate([kv1, kv2], axis=0)
    lb, _ = M.decode_step(CFG, params, jnp.array([1, 2], jnp.int32),
                          jnp.array([2, 3], jnp.int32), kv, use_kernel=True)
    np.testing.assert_allclose(lb[0], s1[0], atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(lb[1], s2[0], atol=2e-5, rtol=2e-5)


def test_kernel_and_ref_paths_agree(params):
    tok = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :4].set(
        jnp.array([10, 20, 30, 40])
    )
    lk, kvk = M.prefill(CFG, params, tok, jnp.array(4, jnp.int32), use_kernel=True)
    lr, kvr = M.prefill(CFG, params, tok, jnp.array(4, jnp.int32), use_kernel=False)
    np.testing.assert_allclose(lk, lr, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(kvk, kvr, atol=2e-5, rtol=2e-5)


def test_training_reduces_loss():
    tiny = M.ModelConfig(name="tiny", d_model=16, n_layers=1, n_heads=2,
                         d_ff=24, max_seq=32)
    params, curve = M.train(tiny, steps=60, batch=8, seq=24, log_every=1000)
    assert curve[-1] < curve[0] * 0.7, f"loss did not drop: {curve}"


def test_gradients_flow_to_all_params():
    tiny = M.ModelConfig(name="tiny", d_model=16, n_layers=1, n_heads=2,
                         d_ff=24, max_seq=32)
    params = M.init_params(tiny, jax.random.PRNGKey(1))
    tokens = jnp.array([[1, 2, 3, 4]], jnp.int32)
    targets = jnp.array([[2, 3, 4, 5]], jnp.int32)
    grads = jax.grad(lambda p: M.loss_fn(tiny, p, tokens, targets))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), f"non-finite grad at {path}"
        # Embedding rows for unused tokens are legitimately zero; every
        # other tensor must receive signal.
        name = jax.tree_util.keystr(path)
        if "embed" not in name:
            assert float(jnp.abs(g).max()) > 0, f"zero grad at {name}"


def test_rope_position_dependence(params):
    """Same token at different positions must produce different K rows."""
    kv = jnp.zeros(CFG.kv_shape(2), jnp.float32)
    logits, kv2 = M.decode_step(
        CFG, params,
        jnp.array([65, 65], jnp.int32),
        jnp.array([0, 7], jnp.int32),
        kv, use_kernel=False,
    )
    k_row_0 = kv2[0, 0, 0, 0]  # lane 0 wrote position 0
    k_row_7 = kv2[1, 0, 0, 7]  # lane 1 wrote position 7
    assert not np.allclose(k_row_0, k_row_7, atol=1e-6)
