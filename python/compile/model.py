"""L2: JAX model — tiny LLaMA-style decoder with KV cache (fwd + bwd).

Two configs ship ("edge" and "cloud"), standing in for the paper's
edge-deployed Yi-6B/LLaMA2-7B-class models and the cloud-deployed
LLaMA2-33B (DESIGN.md §2 substitution table). Architecture is the real
thing at toy scale: token embedding, RMSNorm, rotary position embeddings,
multi-head attention through the Layer-1 Pallas kernel, SwiGLU MLP, weight
tying off (separate unembed), byte-level vocabulary (V=256) so the Rust
tokenizer is a no-op codec.

Two entry points get AOT-lowered by ``aot.py``:

* ``prefill(params, tokens[1,S], length)`` -> (logits[1,V], kv[1,2,L,S,KD])
* ``decode_step(params, tokens[B], pos[B], kv[B,2,L,S,KD])``
  -> (logits[B,V], kv')

The KV cache is laid out batch-major so the Rust coordinator can slice one
request's cache as a single contiguous run of floats when assembling /
disassembling continuous batches (rust/src/runtime/engine.rs).

``loss_fn``/``train`` exercise the backward path (jax.grad through the
model) and produce the checked-in artifact weights: a character-level LM
trained for a few hundred Adam steps on a small embedded corpus, so the
end-to-end Rust serving example generates text that is visibly non-random.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import mha
from .kernels.ref import mha_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters for one deployment size."""

    name: str
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 176
    max_seq: int = 128
    rope_theta: float = 10000.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.d_model

    def kv_shape(self, batch: int) -> Tuple[int, int, int, int, int]:
        """(B, 2, L, S, KD) — batch-major so per-request caches are contiguous."""
        return (batch, 2, self.n_layers, self.max_seq, self.kv_dim)

    def param_count(self, params: Dict[str, Any] | None = None) -> int:
        leaves = jax.tree_util.tree_leaves(params or init_params(self, jax.random.PRNGKey(0)))
        return sum(int(x.size) for x in leaves)


# The two deployment sizes shipped as artifacts. Edge ~ the paper's 6-9B
# class (small, fast, lower quality), cloud ~ the 33B class (bigger, slower
# per watt at the edge but higher quality).
EDGE = ModelConfig(name="edge", d_model=64, n_layers=2, n_heads=4, d_ff=176, max_seq=128)
CLOUD = ModelConfig(name="cloud", d_model=128, n_layers=4, n_heads=8, d_ff=352, max_seq=256)

CONFIGS: Dict[str, ModelConfig] = {"edge": EDGE, "cloud": CLOUD}


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """Deterministic scaled-normal init, one dict entry per tensor."""

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    keys = jax.random.split(key, 2 + cfg.n_layers)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    params: Dict[str, Any] = {
        "embed": nrm(keys[0], (v, d), 0.02),
        "unembed": nrm(keys[1], (d, v), 0.02),
        "norm_f": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + li], 7)
        params["layers"].append(
            {
                "wq": nrm(lk[0], (d, d), d**-0.5),
                "wk": nrm(lk[1], (d, d), d**-0.5),
                "wv": nrm(lk[2], (d, d), d**-0.5),
                "wo": nrm(lk[3], (d, d), d**-0.5),
                "w_gate": nrm(lk[4], (d, f), d**-0.5),
                "w_up": nrm(lk[5], (d, f), d**-0.5),
                "w_down": nrm(lk[6], (f, d), f**-0.5),
                "norm_attn": jnp.ones((d,), jnp.float32),
                "norm_mlp": jnp.ones((d,), jnp.float32),
            }
        )
    return params


def _rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, H, S, Dh); pos: (B, S) absolute positions."""
    b, h, s, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None, :, None] * freqs[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)  # (B,1,S,half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_block(
    cfg: ModelConfig,
    lp: Dict[str, jax.Array],
    x: jax.Array,
    pos: jax.Array,
    k_all: jax.Array,
    v_all: jax.Array,
    kv_len: jax.Array,
    q_pos: jax.Array,
    *,
    causal: bool,
    use_kernel: bool,
) -> jax.Array:
    """Shared attention block. x: (B, S, d); k_all/v_all: (B, Skv, d)."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    skv = k_all.shape[1]

    def split(t, sl):
        return t.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)  # (B,H,S,Dh)

    q = split(x @ lp["wq"], s)
    q = _rope(q, pos, cfg.rope_theta)
    kh = split(k_all, skv)
    vh = split(v_all, skv)
    attn = mha if use_kernel else mha_ref
    out = attn(q, kh, vh, q_pos, kv_len, causal=causal)  # (B,H,S,Dh)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ lp["wo"]


def _mlp(lp: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ lp["w_gate"]) * (x @ lp["w_up"])) @ lp["w_down"]


def forward_full(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: jax.Array,
    *,
    use_kernel: bool = False,
) -> jax.Array:
    """Teacher-forcing forward over (B, S) tokens -> (B, S, V) logits.

    Used for training (bwd via jax.grad) and as the KV-cache equivalence
    oracle in tests. Defaults to the jnp reference attention because
    interpret-mode Pallas inside a training loop is needlessly slow; the two
    paths are asserted equal in python/tests/test_kernel.py.
    """
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_pos = jnp.zeros((b,), jnp.int32)
    kv_len = jnp.full((b,), s, jnp.int32)
    x = params["embed"][tokens]
    for lp in params["layers"]:
        xn = _rmsnorm(x, lp["norm_attn"])
        k_all = _rope(
            (xn @ lp["wk"]).reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3),
            pos,
            cfg.rope_theta,
        ).transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        v_all = xn @ lp["wv"]
        x = x + _attn_block(
            cfg, lp, xn, pos, k_all, v_all, kv_len, q_pos,
            causal=True, use_kernel=use_kernel,
        )
        x = x + _mlp(lp, _rmsnorm(x, lp["norm_mlp"]))
    x = _rmsnorm(x, params["norm_f"])
    return x @ params["unembed"]


def prefill(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: jax.Array,
    length: jax.Array,
    *,
    use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Process a padded prompt. tokens: (1, S=cfg.max_seq); length: () int32.

    Returns (next-token logits (1, V), kv cache (1, 2, L, S, KD)). Rows past
    ``length`` in the cache hold garbage and are masked out by kv_len at
    decode time.
    """
    b, s = tokens.shape
    assert s == cfg.max_seq, (s, cfg.max_seq)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_pos = jnp.zeros((b,), jnp.int32)
    kv_len = jnp.full((b,), s, jnp.int32)  # causal mask handles the rest
    x = params["embed"][tokens]
    ks: List[jax.Array] = []
    vs: List[jax.Array] = []
    for lp in params["layers"]:
        xn = _rmsnorm(x, lp["norm_attn"])
        k_all = _rope(
            (xn @ lp["wk"]).reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3),
            pos,
            cfg.rope_theta,
        ).transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        v_all = xn @ lp["wv"]
        ks.append(k_all)
        vs.append(v_all)
        x = x + _attn_block(
            cfg, lp, xn, pos, k_all, v_all, kv_len, q_pos,
            causal=True, use_kernel=use_kernel,
        )
        x = x + _mlp(lp, _rmsnorm(x, lp["norm_mlp"]))
    x = _rmsnorm(x, params["norm_f"])
    logits_all = x @ params["unembed"]  # (1, S, V)
    last = jnp.take_along_axis(
        logits_all, (length - 1).reshape(1, 1, 1).astype(jnp.int32), axis=1
    )[:, 0, :]
    kv = jnp.stack(
        [jnp.stack(ks, axis=0), jnp.stack(vs, axis=0)], axis=0
    )  # (2, L, B, S, KD)
    kv = kv.transpose(2, 0, 1, 3, 4)  # (B, 2, L, S, KD)
    return last, kv


def decode_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: jax.Array,
    pos: jax.Array,
    kv: jax.Array,
    *,
    use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """One continuous-batching decode iteration.

    tokens: (B,) int32 — the token at position ``pos`` for each request.
    pos: (B,) int32 — absolute position of that token.
    kv: (B, 2, L, S, KD) — per-request caches, valid in [0, pos).

    Returns (logits (B, V), updated kv with row ``pos`` written).
    Padding lanes (dead batch slots) simply carry pos=0 and are ignored by
    the Rust coordinator.
    """
    b = tokens.shape[0]
    s = cfg.max_seq
    pos = pos.astype(jnp.int32)
    x = params["embed"][tokens][:, None, :]  # (B, 1, d)
    pos2 = pos[:, None]  # (B, 1)
    kv_len = pos + 1
    for li, lp in enumerate(params["layers"]):
        xn = _rmsnorm(x, lp["norm_attn"])
        k_new = _rope(
            (xn @ lp["wk"]).reshape(b, 1, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3),
            pos2,
            cfg.rope_theta,
        ).transpose(0, 2, 1, 3).reshape(b, 1, cfg.d_model)
        v_new = xn @ lp["wv"]

        # Scatter this step's K/V row into each request's cache at `pos`.
        def put(cache_b, row_b, p):
            return jax.lax.dynamic_update_slice(cache_b, row_b, (p, 0))

        kv = kv.at[:, 0, li].set(jax.vmap(put)(kv[:, 0, li], k_new, pos))
        kv = kv.at[:, 1, li].set(jax.vmap(put)(kv[:, 1, li], v_new, pos))
        x = x + _attn_block(
            cfg, lp, xn, pos2, kv[:, 0, li], kv[:, 1, li], kv_len, pos,
            causal=False, use_kernel=use_kernel,
        )
        x = x + _mlp(lp, _rmsnorm(x, lp["norm_mlp"]))
    x = _rmsnorm(x, params["norm_f"])
    logits = (x @ params["unembed"])[:, 0, :]
    return logits, kv


# --------------------------------------------------------------------------
# Training (bwd path) — character-level LM on a small embedded corpus.
# --------------------------------------------------------------------------

CORPUS = (
    "Edge-cloud collaboration distributes large language model services "
    "between nearby edge servers and a powerful cloud server. The cloud "
    "offers high quality inference at high energy cost and congested "
    "uplinks; the edge answers fast and cheap but with smaller models. "
    "PerLLM schedules each request to the server that meets its deadline "
    "at the lowest energy, using a constraint satisfaction upper "
    "confidence bound bandit over servers. Diverse services ask for chat, "
    "summaries, translation and code; deadlines range from two to six "
    "seconds; bandwidth fluctuates by twenty percent. The scheduler "
    "learns which server completes which service class in time, and the "
    "regret of its decisions grows only logarithmically. "
) * 8


def batches(cfg: ModelConfig, key: jax.Array, batch: int, seq: int):
    """Infinite stream of (tokens, targets) char-LM batches from CORPUS."""
    data = jnp.array(bytearray(CORPUS.encode("utf-8")), jnp.int32)
    n = data.shape[0] - seq - 1
    while True:
        key, sub = jax.random.split(key)
        starts = jax.random.randint(sub, (batch,), 0, n)
        idx = starts[:, None] + jnp.arange(seq + 1)[None, :]
        chunk = data[idx]
        yield chunk[:, :-1], chunk[:, 1:]


def loss_fn(cfg: ModelConfig, params, tokens, targets) -> jax.Array:
    logits = forward_full(cfg, params, tokens, use_kernel=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1**tf)
    vhat_scale = 1.0 / (1 - b2**tf)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, steps: int = 400, batch: int = 32, seq: int = 64,
          seed: int = 0, log_every: int = 100) -> Tuple[Dict[str, Any], List[float]]:
    """Train the tiny model; returns (params, loss curve). Exercises bwd."""
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt = adam_init(params)
    stream = batches(cfg, jax.random.PRNGKey(seed + 1), batch, seq)

    @jax.jit
    def step(params, opt, tokens, targets):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)
        params, opt = adam_update(params, grads, opt)
        return params, opt, loss

    curve: List[float] = []
    for i in range(steps):
        tokens, targets = next(stream)
        params, opt, loss = step(params, opt, tokens, targets)
        if i % log_every == 0 or i == steps - 1:
            curve.append(float(loss))
            print(f"[train:{cfg.name}] step {i:4d} loss {float(loss):.4f}")
    return params, curve


def param_leaves(params) -> List[jax.Array]:
    """Flat leaf order — MUST match the AOT manifest and the Rust loader."""
    return jax.tree_util.tree_leaves(params)


def leaf_names(params) -> List[str]:
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]
