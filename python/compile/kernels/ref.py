"""Pure-jnp oracle for the Pallas attention kernel.

Implements exactly the masking semantics of ``attention.flash_attention``
(absolute-position causal mask + kv_len padding mask) with a plain softmax,
so any divergence in the kernel's online-softmax accumulation shows up in
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Reference attention over packed (BH, S, d) inputs.

    Same signature/semantics as ``attention.flash_attention`` minus tiling.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / (d**0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    kpos = jnp.arange(skv, dtype=jnp.int32)
    valid = kpos[None, None, :] < kv_len.astype(jnp.int32)[:, None, None]
    if causal:
        qpos = q_pos.astype(jnp.int32)[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
        valid = valid & (kpos[None, None, :] <= qpos[:, :, None])
    s = jnp.where(valid, s, _NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    # Rows that are fully masked (padding queries) sum to ~0; guard the divide
    # the same way the kernel does.
    denom = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqk,bkd->bqd", p / denom, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Multi-head reference: (B, H, S, d) -> (B, H, S, d)."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    out = attention_ref(
        q.reshape(b * h, sq, d),
        k.reshape(b * h, skv, d),
        v.reshape(b * h, skv, d),
        jnp.repeat(q_pos.astype(jnp.int32), h),
        jnp.repeat(kv_len.astype(jnp.int32), h),
        causal=causal,
    )
    return out.reshape(b, h, sq, d)
