"""L1: Pallas flash-attention kernel (TPU-style, interpret mode).

This is the serving hot spot of the PerLLM stack — the attention contraction
inside both the prefill and decode paths of the Layer-2 model. The paper's
testbed runs attention on an A100; the TPU adaptation (DESIGN.md §7) tiles Q
into VMEM-resident blocks and streams K/V tiles through VMEM with an online
(numerically stable, single-pass) softmax — the TPU analogue of
flash-attention's SRAM tiling. Contractions are shaped for the MXU (head-dim
and block sizes multiples of 8/128 where the model allows).

interpret=True is mandatory on this image: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Correctness is checked
against ``kernels.ref`` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic-array edge; the q tile is
# kept small so (block_q x d) + 2 x (block_k x d) + accumulators fit well
# under the ~16 MiB VMEM budget for every config we ship (see DESIGN.md §8).
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30


def _attn_kernel(
    qpos_ref,
    kvlen_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    block_k: int,
    scale: float,
    causal: bool,
):
    """One grid step: one (batch*head, q-tile) pair.

    q_ref: (1, block_q, d) VMEM tile of queries.
    k_ref/v_ref: (1, S, d) — streamed through in block_k-sized slices by the
    fori_loop below (on real TPU this loop would be a third grid dimension
    with VMEM scratch accumulators; for the S <= 512 configs we ship, K/V for
    one head fit in VMEM outright, so the in-kernel loop is the honest
    schedule too).
    qpos_ref/kvlen_ref: (1, 1) absolute position of the first query row and
    number of valid KV entries — this is how decode (q_len=1 at position p)
    and padded prefill share one kernel.
    """
    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    bq = q.shape[0]
    skv = k_ref.shape[1]
    nk = skv // block_k

    qpos0 = qpos_ref[0, 0]
    kvlen = kvlen_ref[0, 0]
    # Absolute row positions: base + this q-tile's offset within the sequence.
    tile_off = pl.program_id(1) * bq
    qpos = qpos0 + tile_off + jax.lax.iota(jnp.int32, bq)

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k = pl.load(k_ref, (0, pl.ds(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.ds(i * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        s = q @ k.T  # (bq, bk) — MXU contraction
        kpos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = kpos[None, :] < kvlen
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(valid, s, _NEG_INF)
        # Online softmax: renormalize the running accumulator by the new max.
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc_new = acc_prev * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros(q.shape, jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    # Rows whose mask is empty (padding queries) have l == 0; guard the divide.
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Tiled attention over packed (batch*heads) inputs.

    Args:
      q: (BH, Sq, d) queries.
      k, v: (BH, Skv, d) keys/values (may contain padding past ``kv_len``).
      q_pos: (BH,) int32 — absolute sequence position of q[:, 0, :].
        Prefill passes zeros; decode passes the per-request write position.
      kv_len: (BH,) int32 — number of valid KV rows per batch*head.
      causal: apply causal masking relative to absolute positions.

    Returns:
      (BH, Sq, d) attention output, same dtype as q.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q != 0:
        raise ValueError(f"Sq={sq} not divisible by block_q={block_q}")
    if skv % block_k != 0:
        raise ValueError(f"Skv={skv} not divisible by block_k={block_k}")

    grid = (bh, sq // block_q)
    scale = 1.0 / (d**0.5)
    qpos2 = q_pos.astype(jnp.int32).reshape(bh, 1)
    kvlen2 = kv_len.astype(jnp.int32).reshape(bh, 1)

    kernel = functools.partial(
        _attn_kernel, block_k=block_k, scale=scale, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Scalar-per-row metadata rides along as (1,1) tiles.
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
            # Q is tiled along the sequence axis: HBM -> VMEM per grid step.
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            # K/V: whole-head blocks; the kernel streams block_k slices.
            pl.BlockSpec((1, skv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(qpos2, kvlen2, q, k, v)


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Multi-head wrapper: (B, H, S, d) -> (B, H, S, d).

    Collapses (B, H) into the packed grid axis the kernel expects and
    broadcasts the per-batch metadata across heads.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    qpos_f = jnp.repeat(q_pos.astype(jnp.int32), h)
    kvlen_f = jnp.repeat(kv_len.astype(jnp.int32), h)
    out = flash_attention(
        qf,
        kf,
        vf,
        qpos_f,
        kvlen_f,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(b, h, sq, d)


def vmem_bytes(block_q: int, block_k: int, skv: int, d: int, itemsize: int = 4) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §8 / §Perf).

    q tile + whole-head K/V + f32 accumulators + softmax stats.
    """
    q_tile = block_q * d * itemsize
    kv = 2 * skv * d * itemsize
    acc = block_q * d * 4
    stats = 2 * block_q * 4
    ptile = block_q * block_k * 4
    return q_tile + kv + acc + stats + ptile


def mxu_utilization_estimate(block_q: int, block_k: int, d: int) -> float:
    """Fraction of the 128x128 MXU each contraction tile fills (DESIGN.md §8)."""
    fill = (min(block_q, 128) / 128.0) * (min(block_k, 128) / 128.0)
    depth = min(d, 128) / 128.0
    return fill * depth
