"""AOT compile path: JAX -> HLO text artifacts + weight blobs for Rust.

Runs ONCE at build time (``make artifacts``); Python is never on the request
path. For each deployment size (edge, cloud) this emits:

* ``{size}_prefill.hlo.txt``          — prefill, batch 1
* ``{size}_decode_b{B}.hlo.txt``      — one decode iteration per batch bucket
* ``{size}_params.bin``               — trained weights, raw little-endian f32,
                                        concatenated in jax tree-leaf order
* ``{size}_manifest.txt``             — one line per weight tensor:
                                        ``name dtype offset count d0 d1 ...``
* ``meta.txt``                        — model geometry the Rust engine needs

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Weights are runtime *arguments* (not baked constants) so the HLO stays small
and the weight blob is a normal deployable artifact; the Rust engine loads
the blob once and passes the same Literals to every execution.
"""

from __future__ import annotations

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Decode batch buckets compiled per size. The Rust batcher pads the live
# request set up to the nearest bucket (vLLM-style shape bucketing under AOT).
DECODE_BATCHES = [1, 2, 4, 8]

TRAIN_STEPS = {"edge": 500, "cloud": 700}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: M.ModelConfig, params) -> str:
    fn = functools.partial(M.prefill, cfg, use_kernel=True)

    def entry(params, tokens, length):
        return fn(params, tokens, length)

    tok_spec = jax.ShapeDtypeStruct((1, cfg.max_seq), jnp.int32)
    len_spec = jax.ShapeDtypeStruct((), jnp.int32)
    p_spec = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    return to_hlo_text(jax.jit(entry).lower(p_spec, tok_spec, len_spec))


def lower_decode(cfg: M.ModelConfig, params, batch: int) -> str:
    fn = functools.partial(M.decode_step, cfg, use_kernel=True)

    def entry(params, tokens, pos, kv):
        return fn(params, tokens, pos, kv)

    tok_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    kv_spec = jax.ShapeDtypeStruct(cfg.kv_shape(batch), jnp.float32)
    p_spec = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    return to_hlo_text(jax.jit(entry).lower(p_spec, tok_spec, pos_spec, kv_spec))


def dump_params(out_dir: str, cfg: M.ModelConfig, params) -> None:
    leaves = M.param_leaves(params)
    names = M.leaf_names(params)
    assert len(leaves) == len(names)
    blob = np.concatenate([np.asarray(x, np.float32).ravel() for x in leaves])
    blob.astype("<f4").tofile(os.path.join(out_dir, f"{cfg.name}_params.bin"))
    off = 0
    lines = []
    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        dims = " ".join(str(d) for d in arr.shape)
        lines.append(f"{name} f32 {off} {arr.size} {dims}")
        off += arr.size
    with open(os.path.join(out_dir, f"{cfg.name}_manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def write_meta(out_dir: str, curves) -> None:
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        f.write(f"decode_batches {' '.join(str(b) for b in DECODE_BATCHES)}\n")
        for cfg in M.CONFIGS.values():
            f.write(
                f"model {cfg.name} vocab {cfg.vocab} d_model {cfg.d_model} "
                f"n_layers {cfg.n_layers} n_heads {cfg.n_heads} "
                f"max_seq {cfg.max_seq} kv_dim {cfg.kv_dim}\n"
            )
        for name, curve in curves.items():
            pts = " ".join(f"{x:.4f}" for x in curve)
            f.write(f"loss_curve {name} {pts}\n")


def build(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    curves = {}
    for cfg in M.CONFIGS.values():
        steps = 30 if quick else TRAIN_STEPS[cfg.name]
        print(f"=== {cfg.name}: training {steps} steps "
              f"({cfg.param_count():,} params) ===")
        params, curve = M.train(cfg, steps=steps)
        curves[cfg.name] = curve
        dump_params(out_dir, cfg, params)

        print(f"=== {cfg.name}: lowering prefill (S={cfg.max_seq}) ===")
        text = lower_prefill(cfg, params)
        with open(os.path.join(out_dir, f"{cfg.name}_prefill.hlo.txt"), "w") as f:
            f.write(text)
        print(f"    {len(text):,} chars")

        for b in DECODE_BATCHES:
            print(f"=== {cfg.name}: lowering decode b{b} ===")
            text = lower_decode(cfg, params, b)
            with open(
                os.path.join(out_dir, f"{cfg.name}_decode_b{b}.hlo.txt"), "w"
            ) as f:
                f.write(text)
            print(f"    {len(text):,} chars")

    write_meta(out_dir, curves)
    print(f"artifacts written to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training run (CI smoke, weights undertrained)")
    args = ap.parse_args()
    build(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
