//! CS-UCB: the paper's Constraint Satisfaction Upper Confidence Bound
//! algorithm (§3.2, Algorithm 1).
//!
//! The edge-cloud assignment problem is a combinatorial multi-armed bandit:
//! the action space assigns each service to a server; the state space is
//! the per-server (compute, bandwidth) snapshot. We maintain one arm per
//! (service class × server) pair — the personalization axis — and per
//! decision:
//!
//! 1. filter actions through the constraint-satisfaction mechanism
//!    f(y) ≥ 0 (Eq. 3: normalized slack of C1 deadline, C2 compute,
//!    C3 bandwidth);
//! 2. score survivors with UCB(a,t) = R̄(a) + δ√(ln t / L(a,t)) + θP(t)
//!    (Eq. 6) and play the argmax;
//! 3. on completion, feed back the reward R = −(weighted energy) + λ f(y)
//!    (Eq. 4) and update the approximate regret (Eq. 5).
//!
//! If no action is feasible the service goes to the least-violating server
//! (the paper: "assigned to a more resource-rich server") and the penalty
//! term P(t) carries the violation severity into the index (Eq. 7).

use std::collections::VecDeque;

use super::{Action, ClusterView, FleetEvent, Scheduler, ShedReason};
use crate::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest};

/// Reward scale: 1 kJ of weighted energy ≡ 1.0 reward unit, keeping the
/// energy and constraint terms of Eq. 4 commensurate.
const ENERGY_SCALE_J: f64 = 1000.0;

/// CS-UCB hyperparameters (Algorithm 1's λ, α, β, δ, θ).
#[derive(Debug, Clone, Copy)]
pub struct CsUcbParams {
    /// Constraint-satisfaction coefficient λ in Eq. 4.
    pub lambda: f64,
    /// Approximation coefficients α, β < 1 in the regret definition (Eq. 5).
    pub alpha: f64,
    pub beta: f64,
    /// Exploration/exploitation balance δ in Eq. 6.
    pub delta: f64,
    /// Penalty conditioning parameter θ in Eq. 6/7.
    pub theta: f64,
    /// Required normalized slack on the binding constraint at admission
    /// (f(y) >= slack_margin). Absorbs load arriving between the decision
    /// and completion.
    pub slack_margin: f64,
    /// Shed the request outright when even the least-violating server has
    /// f(y) < -shed_threshold: every placement is so deep in violation
    /// (deadline hopeless or resources absolutely crammed) that uploading
    /// would only waste energy and link share. The default is
    /// `f64::INFINITY` — shedding disabled, the pure paper behavior
    /// (always fall back to least-violating), keeping `with_defaults`
    /// runs comparable to the paper and to pre-Action baselines. Serving
    /// deployments that prefer rejecting hopeless work should set ~2.0
    /// (only triggers when the binding constraint is violated ~3x over);
    /// the ablation example carries that variant.
    pub shed_threshold: f64,
    /// Constraint lens (PR 5). `false` — the paper's scalar behavior:
    /// decisions filter on [`ClusterView::completion_satisfaction`] and
    /// rewards on the realized completion slack, ignoring any TTFT/energy
    /// constraints the request carries (`with_defaults` stays
    /// paper-identical, and on SLO-vector workloads this IS the honest
    /// "completion-only CS-UCB" baseline). `true` — the [`CsUcbSlo`]
    /// variant: decisions filter on the full SLO vector
    /// ([`ClusterView::constraint_satisfaction`], TTFT slack from
    /// `predicted_ttft`) and rewards on the realized
    /// [`ServiceOutcome::slo_slack`], so interactive requests route by
    /// first-token slack.
    pub slo_aware: bool,
    /// Non-stationarity, opt-in (PR 6): `Some(w)` switches every arm to
    /// **sliding-window** statistics (SW-UCB) — the mean and the
    /// exploration bonus see only the last `w` rewards, so a server
    /// whose behavior changed (crash, restart, degradation) stops being
    /// judged on ancient history after at most `w` pulls. `None` keeps
    /// the classic incremental mean, code-path-identical to pre-PR6.
    /// Mutually exclusive with `discount`.
    pub window: Option<usize>,
    /// **Discounted** statistics (D-UCB): per update, the arm's
    /// accumulated reward mass and sample weight decay by `gamma`
    /// (0 < gamma < 1), giving an effective memory of ~1/(1-gamma)
    /// pulls. `None` = classic mean. Mutually exclusive with `window`.
    pub discount: Option<f64>,
    /// Reset a server's arms (every class) when it comes back —
    /// [`FleetEvent::Up`]/[`FleetEvent::Joined`]: a restarted server
    /// shares little with its pre-crash statistics, and the reset turns
    /// its arms optimistic-untried so they are re-explored immediately.
    pub reset_on_rejoin: bool,
    /// Cache-affinity stickiness weight (PR 10). At the default `0.0`
    /// the index is exactly Eq. 6 — decision-identical to pre-sessions
    /// builds bit for bit (the bonus is branch-gated, never computed).
    /// Positive values add
    /// `affinity * (prefix_hit_tokens / prompt_tokens) * (1 - prefix_pressure)`
    /// to each candidate's index: a server already holding the session's
    /// KV prefix wins ties (and small index gaps), scaled by how much of
    /// the prompt the hit covers and decayed by the target cache's
    /// eviction risk. [`CsUcbAffinity`] forces this on together with the
    /// SLO lens.
    pub affinity: f64,
}

impl Default for CsUcbParams {
    fn default() -> Self {
        CsUcbParams {
            lambda: 0.5,
            alpha: 0.95,
            beta: 0.95,
            delta: 0.25,
            theta: 0.3,
            slack_margin: 0.2,
            shed_threshold: f64::INFINITY,
            slo_aware: false,
            window: None,
            discount: None,
            reset_on_rejoin: false,
            affinity: 0.0,
        }
    }
}

/// Pending violation penalties P(t) keyed by request id. Both id sources —
/// DES trace indices and the live router's monotone counters — are dense
/// from zero, so a flat Vec with a NaN sentinel serves the million-request
/// path with no hashing and no per-decision allocation (growth is
/// amortized and monotone). Ids beyond the dense cap (never produced by
/// our id allocators, but the API takes arbitrary u64) spill to a map.
#[derive(Debug, Default)]
struct PendingPenalties {
    dense: Vec<f64>,
    /// Only ever touched via point lookups (`insert`/`remove` by id) —
    /// never iterated, so map order can't reach a scheduling decision
    /// (pallas-lint rule D2 enforces this staying true).
    spill: std::collections::HashMap<u64, f64>,
}

/// Dense ids up to 16M: 128 MB worst case, far past any single-run trace.
const DENSE_ID_LIMIT: u64 = 1 << 24;

impl PendingPenalties {
    fn insert(&mut self, id: u64, p: f64) {
        if id < DENSE_ID_LIMIT {
            let i = id as usize;
            if i >= self.dense.len() {
                self.dense.resize(i + 1, f64::NAN);
            }
            self.dense[i] = p;
        } else {
            self.spill.insert(id, p);
        }
    }

    fn remove(&mut self, id: u64) -> Option<f64> {
        if id < DENSE_ID_LIMIT {
            let i = id as usize;
            let slot = self.dense.get_mut(i)?;
            let v = *slot;
            *slot = f64::NAN;
            if v.is_nan() {
                None
            } else {
                Some(v)
            }
        } else {
            self.spill.remove(&id)
        }
    }
}

/// Per-arm statistics: estimated reward R̄(a) and pull count L(a, t),
/// plus the opt-in non-stationary accumulators (unused — and empty — in
/// the stationary default, whose update path is exactly the pre-PR6
/// incremental mean). `mean_reward` is always the current estimate under
/// whichever mode is active, so readers (`ucb`, `best_estimate`) never
/// branch on mode.
#[derive(Debug, Clone, Default)]
struct Arm {
    pulls: u64,
    mean_reward: f64,
    /// Sliding-window mode: the last `w` rewards and their running sum.
    window: VecDeque<f64>,
    win_sum: f64,
    /// Discounted mode: geometrically decayed reward mass and sample
    /// weight (D-UCB's N_gamma).
    disc_sum: f64,
    disc_weight: f64,
}

impl Arm {
    /// Stationary incremental mean — the pre-PR6 update, untouched.
    fn update(&mut self, r: f64) {
        self.pulls += 1;
        self.mean_reward += (r - self.mean_reward) / self.pulls as f64;
    }

    fn update_windowed(&mut self, r: f64, w: usize) {
        self.pulls += 1;
        self.window.push_back(r);
        self.win_sum += r;
        while self.window.len() > w {
            self.win_sum -= self.window.pop_front().expect("len > w >= 1"); // lint: allow(p1) loop condition proves non-empty
        }
        self.mean_reward = self.win_sum / self.window.len() as f64;
    }

    fn update_discounted(&mut self, r: f64, gamma: f64) {
        self.pulls += 1;
        self.disc_sum = gamma * self.disc_sum + r;
        self.disc_weight = gamma * self.disc_weight + 1.0;
        self.mean_reward = self.disc_sum / self.disc_weight;
    }

    /// Back to optimistic-untried (server rejoined: its history is about
    /// a machine that no longer exists).
    fn reset(&mut self) {
        *self = Arm::default();
    }
}

pub struct CsUcb {
    params: CsUcbParams,
    /// arms[class][server]
    arms: Vec<Vec<Arm>>,
    n_servers: usize,
    /// Global decision counter t.
    t: u64,
    /// Pending violation penalty P(t) per in-flight decision id — realized
    /// at decision time from the constraint filter.
    pending_penalty: PendingPenalties,
    /// Cumulative empirical regret (Eq. 5 with R(S_max) estimated by the
    /// best current arm estimate).
    cum_regret: f64,
    /// Count of decisions forced through the least-violating fallback.
    fallback_decisions: u64,
    /// Count of requests explicitly shed (violation beyond shed_threshold).
    shed_decisions: u64,
    feedbacks: u64,
    /// Arm resets performed on fleet rejoin events.
    arm_resets: u64,
}

impl CsUcb {
    pub fn new(n_servers: usize, params: CsUcbParams) -> Self {
        if let Some(w) = params.window {
            assert!(w >= 1, "sliding window must hold at least one reward");
        }
        if let Some(g) = params.discount {
            assert!(
                g > 0.0 && g < 1.0,
                "discount factor must be in (0, 1), got {g}"
            );
        }
        assert!(
            !(params.window.is_some() && params.discount.is_some()),
            "window and discount are mutually exclusive"
        );
        CsUcb {
            params,
            arms: vec![vec![Arm::default(); n_servers]; ServiceClass::ALL.len()],
            n_servers,
            t: 0,
            pending_penalty: PendingPenalties::default(),
            cum_regret: 0.0,
            fallback_decisions: 0,
            shed_decisions: 0,
            feedbacks: 0,
            arm_resets: 0,
        }
    }

    pub fn with_defaults(n_servers: usize) -> Self {
        Self::new(n_servers, CsUcbParams::default())
    }

    /// SW-UCB variant: sliding-window statistics over the last `window`
    /// rewards per arm, plus arm reset on rejoin — the non-stationary
    /// configuration the chaos scenarios run as `cs-ucb-sw`.
    pub fn windowed(n_servers: usize, window: usize) -> Self {
        Self::new(
            n_servers,
            CsUcbParams {
                window: Some(window),
                reset_on_rejoin: true,
                ..CsUcbParams::default()
            },
        )
    }

    /// D-UCB variant: discounted statistics with factor `gamma`
    /// (effective memory ~1/(1-gamma) pulls), plus arm reset on rejoin —
    /// `cs-ucb-disc` in the chaos scenarios.
    pub fn discounted(n_servers: usize, gamma: f64) -> Self {
        Self::new(
            n_servers,
            CsUcbParams {
                discount: Some(gamma),
                reset_on_rejoin: true,
                ..CsUcbParams::default()
            },
        )
    }

    /// Eq. 4 reward for a realized outcome: negative weighted energy plus
    /// λ times the realized constraint slack (success gives positive slack,
    /// deadline misses drive it negative). Under `params.slo_aware` the
    /// slack is the realized minimum across the SLO vector — a completed
    /// request that blew its TTFT bound is penalized like a late one.
    pub fn reward(params: &CsUcbParams, outcome: &ServiceOutcome) -> f64 {
        let energy_term = outcome.energy_j / ENERGY_SCALE_J;
        let slack = if params.slo_aware {
            outcome.slo_slack()
        } else {
            outcome.slack()
        };
        let fy = slack.clamp(-2.0, 1.0);
        -energy_term + params.lambda * fy
    }

    /// The configured constraint lens (see `CsUcbParams::slo_aware`).
    #[inline]
    fn fy(&self, view: &ClusterView, req: &ServiceRequest, j: usize) -> f64 {
        if self.params.slo_aware {
            view.constraint_satisfaction(req, j)
        } else {
            view.completion_satisfaction(req, j)
        }
    }

    /// Eq. 6 index for arm (class, server).
    fn ucb(&self, class: usize, server: usize, penalty: f64) -> f64 {
        let arm = &self.arms[class][server];
        if arm.pulls == 0 {
            // Untried arms are optimistic: forced exploration.
            return f64::INFINITY;
        }
        // Effective sample count for the exploration bonus: what the
        // estimator actually remembers — window occupancy (SW-UCB),
        // decayed weight (D-UCB), or raw pulls (stationary, the pre-PR6
        // expression bit for bit).
        let eff = match (self.params.window, self.params.discount) {
            (Some(_), _) => arm.window.len() as f64,
            (None, Some(_)) => arm.disc_weight,
            (None, None) => arm.pulls as f64,
        };
        let t = (self.t.max(2)) as f64;
        let bonus = self.params.delta * (t.ln() / eff).sqrt();
        arm.mean_reward + bonus + self.params.theta * penalty
    }

    /// Best current estimated reward across arms of a class (the R(S_max)
    /// estimate used for the empirical Eq.-5 regret).
    fn best_estimate(&self, class: usize) -> f64 {
        self.arms[class]
            .iter()
            .filter(|a| a.pulls > 0)
            .map(|a| a.mean_reward)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Theoretical regret bound of Eq. 7: √(2 M N log L) + θ P(t), where M
    /// is the number of classes, N the number of servers, and L the total
    /// pulls.
    pub fn regret_bound(&self) -> f64 {
        let m = self.arms.len() as f64;
        let n = self.n_servers as f64;
        let l = (self.t.max(2)) as f64;
        (2.0 * m * n * l.ln()).sqrt()
    }

    pub fn cumulative_regret(&self) -> f64 {
        self.cum_regret
    }
}

impl Scheduler for CsUcb {
    fn name(&self) -> &'static str {
        if self.params.affinity > 0.0 {
            "cs-ucb-affinity (PerLLM)"
        } else if self.params.window.is_some() {
            "cs-ucb-sw (PerLLM)"
        } else if self.params.discount.is_some() {
            "cs-ucb-disc (PerLLM)"
        } else if self.params.slo_aware {
            "cs-ucb-slo (PerLLM)"
        } else {
            "cs-ucb (PerLLM)"
        }
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc router hot path; see tests/router_alloc.rs for the runtime twin
        self.t += 1;
        let class = req.class.index();

        // Single fused pass over the scan set: evaluate f(y) once per
        // candidate and keep the best UCB among margin-feasible arms and
        // the best among bare-feasible arms — no per-decision allocation
        // (§Perf: this scan is the router hot path). `view.scan()` is the
        // incremental feasible-set path: on large topologies the view
        // source prunes saturated servers (provably infeasible, zero
        // compute headroom), so this loop stops visiting all N servers
        // exactly when N is large enough for that to matter. The pruned
        // servers can never win here (their f(y) ≤ -1 fails the `fy < 0`
        // gate), so decisions are identical to the full scan; the
        // all-infeasible fallback below rescans everything, saturated
        // servers included, just as the paper's rule requires.
        let margin = self.params.slack_margin;
        let mut best_margin: Option<(usize, f64)> = None;
        let mut best_bare: Option<(usize, f64)> = None;
        for j in view.scan() {
            // Health gate: never *choose* a server the monitor says is
            // dead. `observed_health` is the lagged probe signal, so a
            // just-crashed server still reads 1.0 and can be picked (and
            // paid for) until the lag elapses; without a monitor the
            // field is pinned at 1.0 and this gate never fires —
            // decisions on fault-free runs are exactly pre-PR6. The
            // all-infeasible fallback below deliberately does not gate:
            // any server is a legal fallback target.
            if view.servers[j].observed_health <= 0.0 {
                continue;
            }
            let fy = self.fy(view, req, j);
            if fy < 0.0 {
                continue;
            }
            let v = self.ucb(class, j, 0.0);
            let mut v = if v.is_infinite() {
                // Optimistic untried arm; tie-break by energy then by
                // current load so cold starts do not herd onto one server.
                f64::MAX / 2.0
                    - view.energy_cost(j) * 1.0e6
                    - view.servers[j].predicted_time * 1.0e3
                    - view.servers[j].occupancy * 1.0e3
            } else {
                v
            };
            // Cache-affinity stickiness (PR 10), branch-gated so the
            // `affinity == 0.0` configurations — every pre-sessions
            // scheduler — never touch the new view fields and stay
            // decision-identical bit for bit. The bonus scales with the
            // fraction of this request's prompt already KV-resident on
            // server j and decays with that cache's occupancy (a nearly
            // full cache is about to evict the session anyway).
            if self.params.affinity > 0.0 && view.servers[j].prefix_hit_tokens > 0.0 {
                let frac = view.servers[j].prefix_hit_tokens / (req.prompt_tokens.max(1) as f64);
                v += self.params.affinity * frac * (1.0 - view.servers[j].prefix_pressure).max(0.0);
            }
            if fy >= margin && best_margin.is_none_or(|(_, bv)| v > bv) {
                best_margin = Some((j, v));
            }
            if best_bare.is_none_or(|(_, bv)| v > bv) {
                best_bare = Some((j, v));
            }
        }

        let (choice, penalty) = match best_margin.or(best_bare) {
            Some((j, _)) => (j, 0.0),
            None => {
                // Nothing feasible: full fallback scan over *every* server
                // (saturated ones included — any server is a legal
                // fallback target). First maximum wins on exact f(y) ties,
                // matching the pre-candidate fused loop bit for bit. This
                // scan only runs on fallback decisions, so the feasible
                // hot path above stays sub-linear under pruning.
                let mut best_fy = f64::NEG_INFINITY;
                let mut least_violating = 0usize;
                for j in 0..view.servers.len() {
                    let fy = self.fy(view, req, j);
                    if fy > best_fy {
                        best_fy = fy;
                        least_violating = j;
                    }
                }
                // If even the least-violating placement is beyond the shed
                // threshold the request is hopeless — reject it before any
                // upload energy is spent (first-class load shedding; the
                // engine/router account the drop and still deliver
                // feedback).
                if best_fy < -self.params.shed_threshold {
                    self.shed_decisions += 1;
                    return Action::shed(ShedReason::Infeasible);
                }
                // Constraint-satisfaction fallback: least-violating server;
                // its violation severity becomes the penalty term P(t).
                self.fallback_decisions += 1;
                (least_violating, best_fy.min(0.0)) // lint: allow(nan-cmp) f(y) chains bottom out at -inf, never NaN (PR-5 convention)
            }
        };
        // Only fallback decisions carry a real penalty; feedback() treats
        // absent as 0.0, so skipping the store for the (overwhelmingly
        // common) feasible case keeps decide() write-free.
        if penalty < 0.0 {
            self.pending_penalty.insert(req.id, penalty);
        }
        // lint: end-no-alloc
        Action::assign(choice)
    }

    fn feedback(&mut self, outcome: &ServiceOutcome, _view: &ClusterView) {
        self.feedbacks += 1;
        if outcome.was_shed() {
            // No arm was pulled: nothing to credit or blame. (Clean up any
            // stale pending penalty under this id just in case.)
            self.pending_penalty.remove(outcome.id);
            return;
        }
        let class = outcome.class.index();
        let penalty = self.pending_penalty.remove(outcome.id).unwrap_or(0.0);
        let mut r = Self::reward(&self.params, outcome);
        // Bad super-arm penalty (Eq. 7): violations at decision time cost
        // proportionally to their severity.
        if penalty < 0.0 {
            r += self.params.theta * penalty;
        }
        let arm = &mut self.arms[class][outcome.server];
        match (self.params.window, self.params.discount) {
            (Some(w), _) => arm.update_windowed(r, w),
            (None, Some(g)) => arm.update_discounted(r, g),
            (None, None) => arm.update(r),
        }

        // Empirical approximate regret (Eq. 5).
        let best = self.best_estimate(class);
        if best.is_finite() {
            let gap = self.params.alpha * self.params.beta * best - r;
            if gap > 0.0 {
                self.cum_regret += gap;
            }
        }
    }

    fn fleet_event(&mut self, ev: &FleetEvent, _now: f64) {
        if !self.params.reset_on_rejoin {
            return;
        }
        if let FleetEvent::Up { server } | FleetEvent::Joined { server } = *ev {
            if server < self.n_servers {
                for row in &mut self.arms {
                    row[server].reset();
                }
                self.arm_resets += 1;
            }
        }
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        let explored: u64 = self
            .arms
            .iter()
            .flat_map(|row| row.iter())
            .filter(|a| a.pulls > 0)
            .count() as u64;
        vec![
            ("cum_regret".into(), self.cum_regret),
            ("regret_bound".into(), self.regret_bound()),
            ("fallback_decisions".into(), self.fallback_decisions as f64),
            ("shed_decisions".into(), self.shed_decisions as f64),
            ("explored_arms".into(), explored as f64),
            ("decisions".into(), self.t as f64),
            ("arm_resets".into(), self.arm_resets as f64),
        ]
    }
}

/// CS-UCB over the full **SLO constraint vector** (PR 5): the same
/// Algorithm-1 machinery as [`CsUcb`], but the constraint-satisfaction
/// family is the per-request [`crate::workload::SloSpec`] — interactive
/// requests filter placements by TTFT slack (`ServerView::predicted_ttft`),
/// energy-budgeted requests by predicted price, and rewards carry the
/// realized minimum vector slack ([`ServiceOutcome::slo_slack`]). On
/// completion-only workloads this is decision-identical to [`CsUcb`]; the
/// divergence (and the point) is on heterogeneous contracts, where a
/// token-batch edge tier that prefills quickly wins interactive traffic
/// the completion lens would happily upload to the slow-first-token cloud.
pub struct CsUcbSlo(CsUcb);

impl CsUcbSlo {
    pub fn new(n_servers: usize, params: CsUcbParams) -> Self {
        CsUcbSlo(CsUcb::new(
            n_servers,
            CsUcbParams {
                slo_aware: true,
                ..params
            },
        ))
    }

    pub fn with_defaults(n_servers: usize) -> Self {
        Self::new(n_servers, CsUcbParams::default())
    }

    pub fn cumulative_regret(&self) -> f64 {
        self.0.cumulative_regret()
    }
}

impl Scheduler for CsUcbSlo {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        self.0.decide(req, view)
    }

    fn feedback(&mut self, outcome: &ServiceOutcome, view: &ClusterView) {
        self.0.feedback(outcome, view)
    }

    fn fleet_event(&mut self, ev: &FleetEvent, now: f64) {
        self.0.fleet_event(ev, now)
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        self.0.diagnostics()
    }
}

/// Default stickiness weight for [`CsUcbAffinity`]: a full prefix hit on
/// an unpressured cache is worth one unit of index — the same order as
/// the λ-weighted constraint slack in the reward, so affinity wins close
/// calls without overriding a genuinely better placement.
pub const DEFAULT_AFFINITY: f64 = 1.0;

/// Cache-affinity CS-UCB (PR 10): [`CsUcbSlo`]'s full SLO-vector lens
/// plus a stickiness bonus from [`super::ServerView::prefix_hit_tokens`]
/// — the per-candidate KV-prefix residency the cluster view surfaces for
/// the request's session. A follow-up conversation turn routed back to
/// the server that already holds its KV prefix skips that prefix's
/// prefill (the view's `predicted_time`/`predicted_ttft` already price
/// this), and the explicit bonus keeps the bandit from scattering a
/// session across the fleet during exploration, which is what makes the
/// hit rate — and interactive TTFT attainment — beat `cs-ucb-slo` on
/// chat-heavy mixes. The bonus decays with `prefix_pressure` (eviction
/// risk): residency on a nearly full cache is a promise the server is
/// about to break. On session-free workloads every `prefix_hit_tokens`
/// is 0.0 and decisions are identical to [`CsUcbSlo`].
pub struct CsUcbAffinity(CsUcb);

impl CsUcbAffinity {
    pub fn new(n_servers: usize, params: CsUcbParams) -> Self {
        assert!(
            params.affinity > 0.0,
            "CsUcbAffinity requires a positive affinity weight, got {}",
            params.affinity
        );
        CsUcbAffinity(CsUcb::new(
            n_servers,
            CsUcbParams {
                slo_aware: true,
                ..params
            },
        ))
    }

    pub fn with_defaults(n_servers: usize) -> Self {
        Self::new(
            n_servers,
            CsUcbParams {
                affinity: DEFAULT_AFFINITY,
                ..CsUcbParams::default()
            },
        )
    }

    pub fn cumulative_regret(&self) -> f64 {
        self.0.cumulative_regret()
    }
}

impl Scheduler for CsUcbAffinity {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc affinity decide delegates to the fused CS-UCB scan
        let a = self.0.decide(req, view);
        // lint: end-no-alloc
        a
    }

    fn feedback(&mut self, outcome: &ServiceOutcome, view: &ClusterView) {
        self.0.feedback(outcome, view)
    }

    fn fleet_event(&mut self, ev: &FleetEvent, now: f64) {
        self.0.fleet_event(ev, now)
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        self.0.diagnostics()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{test_req, test_req_slo, test_view};
    use super::*;
    use crate::workload::service::{ServiceClass, SloSpec};

    fn outcome(server: usize, energy: f64, processing: f64, deadline: f64) -> ServiceOutcome {
        ServiceOutcome {
            id: 1,
            class: ServiceClass::Chat,
            server,
            tx_time: 0.1,
            infer_time: processing - 0.1,
            processing_time: processing,
            ttft_time: 0.2,
            slo: SloSpec::completion_only(deadline),
            energy_j: energy,
            tokens: 80,
            completed_at: processing,
        }
    }

    #[test]
    fn picks_only_feasible_servers() {
        let mut s = CsUcb::with_defaults(2);
        let view = test_view(vec![1.0, 5.0]); // server 1 misses 2 s deadline
        let req = test_req(2.0);
        for _ in 0..20 {
            assert_eq!(s.decide(&req, &view), Action::assign(0));
        }
    }

    #[test]
    fn fallback_when_nothing_feasible() {
        let mut s = CsUcb::with_defaults(2);
        let view = test_view(vec![10.0, 6.0]);
        let req = test_req(2.0);
        let d = s.decide(&req, &view);
        assert_eq!(d, Action::assign(1)); // least violating
        assert_eq!(s.fallback_decisions, 1);
        assert_eq!(s.shed_decisions, 0);
    }

    #[test]
    fn sheds_when_violation_beyond_threshold() {
        let mut s = CsUcb::new(
            2,
            CsUcbParams {
                shed_threshold: 2.0,
                ..CsUcbParams::default()
            },
        );
        // Best server predicts 8 s against a 1 s deadline: f(y) = -7,
        // far beyond the threshold of 2 — hopeless, shed it.
        let view = test_view(vec![10.0, 8.0]);
        let req = test_req(1.0);
        let d = s.decide(&req, &view);
        assert_eq!(d, Action::shed(ShedReason::Infeasible));
        assert_eq!(s.shed_decisions, 1);
        assert_eq!(s.fallback_decisions, 0);
        // Shed feedback is consumed without touching any arm.
        let mut o = outcome(0, 0.0, f64::INFINITY, 1.0);
        o.server = ServiceOutcome::SHED_SERVER;
        s.feedback(&o, &view);
        assert!(s.arms.iter().flatten().all(|a| a.pulls == 0));
        // Defaults shed nothing: the pure paper fallback behavior.
        let mut paper = CsUcb::with_defaults(2);
        assert_eq!(paper.decide(&req, &view), Action::assign(1));
        assert_eq!(paper.shed_decisions, 0);
    }

    /// Pruning infeasible servers out of the candidate set must not move
    /// any decision: the fused loop skips f(y) < 0 servers anyway, and the
    /// all-infeasible fallback rescans everything.
    #[test]
    fn candidate_pruning_is_decision_identical() {
        let mut full = CsUcb::with_defaults(3);
        let mut pruned = CsUcb::with_defaults(3);
        let view_full = test_view(vec![1.0, 5.0, 1.2]); // server 1 misses 2 s
        let mut view_pruned = view_full.clone();
        view_pruned.candidates = vec![0, 2];
        let req = test_req(2.0);
        for i in 0..40 {
            let a = full.decide(&req, &view_full);
            let b = pruned.decide(&req, &view_pruned);
            assert_eq!(a, b, "diverged at decision {i}");
            let j = a.server().expect("assigns");
            let mut o = outcome(j, if j == 0 { 80.0 } else { 400.0 }, 1.0, 2.0);
            o.id = req.id;
            full.feedback(&o, &view_full);
            pruned.feedback(&o, &view_pruned);
        }
        // And when *everything* is pruned-or-infeasible the fallback still
        // scans the full view (identical to no pruning).
        let view_full = test_view(vec![10.0, 6.0, 8.0]);
        let mut view_pruned = view_full.clone();
        view_pruned.candidates = vec![2];
        let a = full.decide(&test_req(2.0), &view_full);
        let b = pruned.decide(&test_req(2.0), &view_pruned);
        assert_eq!(a, b);
        assert_eq!(a, Action::assign(1), "least violating of the full set");
    }

    /// The SLO lens diverges from the completion lens exactly where the
    /// issue says it should: a TTFT-bound request avoids the server whose
    /// first token comes too late even though its completion is fastest.
    #[test]
    fn slo_lens_routes_interactive_by_ttft_slack() {
        // Server 1 is completion-fastest but late to its first token (the
        // shared-uplink cloud shape); server 0 completes later but
        // prefills immediately (the edge shape).
        let mut view = test_view(vec![1.6, 1.0]);
        view.servers[0].predicted_ttft = 0.2; // edge: slow total, quick first token
        view.servers[1].predicted_ttft = 0.9; // cloud: quick total, late first token
        let req = test_req_slo(SloSpec::completion_only(4.0).with_ttft(0.4));
        let mut slo = CsUcbSlo::with_defaults(2);
        let mut plain = CsUcb::with_defaults(2);
        // Only server 0 satisfies the vector; both satisfy the scalar, so
        // the completion lens is free to pick either (untried-arm
        // tie-break: lower energy/predicted time — server 1 here).
        for _ in 0..10 {
            assert_eq!(slo.decide(&req, &view), Action::assign(0));
        }
        assert_eq!(plain.decide(&req, &view), Action::assign(1));
    }

    /// On completion-only contracts the two lenses are decision-identical
    /// (the vector degenerates to the scalar).
    #[test]
    fn slo_lens_matches_plain_on_completion_only() {
        let view = test_view(vec![1.0, 5.0, 1.4]);
        let req = test_req(2.0);
        let mut slo = CsUcbSlo::with_defaults(3);
        let mut plain = CsUcb::with_defaults(3);
        for i in 0..60 {
            let a = plain.decide(&req, &view);
            let b = slo.decide(&req, &view);
            assert_eq!(a, b, "diverged at decision {i}");
            let j = a.server().expect("assigns");
            let mut o = outcome(j, if j == 0 { 60.0 } else { 500.0 }, 1.0, 2.0);
            o.id = req.id;
            plain.feedback(&o, &view);
            slo.feedback(&o, &view);
        }
        assert_eq!(slo.name(), "cs-ucb-slo (PerLLM)");
        assert_eq!(plain.name(), "cs-ucb (PerLLM)");
    }

    /// SLO-aware reward penalizes a TTFT miss the completion reward
    /// cannot see.
    #[test]
    fn slo_reward_sees_ttft_misses() {
        let plain = CsUcbParams::default();
        let aware = CsUcbParams {
            slo_aware: true,
            ..plain
        };
        let mut o = outcome(0, 100.0, 1.0, 4.0);
        o.slo = o.slo.with_ttft(0.1);
        o.ttft_time = 0.9; // violated 9x over
        let r_plain = CsUcb::reward(&plain, &o);
        let r_aware = CsUcb::reward(&aware, &o);
        assert!(r_aware < r_plain, "{r_aware} !< {r_plain}");
        // Comfortably met TTFT (slack 0.9 > the 0.75 completion slack):
        // the vector min is bound by completion again and the two rewards
        // agree.
        o.ttft_time = 0.01;
        let met_aware = CsUcb::reward(&aware, &o);
        let met_plain = CsUcb::reward(&plain, &o);
        assert!((met_aware - met_plain).abs() < 1e-12);
    }

    #[test]
    fn reward_prefers_low_energy_success() {
        let p = CsUcbParams::default();
        let good = CsUcb::reward(&p, &outcome(0, 100.0, 1.0, 4.0));
        let pricey = CsUcb::reward(&p, &outcome(0, 2000.0, 1.0, 4.0));
        let late = CsUcb::reward(&p, &outcome(0, 100.0, 6.0, 4.0));
        assert!(good > pricey);
        assert!(good > late);
    }

    #[test]
    fn learns_better_arm() {
        // Two feasible servers; server 0 yields consistently higher reward.
        let mut s = CsUcb::with_defaults(2);
        let view = test_view(vec![1.0, 1.0]);
        let req = test_req(4.0);
        let mut picks0 = 0;
        for i in 0..200 {
            let j = s.decide(&req, &view).server().expect("assigns");
            if j == 0 {
                picks0 += 1;
            }
            let energy = if j == 0 { 50.0 } else { 800.0 };
            let mut o = outcome(j, energy, 1.0, 4.0);
            o.id = i as u64 + 10;
            // decision stored penalty under req.id (7) — emulate engine by
            // reusing the id.
            o.id = req.id;
            s.feedback(&o, &view);
        }
        assert!(picks0 > 150, "picked server0 {picks0}/200");
    }

    #[test]
    fn regret_grows_sublinearly() {
        let mut s = CsUcb::with_defaults(3);
        let view = test_view(vec![1.0, 1.0, 1.0]);
        let req = test_req(4.0);
        let mut checkpoints = Vec::new();
        for i in 1..=400 {
            let j = s.decide(&req, &view).server().expect("assigns");
            let energy = match j {
                0 => 50.0,
                1 => 300.0,
                _ => 600.0,
            };
            let mut o = outcome(j, energy, 1.0, 4.0);
            o.id = req.id;
            s.feedback(&o, &view);
            if i % 100 == 0 {
                checkpoints.push(s.cumulative_regret());
            }
        }
        // Increments shrink: regret in the last 100 < regret in the first 100.
        let first = checkpoints[0];
        let last = checkpoints[3] - checkpoints[2];
        assert!(last < first, "first={first} last={last}");
        // And the empirical regret respects the Eq.-7 bound's shape.
        assert!(s.regret_bound() > 0.0);
    }

    #[test]
    fn untried_arms_get_explored() {
        let mut s = CsUcb::with_defaults(4);
        let view = test_view(vec![1.0, 1.0, 1.0, 1.0]);
        let req = test_req(4.0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let j = s.decide(&req, &view).server().expect("assigns");
            seen.insert(j);
            let mut o = outcome(j, 100.0, 1.0, 4.0);
            o.id = req.id;
            s.feedback(&o, &view);
        }
        assert_eq!(seen.len(), 4, "all arms tried once: {seen:?}");
    }

    #[test]
    fn pending_penalties_dense_and_spill() {
        let mut p = PendingPenalties::default();
        assert_eq!(p.remove(0), None);
        p.insert(3, -0.5);
        p.insert(3, -0.25); // overwrite, like a map
        assert_eq!(p.remove(3), Some(-0.25));
        assert_eq!(p.remove(3), None);
        // Zero is a real stored value, distinct from absent.
        p.insert(7, 0.0);
        assert_eq!(p.remove(7), Some(0.0));
        // Sparse ids beyond the dense cap take the spill path.
        let big = DENSE_ID_LIMIT + 12;
        p.insert(big, -1.0);
        assert_eq!(p.remove(big), Some(-1.0));
        assert_eq!(p.remove(big), None);
    }

    #[test]
    fn diagnostics_present() {
        let s = CsUcb::with_defaults(2);
        let d = s.diagnostics();
        let names: Vec<_> = d.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"cum_regret"));
        assert!(names.contains(&"regret_bound"));
        assert!(names.contains(&"arm_resets"));
    }

    /// After a mid-run reward shift (server 0 turns pricey, server 1
    /// turns cheap), the sliding-window and discounted estimators
    /// migrate to the newly-good server within roughly one memory span,
    /// while the stationary mean — dragged only 1/n per pull across ~100
    /// pre-shift pulls — keeps riding the stale arm for hundreds of
    /// decisions.
    #[test]
    fn nonstationary_variants_adapt_after_reward_shift() {
        let view = test_view(vec![1.0, 1.0]);
        let req = test_req(4.0);
        let feed = |s: &mut dyn Scheduler, j: usize, energy: f64| {
            let mut o = outcome(j, energy, 1.0, 4.0);
            o.id = req.id;
            s.feedback(&o, &view);
        };
        let run = |s: &mut dyn Scheduler| -> usize {
            // Phase 1: both arms well-sampled; server 0 cheap (50 J),
            // server 1 pricey (800 J).
            for _ in 0..100 {
                feed(s, 0, 50.0);
                feed(s, 1, 800.0);
            }
            // Phase 2 (shifted world): server 0 now costs 900 J, server
            // 1 costs 50 J. Burn in 100 decisions...
            for _ in 0..100 {
                let j = s.decide(&req, &view).server().expect("assigns");
                feed(s, j, if j == 0 { 900.0 } else { 50.0 });
            }
            // ...then count picks of the newly-good server over 50 more.
            let mut picks1 = 0;
            for _ in 0..50 {
                let j = s.decide(&req, &view).server().expect("assigns");
                if j == 1 {
                    picks1 += 1;
                }
                feed(s, j, if j == 0 { 900.0 } else { 50.0 });
            }
            picks1
        };
        let mut sw = CsUcb::windowed(2, 20);
        let mut disc = CsUcb::discounted(2, 0.9);
        let mut stationary = CsUcb::with_defaults(2);
        let (sw, disc, stationary) = (run(&mut sw), run(&mut disc), run(&mut stationary));
        assert!(sw >= 40, "sliding window picked new-best only {sw}/50");
        assert!(disc >= 40, "discounted picked new-best only {disc}/50");
        assert!(
            stationary <= 10,
            "stationary mean should still ride the stale arm, picked new-best {stationary}/50"
        );
    }

    /// `fleet_event(Up/Joined)` with `reset_on_rejoin` wipes the
    /// rejoining server's arms across every class (untried → optimistic
    /// re-exploration) and leaves other servers' statistics intact;
    /// without the flag (every pre-PR6 configuration) it is a no-op.
    #[test]
    fn rejoin_resets_arms_only_when_opted_in() {
        let view = test_view(vec![1.0, 1.0]);
        let req = test_req(4.0);
        let mut s = CsUcb::windowed(2, 20);
        for _ in 0..10 {
            let j = s.decide(&req, &view).server().expect("assigns");
            let mut o = outcome(j, 100.0, 1.0, 4.0);
            o.id = req.id;
            s.feedback(&o, &view);
        }
        let chat = ServiceClass::Chat.index();
        assert!(s.arms[chat][0].pulls > 0);
        s.fleet_event(&FleetEvent::Down { server: 0 }, 5.0);
        assert!(s.arms[chat][0].pulls > 0, "down never resets");
        s.fleet_event(&FleetEvent::Up { server: 0 }, 9.0);
        assert!(s.arms.iter().all(|row| row[0].pulls == 0), "rejoin resets");
        assert!(
            s.arms.iter().any(|row| row[1].pulls > 0),
            "other servers keep their statistics"
        );
        assert_eq!(s.arm_resets, 1);
        // Reset arm is optimistic-untried again: explored immediately.
        assert_eq!(s.decide(&req, &view), Action::assign(0));

        let mut plain = CsUcb::with_defaults(2);
        for _ in 0..10 {
            let j = plain.decide(&req, &view).server().expect("assigns");
            let mut o = outcome(j, 100.0, 1.0, 4.0);
            o.id = req.id;
            plain.feedback(&o, &view);
        }
        let pulls_before: Vec<u64> = plain.arms.iter().map(|row| row[0].pulls).collect();
        plain.fleet_event(&FleetEvent::Joined { server: 0 }, 9.0);
        let pulls_after: Vec<u64> = plain.arms.iter().map(|row| row[0].pulls).collect();
        assert_eq!(pulls_before, pulls_after, "stationary default ignores fleet events");
        assert_eq!(plain.arm_resets, 0);
    }

    /// The stickiness bonus breaks exact index ties toward the server
    /// holding the session's KV prefix, and full cache pressure decays
    /// it back to zero.
    #[test]
    fn affinity_routes_follow_up_to_resident_server() {
        let mut view = test_view(vec![1.0, 1.0]);
        let req = test_req(4.0);
        let mut aff = CsUcbAffinity::with_defaults(2);
        let mut slo = CsUcbSlo::with_defaults(2);
        // Warm every arm with identical rewards so the Eq.-6 indices tie
        // exactly; without affinity the first maximum (server 0) wins.
        for s in [&mut aff as &mut dyn Scheduler, &mut slo as &mut dyn Scheduler] {
            for j in 0..2 {
                for _ in 0..5 {
                    let mut o = outcome(j, 100.0, 1.0, 4.0);
                    o.id = req.id;
                    s.feedback(&o, &view);
                }
            }
        }
        view.servers[1].prefix_hit_tokens = 40.0; // 80% of the 50-token prompt
        view.servers[1].prefix_pressure = 0.25;
        assert_eq!(slo.decide(&req, &view), Action::assign(0), "tie falls to the first server");
        assert_eq!(aff.decide(&req, &view), Action::assign(1), "stickiness wins the tie");
        // A cache at full occupancy is about to evict the session: the
        // bonus decays to zero and the tie falls back to server 0.
        view.servers[1].prefix_pressure = 1.0;
        assert_eq!(aff.decide(&req, &view), Action::assign(0));
    }

    /// With no sessions in play (every `prefix_hit_tokens` 0.0) the
    /// affinity variant is decision-identical to `cs-ucb-slo` — the
    /// sessions-off identity the PR-10 tests pin end to end.
    #[test]
    fn affinity_matches_slo_without_sessions() {
        let view = test_view(vec![1.0, 5.0, 1.4]);
        let req = test_req(2.0);
        let mut aff = CsUcbAffinity::with_defaults(3);
        let mut slo = CsUcbSlo::with_defaults(3);
        for i in 0..60 {
            let a = slo.decide(&req, &view);
            let b = aff.decide(&req, &view);
            assert_eq!(a, b, "diverged at decision {i}");
            let j = a.server().expect("assigns");
            let mut o = outcome(j, if j == 0 { 60.0 } else { 500.0 }, 1.0, 2.0);
            o.id = req.id;
            slo.feedback(&o, &view);
            aff.feedback(&o, &view);
        }
        assert_eq!(aff.name(), "cs-ucb-affinity (PerLLM)");
        assert_eq!(slo.name(), "cs-ucb-slo (PerLLM)");
    }

    /// The health gate: a server the (lagged) monitor reports dead is
    /// never *chosen*, even if its predictions look feasible; at the
    /// default `observed_health = 1.0` the gate never fires.
    #[test]
    fn observed_dead_server_is_not_chosen() {
        let mut view = test_view(vec![1.0, 1.2]);
        view.servers[0].observed_health = 0.0;
        let req = test_req(4.0);
        let mut s = CsUcb::with_defaults(2);
        for _ in 0..10 {
            assert_eq!(s.decide(&req, &view), Action::assign(1));
        }
        // Back to healthy: server 0 is optimistic-untried and wins.
        view.servers[0].observed_health = 1.0;
        assert_eq!(s.decide(&req, &view), Action::assign(0));
    }
}
