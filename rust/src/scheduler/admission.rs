//! Front-door admission control: a per-class token bucket that converts
//! *predicted* SLO violation into a first-class [`Action::Shed`] before
//! the wrapped policy spends an arm pull.
//!
//! The ROADMAP's "exploit `Shed` upstream" direction: the scheduling API
//! made shedding first-class (PR 2) and the SLO vector made violation
//! predictable per constraint family (PR 5) — this gate sits in front of
//! any [`Scheduler`] and rejects requests that are hopeless *everywhere*,
//! at a bounded per-class rate. The bucket is the safety valve: a few
//! predicted-violating requests per second are still admitted (they feed
//! the bandit's penalty/fallback machinery and keep its estimates honest
//! under recoverable congestion), but a flash crowd that would drown the
//! cluster in guaranteed deadline misses is clipped at the door, before
//! any upload energy or link share is spent and before the bandit's
//! decision state is churned by unwinnable placements.
//!
//! Wiring: the gate *is* a `Scheduler`, so both substrates take it
//! unchanged — the DES engine counts its sheds into
//! `RunReport::dropped_by_policy` and surfaces the gate's own counter as
//! `RunReport::gate_sheds`; the live `Router` counts them into
//! `router_sheds` and forwards the diagnostics. Feedback for gated
//! requests flows through to the inner policy as a shed outcome
//! ([`crate::workload::ServiceOutcome::was_shed`]), which every policy
//! already handles (no arm was pulled).

use super::{Action, ClusterView, FleetEvent, Scheduler, ShedReason};
use crate::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest};

/// Gate tuning.
#[derive(Debug, Clone, Copy)]
pub struct GateParams {
    /// Token refill rate per class, tokens per simulated second: the
    /// sustained rate of predicted-violating requests still admitted (to
    /// keep probing for recovery).
    pub refill_per_s: f64,
    /// Bucket capacity per class: the burst of predicted-violating
    /// requests tolerated before the gate starts shedding.
    pub burst: f64,
    /// Feasibility threshold: a request passes freely when some placement
    /// has f(y) >= margin (SLO-vector satisfaction). Must be >= 0 — the
    /// gate's scan prunes provably-infeasible servers, which is only
    /// sound for non-negative margins.
    pub margin: f64,
    /// Scale the refill rate by the fleet's mean *observed* health
    /// (PR 6, opt-in): during an incident the probing budget shrinks
    /// with the capacity the health monitor believes is left, so the
    /// gate sheds harder instead of admitting its full rate of
    /// hopeless work into a half-dead fleet. With no monitor installed
    /// every `observed_health` is 1.0 and the scale is exactly 1 — the
    /// pre-PR6 refill, bit for bit.
    pub adaptive: bool,
}

impl Default for GateParams {
    fn default() -> Self {
        GateParams {
            refill_per_s: 2.0,
            burst: 8.0,
            margin: 0.0,
            adaptive: false,
        }
    }
}

/// Per-class token-bucket admission gate around an inner [`Scheduler`].
pub struct TokenBucketGate {
    inner: Box<dyn Scheduler>,
    params: GateParams,
    /// Current tokens per class (starts full).
    tokens: [f64; ServiceClass::ALL.len()],
    /// Clock of the last refill (view observation time).
    last_refill: f64,
    /// Requests rejected at the door, total and per class.
    gate_sheds: u64,
    gate_sheds_by_class: [u64; ServiceClass::ALL.len()],
    /// Predicted-violating requests admitted on a token (the bucket's
    /// probing budget at work).
    token_admissions: u64,
}

impl TokenBucketGate {
    pub fn new(inner: Box<dyn Scheduler>, params: GateParams) -> Self {
        assert!(
            params.margin >= 0.0,
            "gate margin must be non-negative (candidate pruning soundness)"
        );
        TokenBucketGate {
            inner,
            tokens: [params.burst; ServiceClass::ALL.len()],
            last_refill: 0.0,
            gate_sheds: 0,
            gate_sheds_by_class: [0; ServiceClass::ALL.len()],
            token_admissions: 0,
            params,
        }
    }

    pub fn with_defaults(inner: Box<dyn Scheduler>) -> Self {
        Self::new(inner, GateParams::default())
    }

    pub fn gate_sheds(&self) -> u64 {
        self.gate_sheds
    }

    /// Refill every bucket for the time elapsed since the last decision.
    /// Sources whose views carry no clock (the live router defaults to a
    /// frozen `now`) simply get no refill beyond the initial burst unless
    /// the owner advances the router clock (`Router::set_now`). Under
    /// `params.adaptive` the rate is scaled by the mean observed health
    /// across the view — the lagged probe signal, so the gate tightens
    /// only once the monitor has *seen* the incident, and loosens again
    /// only once it has seen the recovery.
    fn refill(&mut self, now: f64, view: &ClusterView) {
        let dt = now - self.last_refill;
        if dt > 0.0 {
            let rate = if self.params.adaptive && !view.servers.is_empty() {
                let h: f64 = view.servers.iter().map(|s| s.observed_health).sum();
                self.params.refill_per_s * (h / view.servers.len() as f64).clamp(0.0, 1.0)
            } else {
                self.params.refill_per_s
            };
            for t in &mut self.tokens {
                *t = (*t + dt * rate).min(self.params.burst);
            }
            self.last_refill = now;
        }
    }
}

impl Scheduler for TokenBucketGate {
    /// Transparent: report rows stay labeled by the wrapped policy; the
    /// gate's presence shows up in the `gate_*` diagnostics.
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc the gate fronts the router hot path on every arrival
        self.refill(view.now, view);
        // Best SLO-vector satisfaction over the candidate scan. Pruned
        // servers are provably infeasible (f(y) <= -1), so for the
        // non-negative margin this max is decision-identical to a full
        // scan — the gate never misses a feasible placement.
        let best_fy = view
            .scan()
            .map(|j| view.constraint_satisfaction(req, j))
            // lint: allow(nan-cmp) f(y) chains bottom out at -inf, never NaN (PR-5 convention)
            .fold(f64::NEG_INFINITY, f64::max);
        if best_fy >= self.params.margin {
            return self.inner.decide(req, view);
        }
        // Every placement is predicted to violate the request's SLO
        // vector: admit on a token (bounded probing) or shed at the door.
        let class = req.class.index();
        if self.tokens[class] >= 1.0 {
            self.tokens[class] -= 1.0;
            self.token_admissions += 1;
            return self.inner.decide(req, view);
        }
        self.gate_sheds += 1;
        self.gate_sheds_by_class[class] += 1;
        // lint: end-no-alloc
        Action::shed(ShedReason::Overloaded)
    }

    fn feedback(&mut self, outcome: &ServiceOutcome, view: &ClusterView) {
        // Gated requests come back as shed outcomes; the inner policy
        // already treats those as "no arm pulled".
        self.inner.feedback(outcome, view);
    }

    fn fleet_event(&mut self, ev: &FleetEvent, now: f64) {
        // The gate itself keys off observed health in the view; fleet
        // transitions are the inner policy's business (arm resets).
        self.inner.fleet_event(ev, now);
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        let mut d = self.inner.diagnostics();
        d.push(("gate_sheds".into(), self.gate_sheds as f64));
        d.push(("gate_token_admissions".into(), self.token_admissions as f64));
        for c in ServiceClass::ALL {
            d.push((
                format!("gate_sheds_{}", c.name()),
                self.gate_sheds_by_class[c.index()] as f64,
            ));
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{test_req, test_view};
    use super::*;
    use crate::scheduler::csucb::CsUcb;

    fn gated(n: usize, params: GateParams) -> TokenBucketGate {
        TokenBucketGate::new(Box::new(CsUcb::with_defaults(n)), params)
    }

    #[test]
    fn feasible_requests_pass_untouched() {
        let mut g = gated(2, GateParams::default());
        let view = test_view(vec![1.0, 1.5]);
        let req = test_req(4.0);
        for _ in 0..50 {
            assert!(!g.decide(&req, &view).is_shed());
        }
        assert_eq!(g.gate_sheds(), 0);
        assert_eq!(g.token_admissions, 0, "no tokens spent on feasible work");
    }

    #[test]
    fn hopeless_requests_drain_the_bucket_then_shed() {
        let params = GateParams {
            refill_per_s: 1.0,
            burst: 3.0,
            margin: 0.0,
            adaptive: false,
        };
        let mut g = gated(2, params);
        let view = test_view(vec![10.0, 8.0]); // both far past the deadline
        let req = test_req(1.0);
        // First `burst` hopeless requests are admitted on tokens (the
        // inner policy falls back least-violating), then the door closes.
        for i in 0..3 {
            assert!(!g.decide(&req, &view).is_shed(), "burst admission {i}");
        }
        for _ in 0..5 {
            assert_eq!(
                g.decide(&req, &view),
                Action::shed(ShedReason::Overloaded)
            );
        }
        assert_eq!(g.gate_sheds(), 5);
        assert_eq!(g.token_admissions, 3);
        let d = g.diagnostics();
        assert!(d.iter().any(|(k, v)| k == "gate_sheds" && *v == 5.0));
        assert!(d.iter().any(|(k, v)| k == "gate_sheds_chat" && *v == 5.0));
    }

    #[test]
    fn tokens_refill_with_view_time() {
        let params = GateParams {
            refill_per_s: 2.0,
            burst: 1.0,
            margin: 0.0,
            adaptive: false,
        };
        let mut g = gated(1, params);
        let mut view = test_view(vec![10.0]);
        let req = test_req(1.0);
        assert!(!g.decide(&req, &view).is_shed(), "initial token");
        assert!(g.decide(&req, &view).is_shed(), "bucket empty");
        // Half a second at 2 tokens/s refills one token.
        view.now = 0.5;
        assert!(!g.decide(&req, &view).is_shed(), "refilled");
        assert!(g.decide(&req, &view).is_shed());
    }

    #[test]
    fn buckets_are_per_class() {
        let params = GateParams {
            refill_per_s: 0.0,
            burst: 1.0,
            margin: 0.0,
            adaptive: false,
        };
        let mut g = gated(1, params);
        let view = test_view(vec![10.0]);
        let chat = test_req(1.0); // test_req builds a Chat request
        let mut code = test_req(1.0);
        code.class = ServiceClass::Code;
        assert!(!g.decide(&chat, &view).is_shed());
        assert!(g.decide(&chat, &view).is_shed(), "chat bucket drained");
        assert!(!g.decide(&code, &view).is_shed(), "code bucket untouched");
        assert!(g.decide(&code, &view).is_shed());
        assert_eq!(g.gate_sheds_by_class[ServiceClass::Chat.index()], 1);
        assert_eq!(g.gate_sheds_by_class[ServiceClass::Code.index()], 1);
    }

    /// Under `adaptive`, refill is scaled by mean observed health: an
    /// observed-dead fleet earns no probing tokens, and refill resumes
    /// at the normal rate once the (lagged) probes report recovery.
    #[test]
    fn adaptive_refill_tracks_observed_health() {
        let params = GateParams {
            refill_per_s: 2.0,
            burst: 1.0,
            margin: 0.0,
            adaptive: true,
        };
        let mut g = gated(1, params);
        let mut view = test_view(vec![10.0]); // hopeless placement
        let req = test_req(1.0);
        assert!(!g.decide(&req, &view).is_shed(), "initial burst token");
        assert!(g.decide(&req, &view).is_shed(), "bucket empty");
        // Fleet observed dead: half a second earns 0.5 s * 2/s * 0 = 0
        // tokens — the gate stays shut.
        view.servers[0].observed_health = 0.0;
        view.now = 0.5;
        assert!(g.decide(&req, &view).is_shed(), "no refill while observed dead");
        // Probes report recovery: the next half second refills at the
        // full rate (one token).
        view.servers[0].observed_health = 1.0;
        view.now = 1.0;
        assert!(!g.decide(&req, &view).is_shed(), "refill resumes on recovery");
        assert!(g.decide(&req, &view).is_shed());
    }

    /// Fleet events must reach the wrapped policy: a windowed CS-UCB
    /// behind the gate still resets its arms on rejoin.
    #[test]
    fn fleet_events_forward_to_inner_policy() {
        let mut g = TokenBucketGate::with_defaults(Box::new(CsUcb::windowed(2, 8)));
        g.fleet_event(&FleetEvent::Up { server: 0 }, 1.0);
        let resets: f64 = g
            .diagnostics()
            .iter()
            .find(|(k, _)| k == "arm_resets")
            .map(|(_, v)| *v)
            .expect("inner cs-ucb-sw diagnostics present");
        assert_eq!(resets, 1.0, "Up event must reach the wrapped bandit");
    }

    /// A gate shed happens BEFORE the inner policy sees the request: the
    /// bandit's decision counter must not move, and the shed feedback is
    /// consumed without touching any arm.
    #[test]
    fn gate_sheds_spend_no_arm_pull() {
        let params = GateParams {
            refill_per_s: 0.0,
            burst: 0.0,
            margin: 0.0,
            adaptive: false,
        };
        let mut g = gated(2, params);
        let view = test_view(vec![10.0, 8.0]);
        let req = test_req(1.0);
        assert!(g.decide(&req, &view).is_shed());
        let inner_decisions: f64 = g
            .diagnostics()
            .iter()
            .find(|(k, _)| k == "decisions")
            .map(|(_, v)| *v)
            .expect("inner cs-ucb diagnostics present");
        assert_eq!(inner_decisions, 0.0, "inner policy must not be consulted");
        let o = ServiceOutcome::shed(&req, 0.0);
        g.feedback(&o, &view); // must not panic / touch arms
    }
}
