//! AGOD baseline (Du et al., IEEE TMC'24): an **edge-only** offloading
//! scheme combining a diffusion-model decision generator with deep
//! reinforcement learning.
//!
//! Substitution (DESIGN.md §2): the published AGOD samples offloading
//! decisions by iteratively denoising from noise, guided by a learned
//! critic. We reproduce that decision *process* with a tabular critic
//! Q[class][edge] and an iterative perturb-and-refine sampler: start from a
//! uniformly random assignment ("pure noise") and, over K denoising steps,
//! move toward the critic's argmax with temperature decaying per step.
//! What the paper's evaluation exercises — edge-only placement learned from
//! reward — is preserved; the diffusion parameterization itself is not
//! load-bearing for Table 1 / Figs. 4-6.

use super::{Action, ClusterView, Scheduler};
use crate::sim::server::ServerKind;
use crate::util::rng::Rng;
use crate::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest};

pub struct Agod {
    /// Q[class][server], only edge entries used.
    q: Vec<Vec<f64>>,
    counts: Vec<Vec<u64>>,
    rng: Rng,
    /// Denoising steps K.
    pub steps: usize,
    /// Learning rate for the critic update.
    pub lr: f64,
    decisions: u64,
    /// Scratch edge-index buffer, refilled per decision so the hot path
    /// performs no per-decision allocation.
    edge_buf: Vec<usize>,
}

impl Agod {
    pub fn new(n_servers: usize, seed: u64) -> Self {
        Agod {
            q: vec![vec![0.0; n_servers]; ServiceClass::ALL.len()],
            counts: vec![vec![0; n_servers]; ServiceClass::ALL.len()],
            rng: Rng::new(seed), // lint: allow(raw-seed) scheduler-local decision stream; the caller supplies a pre-salted seed
            steps: 6,
            lr: 0.15,
            decisions: 0,
            edge_buf: Vec::with_capacity(n_servers),
        }
    }
}

impl Scheduler for Agod {
    fn name(&self) -> &'static str {
        "agod (edge-only)"
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc baseline decide shares the router hot path; edge_buf is reused
        self.decisions += 1;
        self.edge_buf.clear();
        self.edge_buf
            .extend((0..view.servers.len()).filter(|&j| view.servers[j].kind == ServerKind::Edge));
        assert!(!self.edge_buf.is_empty(), "AGOD needs edge servers");
        let class = req.class.index();

        // Denoising chain: start from noise, anneal toward the critic's
        // preference blended with the instantaneous load signal.
        let mut current = *self.rng.choose(&self.edge_buf);
        for k in 0..self.steps {
            // Temperature decays 1 -> 0 over the chain.
            let temp = 1.0 - (k as f64 + 1.0) / self.steps as f64;
            if self.rng.chance(temp * 0.6) {
                // Noise step: jump to a random edge.
                current = *self.rng.choose(&self.edge_buf);
            } else {
                // Guidance step: move to the best edge under critic +
                // load-balancing tiebreak.
                current = self
                    .edge_buf
                    .iter()
                    .copied()
                    .max_by(|&a, &b| {
                        let va = self.q[class][a] - 0.01 * view.servers[a].n_waiting as f64;
                        let vb = self.q[class][b] - 0.01 * view.servers[b].n_waiting as f64;
                        // lint: allow(p1, n1) q-values and waiting counts are finite by construction
                        va.partial_cmp(&vb).unwrap()
                    })
                    .unwrap_or(current);
            }
        }
        // lint: end-no-alloc
        Action::assign(current)
    }

    fn feedback(&mut self, outcome: &ServiceOutcome, _view: &ClusterView) {
        if outcome.was_shed() {
            // No placement happened; nothing for the critic to learn from.
            return;
        }
        let class = outcome.class.index();
        let j = outcome.server;
        // Same Eq.-4-shaped reward as CS-UCB (fair comparison).
        let r = -outcome.energy_j / 1000.0 + 0.5 * outcome.slack().clamp(-2.0, 1.0);
        self.counts[class][j] += 1;
        let q = &mut self.q[class][j];
        *q += self.lr * (r - *q);
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        vec![("decisions".into(), self.decisions as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{test_req, test_view};
    use super::*;

    #[test]
    fn never_picks_cloud() {
        // test_view marks server 0 as cloud.
        let mut s = Agod::new(3, 1);
        let view = test_view(vec![1.0, 1.0, 1.0]);
        for _ in 0..100 {
            let j = s.decide(&test_req(3.0), &view).server().expect("assigns");
            assert_ne!(j, 0, "picked the cloud");
        }
    }

    #[test]
    fn learns_toward_high_reward_edge() {
        let mut s = Agod::new(3, 2);
        let view = test_view(vec![1.0, 1.0, 1.0]); // 0=cloud, 1/2=edge
        let req = test_req(4.0);
        for _ in 0..300 {
            let j = s.decide(&req, &view).server().expect("assigns");
            let energy = if j == 1 { 50.0 } else { 900.0 };
            let o = ServiceOutcome {
                id: 1,
                class: req.class,
                server: j,
                tx_time: 0.05,
                infer_time: 0.95,
                processing_time: 1.0,
                ttft_time: 0.1,
                slo: crate::workload::service::SloSpec::completion_only(4.0),
                energy_j: energy,
                tokens: 80,
                completed_at: 1.0,
            };
            s.feedback(&o, &view);
        }
        // After training, the critic must prefer edge 1.
        let mut picks1 = 0;
        for _ in 0..100 {
            if s.decide(&req, &view) == Action::assign(1) {
                picks1 += 1;
            }
        }
        assert!(picks1 > 60, "picks1={picks1}");
    }
}
