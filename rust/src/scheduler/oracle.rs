//! Clairvoyant oracle scheduler: lower-bound reference for regret and
//! ablation studies (not part of the paper's baseline set).
//!
//! Uses the cluster's own predictor directly: among deadline-feasible
//! servers pick the minimum estimated energy; otherwise the fastest. Since
//! the DES predictor is well-calibrated this is near-optimal per decision,
//! which is exactly what a regret denominator needs.

use super::{Action, ClusterView, Scheduler};
use crate::workload::service::ServiceRequest;

#[derive(Default)]
pub struct Oracle {
    decisions: u64,
    /// Scratch feasible-index buffer (no per-decision allocation).
    feasible: Vec<usize>,
}

impl Oracle {
    pub fn new() -> Self {
        Oracle::default()
    }
}

impl Scheduler for Oracle {
    fn name(&self) -> &'static str {
        "oracle (clairvoyant)"
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc baseline decide shares the router hot path
        self.decisions += 1;
        view.feasible_servers_into(req, &mut self.feasible);
        let j = if self.feasible.is_empty() {
            view.least_violating(req)
        } else {
            self.feasible
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    view.energy_cost(a)
                        .partial_cmp(&view.energy_cost(b))
                        // lint: allow(p1, n1) energy_cost is a finite sum of finite estimates
                        .unwrap()
                })
                // lint: allow(p1) the is_empty branch above proves the set non-empty
                .unwrap()
        };
        // lint: end-no-alloc
        Action::assign(j)
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        vec![("decisions".into(), self.decisions as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{test_req, test_view};
    use super::*;

    #[test]
    fn picks_cheapest_feasible() {
        let mut s = Oracle::new();
        let mut view = test_view(vec![1.0, 1.0]);
        view.servers[0].infer_energy_est = 50.0;
        view.servers[1].infer_energy_est = 5.0;
        assert_eq!(s.decide(&test_req(3.0), &view), Action::assign(1));
    }

    #[test]
    fn falls_back_to_fastest_when_infeasible() {
        let mut s = Oracle::new();
        let view = test_view(vec![9.0, 7.0]);
        assert_eq!(s.decide(&test_req(2.0), &view), Action::assign(1));
    }
}
