//! RewardlessGuidance baseline (Fang et al., IEEE VTC'23): edge-cloud
//! offloading by **active inference** — decisions minimize expected free
//! energy (risk + ambiguity) computed from the current state, *without*
//! a reward feedback loop (hence "rewardless").
//!
//! Risk: how badly the predicted processing time threatens the deadline,
//! plus the normalized energy estimate. Ambiguity: epistemic preference
//! for less-visited (class, server) pairs, decaying with visits. The
//! method is edge-cloud aware but cannot consolidate experience into
//! reward estimates, which is exactly the scheduling-quality gap the
//! paper's evaluation shows against CS-UCB.

use super::{Action, ClusterView, Scheduler};
use crate::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest};

pub struct RewardlessGuidance {
    /// Visit counts per (class, server) — the only state it keeps.
    visits: Vec<Vec<u64>>,
    /// Ambiguity weight.
    pub kappa: f64,
    /// Energy weight in the risk term.
    pub rho: f64,
    decisions: u64,
}

impl RewardlessGuidance {
    pub fn new(n_servers: usize) -> Self {
        RewardlessGuidance {
            visits: vec![vec![0; n_servers]; ServiceClass::ALL.len()],
            kappa: 0.4,
            rho: 0.9,
            decisions: 0,
        }
    }

    /// Expected free energy of assigning `req` to server `j` (lower =
    /// better).
    fn efe(&self, req: &ServiceRequest, view: &ClusterView, j: usize) -> f64 {
        let sv = &view.servers[j];
        // Risk from nominal expectations stretched by raw occupancy: active
        // inference sees the current state s (the paper defines the state
        // as each server's live compute/bandwidth), but has no calibrated
        // queueing model and no reward learning — the adaptability gap the
        // paper's evaluation exposes.
        // A request with no completion bound divides by +inf — zero
        // pressure, which is exactly what "no completion constraint"
        // means to a risk term.
        let deadline = req.slo.completion.unwrap_or(f64::INFINITY);
        let pressure = sv.solo_time_est * (1.0 + 0.8 * sv.occupancy) / deadline;
        // No constraint filter and no superlinear deadline aversion — a
        // preference prior trades time against energy linearly, which is
        // where it gives ground to CS-UCB's C1-C3 mechanism.
        let risk = pressure + self.rho * view.energy_cost(j) / 1000.0;
        // Ambiguity: uncertainty about rarely-visited pairs *reduces* free
        // energy (exploration drive) — active inference agents seek
        // information.
        let v = self.visits[req.class.index()][j] as f64;
        let ambiguity = -self.kappa / (1.0 + v).sqrt();
        risk + ambiguity
    }
}

impl Scheduler for RewardlessGuidance {
    fn name(&self) -> &'static str {
        "rewardless (edge-cloud)"
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc baseline decide shares the router hot path
        self.decisions += 1;
        let j = (0..view.servers.len())
            .min_by(|&a, &b| {
                self.efe(req, view, a)
                    .partial_cmp(&self.efe(req, view, b))
                    // lint: allow(p1, n1) efe() is built from finite loads and clamped logs
                    .unwrap()
            })
            // lint: allow(p1) every cluster constructor requires n_servers > 0
            .expect("non-empty cluster");
        self.visits[req.class.index()][j] += 1;
        // lint: end-no-alloc
        Action::assign(j)
    }

    fn feedback(&mut self, _outcome: &ServiceOutcome, _view: &ClusterView) {
        // Rewardless: outcomes are not consumed. (That's the point.)
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        vec![("decisions".into(), self.decisions as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{test_req, test_view};
    use super::*;

    #[test]
    fn prefers_faster_server_under_pressure() {
        let mut s = RewardlessGuidance::new(2);
        // Server 1 would miss the deadline.
        let view = test_view(vec![1.0, 5.0]);
        let req = test_req(2.0);
        // Warm the visit counts symmetrically so ambiguity doesn't dominate.
        s.visits = vec![vec![10, 10]; 4];
        assert_eq!(s.decide(&req, &view), Action::assign(0));
    }

    #[test]
    fn explores_unvisited_servers_initially() {
        let mut s = RewardlessGuidance::new(3);
        let view = test_view(vec![1.0, 1.0, 1.0]);
        let req = test_req(4.0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            seen.insert(s.decide(&req, &view).server().expect("assigns"));
        }
        assert!(seen.len() >= 2, "no exploration: {seen:?}");
    }

    #[test]
    fn uses_both_tiers() {
        let mut s = RewardlessGuidance::new(3);
        // 0=cloud fast, 1,2=edge fast for some, slow for others — vary the
        // view across calls.
        let mut picked_cloud = false;
        let mut picked_edge = false;
        for i in 0..40 {
            let view = if i % 2 == 0 {
                test_view(vec![0.5, 3.0, 3.0])
            } else {
                test_view(vec![3.0, 0.5, 0.5])
            };
            let j = s.decide(&test_req(2.0), &view).server().expect("assigns");
            if j == 0 {
                picked_cloud = true;
            } else {
                picked_edge = true;
            }
        }
        assert!(picked_cloud && picked_edge);
    }
}
