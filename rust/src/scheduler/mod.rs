//! Scheduling layer: the paper's contribution (CS-UCB) plus the three
//! published baselines and a clairvoyant oracle, behind one
//! **action-based API shared by both substrates** (the DES engine and the
//! live coordinator router).
//!
//! The API is built from three abstractions:
//!
//! * [`Action`] — what a policy may do with one request: `Assign` it to a
//!   server now, `Defer` it (deferred batching), or `Shed` it outright.
//!   Shedding is first-class: a policy that knows every placement is
//!   hopeless can reject the work before any upload energy is spent,
//!   and the engine/router account the drop and still deliver bandit
//!   feedback for it.
//! * [`ViewSource`] — anything that can fill a caller-owned
//!   [`ClusterView`] snapshot in place (`view_into`). Both the DES
//!   cluster (`sim::cluster::ClusterSim`) and the live router
//!   (`coordinator::router::Router`) implement it, so the decision path
//!   is allocation-free end to end on either substrate: one scratch view
//!   refilled per decision, `_into` feasibility helpers writing into
//!   reusable index buffers.
//! * [`crate::workload::ArrivalSource`] — a pull-based workload cursor.
//!   The engine prefetches exactly one pending arrival instead of
//!   pre-pushing the whole trace, which caps the event-heap size on
//!   million-request runs.
//!
//! Every scheduler sees the *same* cluster view (same predictors, same
//! resource snapshots) — differences in the results come from decision
//! logic, not from information asymmetry.
//!
//! Constraints are **SLO vectors** (PR 5): each request carries a
//! [`crate::workload::SloSpec`] — optional TTFT, completion, and
//! energy-budget bounds — and [`ClusterView::constraint_satisfaction`]
//! takes the minimum normalized slack across the *present* constraints
//! (TTFT judged against `ServerView::predicted_ttft`). Schedulers that
//! want the paper's scalar behavior opt into the
//! [`ClusterView::completion_satisfaction`] lens instead, which reads
//! only `SloSpec::completion` — that is how `CsUcb::with_defaults` stays
//! paper-identical while `CsUcbSlo` and the admission gate consume the
//! full vector (migration guide: ROADMAP.md "SLO contracts").
//!
//! Porting a scheduler to this API: implement
//! `fn decide(&mut self, req, view) -> Action`; return
//! `Action::assign(j)` for immediate dispatch, `Action::defer(j, s)` to
//! hold for `s` seconds, `Action::shed(reason)` to reject. Keep any index
//! buffers you need as struct fields and fill them with the `_into`
//! helpers ([`ClusterView::feasible_servers_into`] /
//! [`ClusterView::feasible_servers_with_slack_into`]) so `decide` never
//! allocates. Shed requests come back through `feedback` with
//! [`ServiceOutcome::was_shed`] set — skip arm updates for those (no arm
//! was pulled) but do count them.

pub mod admission;
pub mod agod;
pub mod csucb;
pub mod fineinfer;
pub mod oracle;
pub mod rewardless;

use crate::sim::energy::EnergyWeights;
use crate::sim::server::ServerKind;
use crate::workload::service::{ServiceOutcome, ServiceRequest, SloSpec};

/// Per-candidate-server snapshot handed to the scheduler for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerView {
    pub kind: ServerKind,
    /// Predicted end-to-end processing time if this request is assigned
    /// here *now* (upload fair-share + queue wait + stretched service).
    pub predicted_time: f64,
    /// Predicted time to *first token* for this assignment (upload +
    /// queue wait + stretched prefill), from the server's service model —
    /// the honest TTFT estimate batching-aware models expose
    /// (`sim::service_model::ServicePrediction`). Always
    /// `<= predicted_time`; TTFT-SLO policies read this, deadline
    /// policies keep using `predicted_time`.
    pub predicted_ttft: f64,
    /// Remaining compute units (paper C2 headroom).
    pub compute_headroom: f64,
    /// Compute units this request would consume (paper C_i).
    pub compute_demand: f64,
    /// Available uplink bandwidth for a new flow, bits/s (paper C3 headroom).
    pub bandwidth_headroom: f64,
    /// Bandwidth the request's upload needs to meet its share, bits/s.
    pub bandwidth_demand: f64,
    /// Estimated transmission energy for this request, J.
    pub tx_energy_est: f64,
    /// Estimated marginal inference energy for this request, J.
    pub infer_energy_est: f64,
    /// Batch occupancy right now.
    pub n_active: usize,
    pub n_waiting: usize,
    /// Load-independent estimate: solo transmission + solo service time.
    /// Methods without a calibrated queueing model (RewardlessGuidance)
    /// combine this with `occupancy` instead of `predicted_time`.
    pub solo_time_est: f64,
    /// Fraction of the server's slots + bounded queue currently occupied.
    pub occupancy: f64,
    /// *Observed* health signal in [0, 1]: the server's service-rate
    /// multiplier as seen through the lagged health-probe pipeline
    /// (`sim::faults::HealthMonitor`), NOT ground truth — a just-crashed
    /// server still reads 1.0 until the probe lag elapses, so schedulers
    /// can route to it and pay for it. Pinned at 1.0 when no monitor is
    /// installed (every pre-fault run).
    pub observed_health: f64,
    /// KV-prefix residency signal (PR 10): how many of this request's
    /// conversation-prefix tokens are resident in the server's prefix
    /// cache right now (0.0 for single-shot requests and cold servers).
    /// `predicted_time`/`predicted_ttft` already price the reuse; this
    /// field lets affinity-aware policies weigh stickiness explicitly.
    pub prefix_hit_tokens: f64,
    /// Prefix-cache occupancy in [0, 1] — the eviction-risk proxy: a
    /// nearly full cache is likely to evict this session soon, so the
    /// stickiness bonus should decay with it.
    pub prefix_pressure: f64,
}

/// Cluster snapshot at decision time (the CMAB state space s of §3.2).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterView {
    pub now: f64,
    /// View epoch: a monotone snapshot version stamped by the
    /// [`ViewSource`] on every fill. Two fills with the same epoch are
    /// the same snapshot; a larger epoch is a strictly later one. The
    /// sharded engine's merge barrier (sim/shard.rs) relies on this
    /// contract: every decision/feedback observes a fully merged,
    /// epoch-stamped snapshot, never a torn mix of shard states.
    /// Schedulers may read it for staleness bookkeeping but must not
    /// assume consecutive integers.
    pub epoch: u64,
    pub servers: Vec<ServerView>,
    pub weights: EnergyWeights,
    /// Incremental feasible-set hint: the indices of servers that can
    /// still *admit* a request (slot or queue space), maintained O(1) per
    /// occupancy change by the view source (see
    /// `sim::cluster::ClusterSim::refresh_admissibility`). Empty means
    /// "no pruning information — scan every server": sources without an
    /// index (the live router) and snapshots where every server is
    /// admissible both use the sentinel, so the common uncongested case
    /// pays nothing. Excluded servers are provably infeasible (zero
    /// compute headroom ⇒ f(y) ≤ -1), which is why [`Self::scan`]-based
    /// feasibility filtering is decision-identical to a full scan; only
    /// the full-scan fallbacks ([`Self::least_violating`]) still visit
    /// saturated servers.
    pub candidates: Vec<u32>,
}

impl Default for ClusterView {
    fn default() -> Self {
        ClusterView {
            now: 0.0,
            epoch: 0,
            servers: Vec::new(),
            weights: EnergyWeights::default(),
            candidates: Vec::new(),
        }
    }
}

impl ClusterView {
    /// An empty snapshot with room for `n` servers — the scratch buffer
    /// both substrates refill per decision via [`ViewSource::view_into`],
    /// so the decision hot path performs no per-request allocation.
    /// Schedulers receive views by reference (`Scheduler::decide` borrows)
    /// and must not retain them across decisions.
    pub fn with_capacity(n: usize, weights: EnergyWeights) -> ClusterView {
        ClusterView {
            now: 0.0,
            epoch: 0,
            servers: Vec::with_capacity(n),
            weights,
            candidates: Vec::new(),
        }
    }

    /// Iterate the servers a feasibility-filtering scheduler needs to
    /// score: the candidate subset when the view source provided one,
    /// every server otherwise. Ascending order either way, so tie-breaks
    /// match the full scan exactly.
    pub fn scan(&self) -> impl Iterator<Item = usize> + '_ {
        let pruned = !self.candidates.is_empty();
        let full = if pruned { 0..0 } else { 0..self.servers.len() };
        self.candidates
            .iter()
            .map(|&i| i as usize)
            .chain(full)
    }

    /// Paper Eq. 3 for a single assignment y = (request → server j),
    /// generalized to the SLO vector: the minimum normalized slack across
    /// every *present* request constraint (C1 completion via
    /// `predicted_time`, TTFT via `predicted_ttft`, energy budget via the
    /// raw tx+infer energy estimate) and the resource families (C2
    /// compute, C3 bandwidth). f(y) >= 0 iff every binding constraint
    /// holds.
    ///
    /// A completion-only contract reproduces the pre-PR5 scalar formula
    /// `(D∆ - predicted) / D∆` bit for bit (pinned by
    /// `rust/tests/slo_identity.rs`), except that a non-positive D∆ now
    /// yields `-inf` instead of NaN — NaN compared false against every
    /// `>= margin` filter AND fell out of `min` (Rust's `f64::min` ignores
    /// NaN), so a zero-deadline request used to be judged on C2/C3 alone
    /// and could be admitted as "feasible".
    pub fn constraint_satisfaction(&self, req: &ServiceRequest, server: usize) -> f64 {
        let sv = &self.servers[server];
        let d = req.slo.min_slack(
            sv.predicted_ttft,
            sv.predicted_time,
            sv.tx_energy_est + sv.infer_energy_est,
        );
        self.resource_slack_min(d, server)
    }

    /// The pre-PR5 **completion-only lens** on the same Eq.-3 mechanism:
    /// judge the placement on the scalar completion deadline (plus C2/C3),
    /// ignoring any TTFT or energy constraints the request carries. This
    /// is what the paper-identical `CsUcb::with_defaults` consumes — the
    /// honest "completion-only CS-UCB" baseline that `CsUcbSlo` is
    /// measured against on SLO-vector workloads. Requests without a
    /// completion bound contribute `+inf` (only C2/C3 bind).
    pub fn completion_satisfaction(&self, req: &ServiceRequest, server: usize) -> f64 {
        let sv = &self.servers[server];
        let d = match req.slo.completion {
            Some(dl) => SloSpec::norm_slack(dl, sv.predicted_time),
            None => f64::INFINITY,
        };
        self.resource_slack_min(d, server)
    }

    /// Fold the C2 (compute) and C3 (bandwidth) normalized slacks into an
    /// already-computed request-constraint slack — the shared tail of both
    /// satisfaction lenses, kept identical to the historical
    /// `d.min(c).min(b)` expression.
    #[inline]
    fn resource_slack_min(&self, d: f64, server: usize) -> f64 {
        let sv = &self.servers[server];
        let c = if sv.compute_headroom > 0.0 {
            // lint: allow(nan-cmp) denominator clamp on a headroom just checked > 0
            (sv.compute_headroom - sv.compute_demand) / sv.compute_headroom.max(1e-9)
        } else {
            -1.0
        };
        let b = if sv.bandwidth_headroom > 0.0 {
            // lint: allow(nan-cmp) denominator clamp on a headroom just checked > 0
            (sv.bandwidth_headroom - sv.bandwidth_demand) / sv.bandwidth_headroom.max(1e-9)
        } else {
            -1.0
        };
        // lint: allow(nan-cmp) operands are -1.0 sentinels or ±inf-bounded slacks, never NaN
        d.min(c).min(b)
    }

    /// Estimated weighted energy cost (Eq. 2 terms) of the assignment.
    pub fn energy_cost(&self, server: usize) -> f64 {
        let sv = &self.servers[server];
        self.weights.w_tran * sv.tx_energy_est + self.weights.w_infer * sv.infer_energy_est
    }

    /// Servers whose assignment satisfies every constraint (f(y) >= 0).
    ///
    /// Allocating wrapper around [`Self::feasible_servers_into`]; hot
    /// paths should hold a scratch `Vec<usize>` and use the `_into` form.
    pub fn feasible_servers(&self, req: &ServiceRequest) -> Vec<usize> {
        let mut out = Vec::new();
        self.feasible_servers_into(req, &mut out);
        out
    }

    /// Fill `out` with the feasible server indices (f(y) >= 0).
    pub fn feasible_servers_into(&self, req: &ServiceRequest, out: &mut Vec<usize>) {
        self.feasible_servers_with_slack_into(req, 0.0, out);
    }

    /// Servers with at least `margin` normalized slack on the binding
    /// constraint (f(y) >= margin). A positive margin absorbs the load that
    /// arrives between admission and completion.
    ///
    /// Allocating wrapper around
    /// [`Self::feasible_servers_with_slack_into`].
    pub fn feasible_servers_with_slack(&self, req: &ServiceRequest, margin: f64) -> Vec<usize> {
        let mut out = Vec::new();
        self.feasible_servers_with_slack_into(req, margin, &mut out);
        out
    }

    /// Fill `out` with the indices of servers whose binding-constraint
    /// slack is at least `margin` (f(y) >= margin). Clears `out` first, so
    /// a scheduler-owned scratch buffer can be reused across decisions
    /// without any per-decision allocation once it has grown to cluster
    /// size.
    ///
    /// For `margin >= 0` the scan is restricted to [`Self::scan`]'s
    /// candidate set: pruned servers carry zero compute headroom, so their
    /// f(y) ≤ -1 can never clear a non-negative margin — the result is
    /// identical to the full scan, minus the visits. Negative margins
    /// (callers probing *violating* placements) always scan everything.
    pub fn feasible_servers_with_slack_into(
        &self,
        req: &ServiceRequest,
        margin: f64,
        out: &mut Vec<usize>,
    ) {
        // lint: no-alloc per-decision feasibility scan; `out` is a caller-owned scratch buffer
        out.clear();
        if margin >= 0.0 {
            out.extend(
                self.scan()
                    .filter(|&j| self.constraint_satisfaction(req, j) >= margin),
            );
        } else {
            out.extend(
                (0..self.servers.len())
                    .filter(|&j| self.constraint_satisfaction(req, j) >= margin),
            );
        }
        // lint: end-no-alloc
    }

    /// Fallback when no server is feasible: the paper assigns the service
    /// to "a more resource-rich server" — the one with maximum f(y), i.e.
    /// the least-violating assignment. Always a full scan (every server is
    /// a legal fallback target, including saturated ones), but it only
    /// runs on fallback decisions, never on the feasible hot path.
    pub fn least_violating(&self, req: &ServiceRequest) -> usize {
        self.least_violating_with_fy(req).0
    }

    /// [`Self::least_violating`] plus the winning slack value, for callers
    /// that need both without scanning twice. Ties keep the LAST maximum
    /// (the historical `max_by` behavior this wrapper preserves). Note:
    /// CS-UCB's all-infeasible fallback intentionally does NOT use this
    /// helper — its inline scan keeps the FIRST maximum on ties, matching
    /// its own pre-candidate fused loop bit for bit; swapping it onto this
    /// helper would silently flip fallback choices on exact f(y) ties.
    pub fn least_violating_with_fy(&self, req: &ServiceRequest) -> (usize, f64) {
        assert!(!self.servers.is_empty(), "non-empty cluster");
        let mut best = (0usize, f64::NEG_INFINITY);
        for j in 0..self.servers.len() {
            let fy = self.constraint_satisfaction(req, j);
            // `>=` keeps the last maximum on exact ties — the tie-break
            // the previous `max_by`-based implementation had.
            if fy >= best.1 {
                best = (j, fy);
            }
        }
        best
    }
}

/// Why a policy shed a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Every assignment violates the constraints beyond recovery — the
    /// request would miss its requirement wherever it is placed.
    Infeasible,
    /// The policy declined for load reasons (queues saturated) even
    /// though a placement nominally exists.
    Overloaded,
}

/// A scheduling action for one request — what [`Scheduler::decide`]
/// returns. Both substrates (DES engine, live router) handle every
/// variant: `Assign` dispatches now, `Defer` holds the request (deferred
/// batching), `Shed` rejects it. Sheds count into `RunReport::dropped`
/// (engine) / router shed diagnostics, and the policy still receives
/// bandit feedback for them (a failed outcome with
/// [`ServiceOutcome::was_shed`] set).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Dispatch to `server` immediately.
    Assign { server: usize },
    /// Hold the request `delay_s` seconds, then dispatch to `server`.
    Defer { server: usize, delay_s: f64 },
    /// Reject the request outright; no server resources are consumed.
    Shed { reason: ShedReason },
}

impl Action {
    pub fn assign(server: usize) -> Action {
        Action::Assign { server }
    }

    pub fn defer(server: usize, delay_s: f64) -> Action {
        Action::Defer { server, delay_s }
    }

    pub fn shed(reason: ShedReason) -> Action {
        Action::Shed { reason }
    }

    /// Target server, if the action dispatches anywhere.
    pub fn server(&self) -> Option<usize> {
        match *self {
            Action::Assign { server } | Action::Defer { server, .. } => Some(server),
            Action::Shed { .. } => None,
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, Action::Shed { .. })
    }
}

/// Legacy single-assignment decision — the PR-1 API, kept only as a
/// compat shim for external callers. It cannot express shedding; convert
/// with `Action::from(decision)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// Target server index.
    pub server: usize,
    /// Hold the request this long before dispatching (deferred batching).
    pub defer_s: f64,
}

impl Decision {
    pub fn now(server: usize) -> Decision {
        Decision {
            server,
            defer_s: 0.0,
        }
    }
}

impl From<Decision> for Action {
    fn from(d: Decision) -> Action {
        if d.defer_s > 0.0 {
            Action::Defer {
                server: d.server,
                delay_s: d.defer_s,
            }
        } else {
            Action::Assign { server: d.server }
        }
    }
}

/// Anything that can fill a scheduler-facing snapshot in place: the DES
/// cluster and the live router both implement this, which is what lets
/// one scheduler implementation run unchanged on either substrate with
/// zero per-request allocation (callers own one scratch [`ClusterView`]
/// and refill it per decision).
///
/// # Versioned-view contract (sharded engine)
///
/// Every fill must stamp [`ClusterView::epoch`] with a monotone
/// non-decreasing snapshot version, and the snapshot must be
/// *internally consistent*: all servers observed at the same simulated
/// instant `out.now`. The sequential substrates satisfy this trivially
/// (one thread, one clock). The sharded engine satisfies it by
/// construction: shards park at a merge barrier, are advanced to the
/// barrier time, and only then is the view assembled and stamped — so a
/// scheduler can never observe one shard ahead of another. The identity
/// test (`rust/tests/sharded_identity.rs`) pins that decisions taken
/// under this contract are bit-identical to the sequential engine's.
pub trait ViewSource {
    /// Fill `out` with the current cluster snapshot for `req`. Must fully
    /// overwrite `out` (the buffer is reused across requests) and stamp
    /// `out.epoch` per the versioned-view contract above.
    fn view_into(&self, req: &ServiceRequest, out: &mut ClusterView);
}

/// Fleet-membership and availability transitions, pushed to schedulers
/// as they happen (the engine emits them from the fault layer; the
/// legacy scripted-outage path emits them too). `Down`/`Up` are
/// *ground-truth* transitions — a scheduler that wants the production
/// experience should act on `observed_health` instead and use these only
/// for bookkeeping that a real control plane would also see (e.g. a
/// registry webhook on rejoin).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEvent {
    /// Server went down (outage or crash).
    Down { server: usize },
    /// Server recovered from an outage or crash.
    Up { server: usize },
    /// Server gracefully left the fleet (drains, admits nothing).
    Left { server: usize },
    /// Server rejoined the fleet. Non-stationary bandits typically reset
    /// the server's arms here: post-restart behavior shares little with
    /// pre-crash statistics.
    Joined { server: usize },
}

/// Common interface for PerLLM and baselines.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Choose an [`Action`] for `req` given the current cluster view.
    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action;

    /// Observe the realized outcome of a past decision (bandit feedback).
    /// Shed requests are delivered too ([`ServiceOutcome::was_shed`]);
    /// implementations must not index arms by `outcome.server` for those.
    fn feedback(&mut self, _outcome: &ServiceOutcome, _view: &ClusterView) {}

    /// Observe a fleet transition ([`FleetEvent`]). Default: ignore —
    /// stationary policies are oblivious to fleet dynamics, which keeps
    /// every existing scheduler bit-identical on fault-free runs.
    fn fleet_event(&mut self, _ev: &FleetEvent, _now: f64) {}

    /// Scheduler-specific diagnostics for reports (e.g. cumulative regret).
    fn diagnostics(&self) -> Vec<(String, f64)> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::service::ServiceClass;

    pub(crate) fn test_view(predicted: Vec<f64>) -> ClusterView {
        let servers = predicted
            .into_iter()
            .enumerate()
            .map(|(i, p)| ServerView {
                kind: if i == 0 { ServerKind::Cloud } else { ServerKind::Edge },
                predicted_time: p,
                predicted_ttft: 0.5 * p,
                compute_headroom: 2.0,
                compute_demand: 0.5,
                bandwidth_headroom: 50.0e6,
                bandwidth_demand: 1.0e6,
                tx_energy_est: 1.0,
                infer_energy_est: 5.0,
                n_active: 0,
                n_waiting: 0,
                solo_time_est: p,
                occupancy: 0.0,
                observed_health: 1.0,
                prefix_hit_tokens: 0.0,
                prefix_pressure: 0.0,
            })
            .collect();
        ClusterView {
            now: 0.0,
            epoch: 0,
            servers,
            weights: EnergyWeights::default(),
            candidates: Vec::new(),
        }
    }

    pub(crate) fn test_req(deadline: f64) -> ServiceRequest {
        test_req_slo(SloSpec::completion_only(deadline))
    }

    pub(crate) fn test_req_slo(slo: SloSpec) -> ServiceRequest {
        ServiceRequest {
            id: 7,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: 50,
            output_tokens: 30,
            slo,
            payload_bytes: 100_000,
            session: None,
        }
    }

    #[test]
    fn fy_positive_iff_all_constraints_hold() {
        let view = test_view(vec![1.0, 3.0]);
        let req = test_req(2.0);
        assert!(view.constraint_satisfaction(&req, 0) >= 0.0);
        assert!(view.constraint_satisfaction(&req, 1) < 0.0); // misses deadline
        assert_eq!(view.feasible_servers(&req), vec![0]);
    }

    /// TTFT constraints bind through `predicted_ttft`: a server fast on
    /// completion but slow to first token is infeasible for an
    /// interactive contract, while the completion-only lens ignores it.
    #[test]
    fn fy_ttft_constraint_binds_on_predicted_ttft() {
        // test_view: predicted_ttft = 0.5 * predicted_time.
        let view = test_view(vec![1.0, 3.0]);
        let req = test_req_slo(SloSpec::completion_only(4.0).with_ttft(0.8));
        // Server 0: ttft 0.5 <= 0.8 → feasible. Server 1: ttft 1.5 > 0.8.
        assert!(view.constraint_satisfaction(&req, 0) >= 0.0);
        assert!(view.constraint_satisfaction(&req, 1) < 0.0);
        assert_eq!(view.feasible_servers(&req), vec![0]);
        // The completion lens sees both as feasible (4 s is generous).
        assert!(view.completion_satisfaction(&req, 1) >= 0.0);
    }

    /// Energy budgets bind through the raw tx+infer estimate.
    #[test]
    fn fy_energy_budget_binds() {
        let view = test_view(vec![1.0]); // tx 1 J + infer 5 J = 6 J est
        let within = test_req_slo(SloSpec::completion_only(4.0).with_energy_budget(10.0));
        let beyond = test_req_slo(SloSpec::completion_only(4.0).with_energy_budget(4.0));
        assert!(view.constraint_satisfaction(&within, 0) >= 0.0);
        assert!(view.constraint_satisfaction(&beyond, 0) < 0.0);
        assert!(view.completion_satisfaction(&beyond, 0) >= 0.0);
    }

    /// Regression (satellite): a zero/negative deadline used to make the
    /// C1 term NaN, which `f64::min` silently dropped — the request was
    /// then judged on C2/C3 alone and could be "feasible". It must be
    /// `-inf`: infeasible everywhere, filtered by every margin.
    #[test]
    fn zero_deadline_is_neg_inf_not_nan() {
        let view = test_view(vec![1.0]);
        for slo in [
            SloSpec::completion_only(0.0),
            SloSpec::completion_only(-1.0),
            SloSpec::ttft_only(0.0),
            SloSpec::completion_only(4.0).with_energy_budget(0.0),
        ] {
            let req = test_req_slo(slo);
            let fy = view.constraint_satisfaction(&req, 0);
            assert_eq!(fy, f64::NEG_INFINITY, "slo {slo:?} gave {fy}");
            assert!(view.feasible_servers(&req).is_empty());
            assert!(view.feasible_servers_with_slack(&req, -1000.0).is_empty());
        }
        // The completion lens gets the same guard.
        let req = test_req(0.0);
        assert_eq!(view.completion_satisfaction(&req, 0), f64::NEG_INFINITY);
    }

    /// A request with no completion bound passes the completion lens on
    /// C2/C3 alone (vacuous C1), and the vector lens on its own terms.
    #[test]
    fn absent_completion_is_vacuous_for_the_lens() {
        let view = test_view(vec![1.0]);
        let req = test_req_slo(SloSpec::ttft_only(0.8));
        assert!(view.completion_satisfaction(&req, 0) >= 0.0);
        assert!(view.constraint_satisfaction(&req, 0) >= 0.0); // ttft 0.5
    }

    #[test]
    fn fy_detects_compute_violation() {
        let mut view = test_view(vec![1.0]);
        view.servers[0].compute_demand = 5.0; // exceeds headroom 2.0
        let req = test_req(4.0);
        assert!(view.constraint_satisfaction(&req, 0) < 0.0);
    }

    #[test]
    fn fy_detects_bandwidth_violation() {
        let mut view = test_view(vec![1.0]);
        view.servers[0].bandwidth_demand = 100.0e6;
        let req = test_req(4.0);
        assert!(view.constraint_satisfaction(&req, 0) < 0.0);
    }

    #[test]
    fn least_violating_picks_max_fy() {
        let view = test_view(vec![10.0, 4.0, 8.0]);
        let req = test_req(2.0); // everyone infeasible
        assert!(view.feasible_servers(&req).is_empty());
        assert_eq!(view.least_violating(&req), 1);
    }

    #[test]
    fn energy_cost_weighted() {
        let mut view = test_view(vec![1.0]);
        view.weights = EnergyWeights {
            w_tran: 2.0,
            w_infer: 1.0,
            w_idle: 1.0,
        };
        assert!((view.energy_cost(0) - (2.0 + 5.0)).abs() < 1e-12);
    }

    #[test]
    fn feasible_into_matches_allocating_form_and_reuses_buffer() {
        let view = test_view(vec![1.0, 3.0, 1.5]);
        let req = test_req(2.0);
        let mut buf = vec![99, 98, 97, 96]; // stale content must be cleared
        view.feasible_servers_into(&req, &mut buf);
        assert_eq!(buf, view.feasible_servers(&req));
        view.feasible_servers_with_slack_into(&req, 0.2, &mut buf);
        assert_eq!(buf, view.feasible_servers_with_slack(&req, 0.2));
    }

    /// A pruned candidate set restricts `scan()` and the feasibility
    /// helpers to the listed servers, and (because pruned servers are
    /// infeasible by construction at the source) yields the same feasible
    /// set the full scan would.
    #[test]
    fn candidate_scan_matches_full_scan_on_feasible_sets() {
        let mut view = test_view(vec![1.0, 3.0, 1.5]);
        let req = test_req(2.0);
        let full = view.feasible_servers(&req);
        assert_eq!(full, vec![0, 2]);
        // Simulate the source pruning server 1 (saturated: in a real fill
        // it would also carry zero headroom; here it is merely infeasible
        // on deadline, which is enough for the equality we assert).
        view.candidates = vec![0, 2];
        assert_eq!(view.scan().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(view.feasible_servers(&req), full);
        let mut buf = Vec::new();
        view.feasible_servers_with_slack_into(&req, 0.1, &mut buf);
        let mut full_buf = Vec::new();
        view.candidates.clear();
        view.feasible_servers_with_slack_into(&req, 0.1, &mut full_buf);
        assert_eq!(buf, full_buf);
        // Negative margins (probing violating placements) ignore pruning.
        view.candidates = vec![0];
        view.feasible_servers_with_slack_into(&req, -10.0, &mut buf);
        assert_eq!(buf, vec![0, 1, 2]);
        // Empty candidates = full-scan sentinel.
        view.candidates.clear();
        assert_eq!(view.scan().collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn least_violating_with_fy_reports_winner_slack() {
        let view = test_view(vec![10.0, 4.0, 8.0]);
        let req = test_req(2.0);
        let (j, fy) = view.least_violating_with_fy(&req);
        assert_eq!(j, 1);
        assert!((fy - view.constraint_satisfaction(&req, 1)).abs() < 1e-12);
        assert!(fy < 0.0);
    }

    #[test]
    fn action_helpers_and_server_accessor() {
        assert_eq!(Action::assign(3).server(), Some(3));
        assert_eq!(Action::defer(1, 0.5).server(), Some(1));
        assert_eq!(Action::shed(ShedReason::Infeasible).server(), None);
        assert!(Action::shed(ShedReason::Overloaded).is_shed());
        assert!(!Action::assign(0).is_shed());
    }

    #[test]
    fn decision_shim_converts_to_action() {
        assert_eq!(Action::from(Decision::now(2)), Action::assign(2));
        assert_eq!(
            Action::from(Decision {
                server: 4,
                defer_s: 0.25,
            }),
            Action::defer(4, 0.25)
        );
    }
}
