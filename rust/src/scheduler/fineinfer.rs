//! FineInfer baseline (He, Lu, Alonso — EuroMLSys'24): a **cloud-only**
//! solution with *deferred continuous batching* — requests are held until
//! the next batch boundary and dispatched to the cloud together, improving
//! batch occupancy at the cost of head-of-line latency and leaving the
//! shared cloud uplink as the bottleneck (hence its Figure-5 throughput
//! floor in the paper).

use super::{Action, ClusterView, Scheduler};
use crate::workload::service::ServiceRequest;

pub struct FineInfer {
    cloud: usize,
    /// Deferred-batching window, seconds.
    pub window_s: f64,
    decisions: u64,
}

impl FineInfer {
    pub fn new(cloud_index: usize) -> Self {
        FineInfer {
            cloud: cloud_index,
            window_s: 0.25,
            decisions: 0,
        }
    }
}

impl Scheduler for FineInfer {
    fn name(&self) -> &'static str {
        "fineinfer (cloud-only)"
    }

    fn decide(&mut self, _req: &ServiceRequest, view: &ClusterView) -> Action {
        // lint: no-alloc baseline decide shares the router hot path
        self.decisions += 1;
        // Hold until the next global batch boundary.
        let phase = view.now % self.window_s;
        let action = if phase == 0.0 {
            Action::assign(self.cloud)
        } else {
            Action::defer(self.cloud, self.window_s - phase)
        };
        // lint: end-no-alloc
        action
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        vec![("decisions".into(), self.decisions as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{test_req, test_view};
    use super::*;

    #[test]
    fn always_cloud() {
        let mut s = FineInfer::new(0);
        let view = test_view(vec![1.0, 0.5]);
        for _ in 0..10 {
            assert_eq!(s.decide(&test_req(3.0), &view).server(), Some(0));
        }
    }

    #[test]
    fn defers_to_batch_boundary() {
        let mut s = FineInfer::new(0);
        let mut view = test_view(vec![1.0]);
        view.now = 0.10;
        let Action::Defer { server, delay_s } = s.decide(&test_req(3.0), &view) else {
            panic!("mid-window decision must defer");
        };
        assert_eq!(server, 0);
        assert!((delay_s - 0.15).abs() < 1e-9, "defer={delay_s}");
        view.now = 0.25;
        let d2 = s.decide(&test_req(3.0), &view);
        assert_eq!(d2, Action::assign(0), "on-boundary dispatches now");
    }
}
