//! Workload substrate: diverse-service request model and reproducible
//! trace generation (the paper's 10 k-request evaluation workloads).

pub mod generator;
pub mod service;

pub use generator::{generate, ArrivalProcess, ClassProfile, WorkloadConfig};
pub use service::{ServiceClass, ServiceOutcome, ServiceRequest};
