//! Workload substrate: diverse-service request model and reproducible
//! trace generation (the paper's 10 k-request evaluation workloads).
//!
//! Workloads reach the DES through the pull-based [`ArrivalSource`]
//! cursor instead of a pre-materialized `Vec<ServiceRequest>`: the engine
//! prefetches exactly one pending arrival at a time, so the event heap no
//! longer scales with trace length (a 1M-request run used to start by
//! pushing 1M arrival events). [`generator::WorkloadGen`] streams the
//! synthetic workloads; [`TraceSource`] adapts an existing in-memory
//! trace.

pub mod generator;
pub mod service;
pub mod sessions;

pub use generator::{
    generate, ArrivalModulation, ArrivalProcess, ClassProfile, SloSampling, WorkloadConfig,
    WorkloadGen,
};
pub use service::{
    ServiceClass, ServiceOutcome, ServiceRequest, SessionRef, SloSpec, KV_BYTES_PER_TOKEN,
};
pub use sessions::{SessionConfig, SessionProfile, SessionSource, SESSION_STREAM_SALT};

/// Pull-based workload cursor: the engine asks for one arrival at a time.
///
/// Implementations must yield requests in nondecreasing `arrival` order
/// (the DES clock is monotone; an out-of-order arrival is clamped to the
/// current simulated time by the event queue).
pub trait ArrivalSource {
    /// The next request, or `None` when the workload is exhausted.
    fn next_arrival(&mut self) -> Option<ServiceRequest>;

    /// Remaining number of requests, if known (used only to size result
    /// buffers — correctness never depends on it).
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Adapter: stream an existing in-memory trace (sorted by arrival time)
/// through the [`ArrivalSource`] interface. This is what keeps the
/// slice-based `sim::engine::simulate` entry point working on the
/// streaming engine.
pub struct TraceSource<'a> {
    trace: &'a [ServiceRequest],
    next: usize,
}

impl<'a> TraceSource<'a> {
    pub fn new(trace: &'a [ServiceRequest]) -> Self {
        TraceSource { trace, next: 0 }
    }
}

impl ArrivalSource for TraceSource<'_> {
    fn next_arrival(&mut self) -> Option<ServiceRequest> {
        let r = self.trace.get(self.next)?.clone();
        self.next += 1;
        Some(r)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.trace.len() - self.next)
    }
}

/// Streaming k-way merge of several [`ArrivalSource`]s by arrival time —
/// the **per-tier arrival mix** primitive for multi-tier topologies: give
/// each tier (or each service population) its own `WorkloadGen` (rate,
/// class weights, token profiles, seed) and merge them into the single
/// nondecreasing stream the engine consumes. Holds one prefetched head
/// per source, so memory stays O(sources) no matter how long each stream
/// runs.
///
/// Equal arrival times resolve to the lowest source index (deterministic,
/// like a stable merge). Request ids are relabeled densely in merged
/// order — the per-source ids are meaningless once streams interleave,
/// and downstream consumers (CS-UCB's dense penalty table, outcome
/// bookkeeping) rely on dense ids from zero.
pub struct MergedArrivals<'a> {
    sources: Vec<&'a mut dyn ArrivalSource>,
    heads: Vec<Option<ServiceRequest>>,
    /// Per-source intensity modulation applied to the *realized*
    /// inter-arrival gaps of that source's stream (identity by default).
    mods: Vec<ArrivalModulation>,
    /// Last raw (pre-modulation) arrival time seen from each source.
    raw_t: Vec<f64>,
    /// Last modulated arrival time emitted for each source.
    mod_t: Vec<f64>,
    next_id: u64,
}

impl<'a> MergedArrivals<'a> {
    pub fn new(mut sources: Vec<&'a mut dyn ArrivalSource>) -> Self {
        let heads: Vec<Option<ServiceRequest>> =
            sources.iter_mut().map(|s| s.next_arrival()).collect();
        let n = sources.len();
        // Until a modulation is installed the raw/modulated clocks track
        // the head verbatim.
        let raw_t = heads
            .iter()
            .map(|h| h.as_ref().map_or(0.0, |r| r.arrival))
            .collect::<Vec<_>>();
        let mod_t = raw_t.clone();
        MergedArrivals {
            sources,
            heads,
            mods: vec![ArrivalModulation::None; n],
            raw_t,
            mod_t,
            next_id: 0,
        }
    }

    /// Install one [`ArrivalModulation`] per source — the per-tier demand
    /// shaping knob for multi-tier topologies (e.g. a flash crowd hitting
    /// only the edge-tier population while the cloud mix stays diurnal).
    ///
    /// The modulation rescales each source's realized inter-arrival gaps:
    /// `dt' = dt / m(t')` with the intensity evaluated at the source's
    /// previous *modulated* arrival — the same first-order inhomogeneous
    /// approximation as [`WorkloadConfig::with_modulation`]
    /// (`generator::WorkloadConfig::with_modulation`), but applied at the
    /// merge layer so it composes with any [`ArrivalSource`], including
    /// replayed traces. [`ArrivalModulation::None`] entries leave that
    /// source's stream bit-identical. Zero extra RNG draws, so request
    /// content (classes, tokens, SLOs) is untouched by construction.
    ///
    /// Panics if the arity does not match the source count, if any
    /// modulation has nonsensical parameters, or if arrivals were already
    /// consumed (mid-stream installation would shift semantics silently).
    pub fn with_modulations(mut self, mods: Vec<ArrivalModulation>) -> Self {
        assert_eq!(
            mods.len(),
            self.sources.len(),
            "one modulation per source required"
        );
        assert_eq!(
            self.next_id, 0,
            "modulations must be installed before consuming arrivals"
        );
        for m in &mods {
            m.validate();
        }
        self.mods = mods;
        // The heads were prefetched under the identity modulation from
        // t = 0; re-derive them under the installed ones.
        for i in 0..self.heads.len() {
            if let Some(r) = &mut self.heads[i] {
                if self.mods[i] != ArrivalModulation::None {
                    let m = self.mods[i].intensity(0.0);
                    r.arrival = self.raw_t[i] / m;
                    self.mod_t[i] = r.arrival;
                }
            }
        }
        self
    }

    /// Pull the next head from source `i`, applying its modulation.
    fn refill(&mut self, i: usize) {
        self.heads[i] = self.sources[i].next_arrival().map(|mut r| {
            let raw = r.arrival;
            if self.mods[i] != ArrivalModulation::None {
                let m = self.mods[i].intensity(self.mod_t[i]);
                r.arrival = self.mod_t[i] + (raw - self.raw_t[i]) / m;
            }
            self.raw_t[i] = raw;
            self.mod_t[i] = r.arrival;
            r
        });
    }
}

impl ArrivalSource for MergedArrivals<'_> {
    fn next_arrival(&mut self) -> Option<ServiceRequest> {
        let mut best: Option<(usize, f64)> = None;
        for (i, head) in self.heads.iter().enumerate() {
            if let Some(r) = head {
                // Strict `<` keeps the earliest source index on ties.
                if best.is_none_or(|(_, t)| r.arrival < t) {
                    best = Some((i, r.arrival));
                }
            }
        }
        let (i, _) = best?;
        let mut r = self.heads[i].take().expect("selected head");
        self.refill(i);
        r.id = self.next_id;
        self.next_id += 1;
        Some(r)
    }

    fn len_hint(&self) -> Option<usize> {
        let mut total = self.heads.iter().flatten().count();
        for s in &self.sources {
            total += s.len_hint()?;
        }
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Merging two per-tier mixes yields a single nondecreasing stream
    /// with dense relabeled ids — exactly the stable merge of the two
    /// generated traces.
    #[test]
    fn merged_arrivals_is_a_stable_merge() {
        let chat = WorkloadConfig::default()
            .with_requests(40)
            .with_arrivals(ArrivalProcess::Poisson { rate: 9.0 })
            .with_seed(1);
        let code = WorkloadConfig::default()
            .with_requests(25)
            .with_arrivals(ArrivalProcess::Poisson { rate: 4.0 })
            .with_seed(2);

        // Expected: classic stable two-way merge of the materialized
        // traces, preferring the first source on ties.
        let ta = generate(&chat);
        let tb = generate(&code);
        let mut expect = Vec::new();
        let (mut i, mut j) = (0, 0);
        while i < ta.len() || j < tb.len() {
            let take_a = match (ta.get(i), tb.get(j)) {
                (Some(a), Some(b)) => a.arrival <= b.arrival,
                (Some(_), None) => true,
                _ => false,
            };
            if take_a {
                expect.push(ta[i].clone());
                i += 1;
            } else {
                expect.push(tb[j].clone());
                j += 1;
            }
        }

        let mut sa = WorkloadGen::new(&chat);
        let mut sb = WorkloadGen::new(&code);
        let mut merged = MergedArrivals::new(vec![&mut sa, &mut sb]);
        assert_eq!(merged.len_hint(), Some(65));
        let mut got = Vec::new();
        while let Some(r) = merged.next_arrival() {
            got.push(r);
        }
        assert_eq!(got.len(), 65);
        assert!(merged.next_arrival().is_none());
        for (k, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert_eq!(g.id, k as u64, "ids relabeled densely");
            assert_eq!(g.arrival, e.arrival, "order diverged at {k}");
            assert_eq!(g.prompt_tokens, e.prompt_tokens);
            assert_eq!(g.class, e.class);
        }
        assert!(got.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    /// Three-way merge under strongly unequal per-tier rates: the merged
    /// stream keeps the ArrivalSource order contract (nondecreasing
    /// arrivals), relabels ids densely from zero, conserves every
    /// request, and is deterministic run to run.
    #[test]
    fn kway_merge_unequal_rates_contract() {
        let mk = |n: usize, rate: f64, seed: u64| {
            WorkloadConfig::default()
                .with_requests(n)
                .with_arrivals(ArrivalProcess::Poisson { rate })
                .with_seed(seed)
        };
        // Rates spanning two orders of magnitude: the slow tier's stream
        // outlives the fast ones, exercising exhausted-source heads.
        let cfgs = [mk(120, 50.0, 11), mk(40, 2.0, 22), mk(9, 0.5, 33)];

        let run = || {
            let mut gens: Vec<WorkloadGen> = cfgs.iter().map(WorkloadGen::new).collect();
            let sources: Vec<&mut dyn ArrivalSource> = gens
                .iter_mut()
                .map(|g| g as &mut dyn ArrivalSource)
                .collect();
            let mut merged = MergedArrivals::new(sources);
            assert_eq!(merged.len_hint(), Some(169));
            let mut got = Vec::new();
            while let Some(r) = merged.next_arrival() {
                // Order contract the engine debug_asserts on.
                if let Some(prev) = got.last().map(|p: &ServiceRequest| p.arrival) {
                    assert!(r.arrival >= prev, "order broke at {}", r.id);
                }
                got.push(r);
            }
            assert!(merged.next_arrival().is_none(), "stays exhausted");
            got
        };

        let a = run();
        let b = run();
        assert_eq!(a.len(), 169, "every request conserved");
        // Dense id relabeling from zero, in merged order.
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i as u64));
        // Deterministic: identical sequences, field for field.
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.class, y.class);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
        // The fast tier dominates early, the slow tail survives to the
        // end: the last arrival must come from the 0.5 req/s source
        // (its 9 requests stretch past everything else).
        let span_fast = 120.0 / 50.0;
        assert!(a.last().unwrap().arrival > 2.0 * span_fast);
    }

    /// Sources that start empty or exhaust mid-merge never stall the
    /// stream or distort ids.
    #[test]
    fn merge_with_empty_and_short_sources() {
        let empty_cfg = WorkloadConfig::default().with_requests(0).with_seed(1);
        let short_cfg = WorkloadConfig::default()
            .with_requests(3)
            .with_arrivals(ArrivalProcess::Poisson { rate: 5.0 })
            .with_seed(2);
        let long_cfg = WorkloadConfig::default()
            .with_requests(10)
            .with_arrivals(ArrivalProcess::Poisson { rate: 5.0 })
            .with_seed(3);
        let mut e = WorkloadGen::new(&empty_cfg);
        let mut s = WorkloadGen::new(&short_cfg);
        let mut l = WorkloadGen::new(&long_cfg);
        let mut merged = MergedArrivals::new(vec![&mut e, &mut s, &mut l]);
        assert_eq!(merged.len_hint(), Some(13));
        let mut got = Vec::new();
        while let Some(r) = merged.next_arrival() {
            got.push(r);
        }
        assert_eq!(got.len(), 13);
        assert!(got.iter().enumerate().all(|(i, r)| r.id == i as u64));
        assert!(got.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    /// Identity modulations are the same code path as no modulations:
    /// the merged stream is bit-identical, field for field.
    #[test]
    fn identity_modulations_leave_the_merge_bit_identical() {
        let mk = |n: usize, rate: f64, seed: u64| {
            WorkloadConfig::default()
                .with_requests(n)
                .with_arrivals(ArrivalProcess::Poisson { rate })
                .with_seed(seed)
        };
        let (ca, cb) = (mk(80, 12.0, 7), mk(50, 3.0, 8));
        let collect = |modulate: bool| {
            let mut sa = WorkloadGen::new(&ca);
            let mut sb = WorkloadGen::new(&cb);
            let mut merged = MergedArrivals::new(vec![&mut sa, &mut sb]);
            if modulate {
                merged = merged
                    .with_modulations(vec![ArrivalModulation::None, ArrivalModulation::None]);
            }
            let mut got = Vec::new();
            while let Some(r) = merged.next_arrival() {
                got.push(r);
            }
            got
        };
        let plain = collect(false);
        let modded = collect(true);
        assert_eq!(plain.len(), modded.len());
        for (x, y) in plain.iter().zip(&modded) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.class, y.class);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    /// A flash crowd on one source compresses only that source's gaps:
    /// the merged stream densifies inside the window, stays nondecreasing,
    /// keeps dense ids, and the unmodulated co-source is untouched.
    #[test]
    fn per_source_flash_crowd_shapes_only_its_own_stream() {
        let edge = WorkloadConfig::default()
            .with_requests(600)
            .with_arrivals(ArrivalProcess::Poisson { rate: 10.0 })
            .with_seed(41);
        let cloud = WorkloadConfig::default()
            .with_requests(200)
            .with_arrivals(ArrivalProcess::Poisson { rate: 3.0 })
            .with_seed(42);
        let crowd = ArrivalModulation::FlashCrowd {
            at_s: 10.0,
            duration_s: 10.0,
            factor: 6.0,
        };
        let collect = |mods: Option<Vec<ArrivalModulation>>| {
            let mut se = WorkloadGen::new(&edge);
            let mut sc = WorkloadGen::new(&cloud);
            let mut merged = MergedArrivals::new(vec![&mut se, &mut sc]);
            if let Some(m) = mods {
                merged = merged.with_modulations(m);
            }
            let mut got = Vec::new();
            while let Some(r) = merged.next_arrival() {
                got.push(r);
            }
            got
        };
        let plain = collect(None);
        let shaped = collect(Some(vec![crowd, ArrivalModulation::None]));
        assert_eq!(shaped.len(), plain.len(), "requests conserved");
        assert!(shaped.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(shaped.iter().enumerate().all(|(i, r)| r.id == i as u64));
        let in_window =
            |t: &[ServiceRequest]| t.iter().filter(|r| (10.0..20.0).contains(&r.arrival)).count();
        assert!(
            in_window(&shaped) > 2 * in_window(&plain),
            "crowd window densified: {} vs {}",
            in_window(&shaped),
            in_window(&plain)
        );
        // The cloud source is identity-modulated: its arrivals (matched by
        // request content, which modulation never touches) keep their raw
        // times bit for bit.
        let cloud_trace = generate(&cloud);
        for want in &cloud_trace {
            assert!(
                shaped
                    .iter()
                    .any(|r| r.arrival.to_bits() == want.arrival.to_bits()
                        && r.prompt_tokens == want.prompt_tokens),
                "cloud arrival at {} disturbed",
                want.arrival
            );
        }
    }

    #[test]
    #[should_panic(expected = "one modulation per source")]
    fn modulation_arity_mismatch_is_rejected() {
        let cfg = WorkloadConfig::default().with_requests(3).with_seed(1);
        let mut g = WorkloadGen::new(&cfg);
        let _ = MergedArrivals::new(vec![&mut g])
            .with_modulations(vec![ArrivalModulation::None, ArrivalModulation::None]);
    }

    #[test]
    #[should_panic(expected = "before consuming")]
    fn late_modulation_install_is_rejected() {
        let cfg = WorkloadConfig::default()
            .with_requests(5)
            .with_arrivals(ArrivalProcess::Poisson { rate: 5.0 })
            .with_seed(1);
        let mut g = WorkloadGen::new(&cfg);
        let mut merged = MergedArrivals::new(vec![&mut g]);
        let _ = merged.next_arrival();
        let _ = merged.with_modulations(vec![ArrivalModulation::DiurnalSine {
            period_s: 60.0,
            amplitude: 0.5,
        }]);
    }

    #[test]
    fn trace_source_streams_in_order_then_exhausts() {
        let trace = generate(&WorkloadConfig::default().with_requests(5).with_seed(3));
        let mut src = TraceSource::new(&trace);
        assert_eq!(src.len_hint(), Some(5));
        for want in &trace {
            let got = src.next_arrival().expect("request");
            assert_eq!(got.id, want.id);
            assert_eq!(got.arrival, want.arrival);
        }
        assert_eq!(src.len_hint(), Some(0));
        assert!(src.next_arrival().is_none());
        assert!(src.next_arrival().is_none(), "stays exhausted");
    }
}
