//! Workload substrate: diverse-service request model and reproducible
//! trace generation (the paper's 10 k-request evaluation workloads).
//!
//! Workloads reach the DES through the pull-based [`ArrivalSource`]
//! cursor instead of a pre-materialized `Vec<ServiceRequest>`: the engine
//! prefetches exactly one pending arrival at a time, so the event heap no
//! longer scales with trace length (a 1M-request run used to start by
//! pushing 1M arrival events). [`generator::WorkloadGen`] streams the
//! synthetic workloads; [`TraceSource`] adapts an existing in-memory
//! trace.

pub mod generator;
pub mod service;

pub use generator::{generate, ArrivalProcess, ClassProfile, WorkloadConfig, WorkloadGen};
pub use service::{ServiceClass, ServiceOutcome, ServiceRequest};

/// Pull-based workload cursor: the engine asks for one arrival at a time.
///
/// Implementations must yield requests in nondecreasing `arrival` order
/// (the DES clock is monotone; an out-of-order arrival is clamped to the
/// current simulated time by the event queue).
pub trait ArrivalSource {
    /// The next request, or `None` when the workload is exhausted.
    fn next_arrival(&mut self) -> Option<ServiceRequest>;

    /// Remaining number of requests, if known (used only to size result
    /// buffers — correctness never depends on it).
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Adapter: stream an existing in-memory trace (sorted by arrival time)
/// through the [`ArrivalSource`] interface. This is what keeps the
/// slice-based `sim::engine::simulate` entry point working on the
/// streaming engine.
pub struct TraceSource<'a> {
    trace: &'a [ServiceRequest],
    next: usize,
}

impl<'a> TraceSource<'a> {
    pub fn new(trace: &'a [ServiceRequest]) -> Self {
        TraceSource { trace, next: 0 }
    }
}

impl ArrivalSource for TraceSource<'_> {
    fn next_arrival(&mut self) -> Option<ServiceRequest> {
        let r = self.trace.get(self.next)?.clone();
        self.next += 1;
        Some(r)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.trace.len() - self.next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_source_streams_in_order_then_exhausts() {
        let trace = generate(&WorkloadConfig::default().with_requests(5).with_seed(3));
        let mut src = TraceSource::new(&trace);
        assert_eq!(src.len_hint(), Some(5));
        for want in &trace {
            let got = src.next_arrival().expect("request");
            assert_eq!(got.id, want.id);
            assert_eq!(got.arrival, want.arrival);
        }
        assert_eq!(src.len_hint(), Some(0));
        assert!(src.next_arrival().is_none());
        assert!(src.next_arrival().is_none(), "stays exhausted");
    }
}
