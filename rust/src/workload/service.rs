//! Service request model: the "diverse LLM services" of the paper.
//!
//! Each request carries a service class (chat, summarization, translation,
//! code — the diversity the paper's intro motivates), token counts, a
//! **personalized SLO vector** [`SloSpec`] generalizing the paper's scalar
//! processing-time requirement D∆ (§4.2), and the upload payload implied
//! by its prompt.
//!
//! # SLO contracts (PR 5)
//!
//! The paper's C1 constraint is a single completion deadline. Real service
//! diversity is a *vector* of constraints: interactive classes (chat,
//! translate) care about time-to-first-token, batch classes (summarize,
//! code) about completion and energy price. [`SloSpec`] carries each as an
//! `Option` — absent means "not part of this request's contract" — and
//! every consumer (the constraint-satisfaction mechanism, the engine's
//! attainment accounting, admission control) treats only the *present*
//! constraints as binding.
//!
//! The scalar `deadline` accessor is gone: consumers read
//! `SloSpec::completion` directly (`.unwrap_or(f64::INFINITY)` where an
//! unconstrained scalar is genuinely wanted). A completion-only spec
//! reproduces the pre-PR5 pipeline bit for bit (pinned by
//! `rust/tests/slo_identity.rs`).

use crate::sim::time::SimTime;

/// Service classes with distinct token profiles and deadline sensitivity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServiceClass {
    /// Short prompt, short answer, tight deadline (interactive).
    Chat,
    /// Long prompt, short answer (long-text quality users, paper §1).
    Summarize,
    /// Medium prompt, medium answer.
    Translate,
    /// Medium prompt, long answer, loose deadline.
    Code,
}

impl ServiceClass {
    pub const ALL: [ServiceClass; 4] = [
        ServiceClass::Chat,
        ServiceClass::Summarize,
        ServiceClass::Translate,
        ServiceClass::Code,
    ];

    pub fn index(self) -> usize {
        match self {
            ServiceClass::Chat => 0,
            ServiceClass::Summarize => 1,
            ServiceClass::Translate => 2,
            ServiceClass::Code => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServiceClass::Chat => "chat",
            ServiceClass::Summarize => "summarize",
            ServiceClass::Translate => "translate",
            ServiceClass::Code => "code",
        }
    }

    /// Default TTFT bound for this class, if it is interactive. Chat is
    /// tightest (a conversational turn stalls on the first token),
    /// translate a little looser; summarize/code stream into a buffer
    /// nobody watches token-by-token, so they carry no TTFT constraint.
    pub fn default_ttft(self) -> Option<SimTime> {
        match self {
            ServiceClass::Chat => Some(0.6),
            ServiceClass::Translate => Some(1.1),
            ServiceClass::Summarize | ServiceClass::Code => None,
        }
    }

    /// The class's default constraint vector around a drawn completion
    /// requirement: interactive classes (chat, translate) are TTFT-bound
    /// on top of completion, batch classes (summarize, code)
    /// completion-bound only.
    pub fn default_slo(self, completion: SimTime) -> SloSpec {
        SloSpec {
            ttft: self.default_ttft(),
            completion: Some(completion),
            energy_budget_j: None,
        }
    }
}

/// Per-request SLO contract: the constraint vector replacing the scalar
/// deadline. Absent (`None`) constraints are not part of the contract and
/// never bind — a completion-only spec is exactly the paper's D∆.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SloSpec {
    /// Time-to-first-token bound, seconds from arrival.
    pub ttft: Option<SimTime>,
    /// End-to-end completion bound, seconds from arrival (the paper's D∆).
    pub completion: Option<SimTime>,
    /// Energy-price ceiling for serving this request, joules.
    pub energy_budget_j: Option<f64>,
}

impl SloSpec {
    /// The compat constructor: the paper's scalar deadline as a
    /// completion-only contract.
    pub fn completion_only(deadline: SimTime) -> SloSpec {
        SloSpec {
            ttft: None,
            completion: Some(deadline),
            energy_budget_j: None,
        }
    }

    pub fn ttft_only(ttft: SimTime) -> SloSpec {
        SloSpec {
            ttft: Some(ttft),
            completion: None,
            energy_budget_j: None,
        }
    }

    pub fn with_ttft(mut self, ttft: SimTime) -> SloSpec {
        self.ttft = Some(ttft);
        self
    }

    pub fn with_energy_budget(mut self, joules: f64) -> SloSpec {
        self.energy_budget_j = Some(joules);
        self
    }

    /// True when the contract is exactly the paper's scalar form.
    pub fn is_completion_only(&self) -> bool {
        self.ttft.is_none() && self.energy_budget_j.is_none() && self.completion.is_some()
    }

    /// Normalized slack of one constraint: `(target - value) / target`.
    /// A non-positive target can never be met and used to produce NaN
    /// (`(0 - v) / 0`) that silently slipped through every `>= margin`
    /// filter — it is normalized to `-inf` instead (regression-tested in
    /// scheduler/mod.rs).
    #[inline]
    pub fn norm_slack(target: SimTime, value: f64) -> f64 {
        if target <= 0.0 {
            f64::NEG_INFINITY
        } else {
            (target - value) / target
        }
    }

    /// Minimum normalized slack across the *present* constraints of this
    /// contract, evaluated against predictions (decision time) or realized
    /// values (feedback time). Absent constraints contribute `+inf`
    /// (vacuously satisfied); an empty contract is always satisfied.
    ///
    /// Float-identity note: for a completion-only spec this is exactly
    /// `(D∆ - value) / D∆` — the pre-PR5 C1 term, bit for bit.
    pub fn min_slack(&self, ttft: f64, completion: f64, energy_j: f64) -> f64 {
        let mut worst = match self.completion {
            Some(d) => Self::norm_slack(d, completion),
            None => f64::INFINITY,
        };
        if let Some(t) = self.ttft {
            // lint: allow(nan-cmp) norm_slack returns -inf, never NaN, for degenerate bounds
            worst = worst.min(Self::norm_slack(t, ttft));
        }
        if let Some(b) = self.energy_budget_j {
            // lint: allow(nan-cmp) norm_slack returns -inf, never NaN, for degenerate bounds
            worst = worst.min(Self::norm_slack(b, energy_j));
        }
        worst
    }
}

/// KV bytes per cached context token: the per-token KV-cache footprint a
/// prefix transfer ships over a [`crate::sim::cluster::LinkSpec`]. A
/// 7B-class model at fp16 stores ~0.5 MB/token across layers; edge
/// deployments quantize and prune, so the sim uses 8 KiB/token — the
/// ratio (transfer vs recompute) is what matters, and it is exercised
/// across two orders of magnitude by the prefix-cache tests.
pub const KV_BYTES_PER_TOKEN: u64 = 8192;

/// Session (multi-turn conversation) identity carried by a request.
///
/// `prefix_tokens` is the KV-cacheable context prefix — everything the
/// conversation accumulated *before* this turn's new user tokens. A
/// server holding those KV tokens (see `sim::prefix::PrefixCache`) can
/// skip that prefix's prefill; any other server pays full prefill or a
/// KV transfer of `xfer_tokens * KV_BYTES_PER_TOKEN` bytes stamped by
/// the engine at dispatch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionRef {
    /// Stable conversation id (dense, from the session source).
    pub session_id: u64,
    /// 1-based turn index within the conversation.
    pub turn: u32,
    /// Reusable context prefix length in tokens (0 on turn 1).
    pub prefix_tokens: u32,
    /// KV tokens the engine decided to ship to the target server over
    /// the link (0 unless a transfer was judged economical). Stamped by
    /// the engine after placement; reset on requeue.
    pub xfer_tokens: u32,
}

impl SessionRef {
    /// Prefill tokens this turn can skip on a server holding `resident`
    /// KV tokens for the session. Prefix caches hold *prefixes*, so the
    /// target's resident tokens and a shipped transfer compose
    /// additively: the engine ships exactly the contiguous tail the
    /// target is missing, and what lands is `resident + xfer`, capped by
    /// the turn's actual prefix. Both substrates and the view-pricing
    /// path compute reuse through this one function so the accounting
    /// can never drift.
    #[inline]
    pub fn usable_prefix(&self, resident_tokens: u64) -> u32 {
        let avail = resident_tokens.saturating_add(self.xfer_tokens as u64);
        (self.prefix_tokens as u64).min(avail) as u32
    }

    /// Bytes a KV transfer of `tokens` context tokens ships over a link.
    #[inline]
    pub fn kv_bytes(tokens: u32) -> u64 {
        tokens as u64 * KV_BYTES_PER_TOKEN
    }
}

/// One inference service request (one "arm pull context" for the bandit).
#[derive(Debug, Clone)]
pub struct ServiceRequest {
    pub id: u64,
    pub class: ServiceClass,
    /// Arrival time at the router.
    pub arrival: SimTime,
    /// Prompt length in tokens.
    pub prompt_tokens: u32,
    /// Expected/decoded output length in tokens.
    pub output_tokens: u32,
    /// Personalized SLO contract (paper C1, generalized to a vector).
    pub slo: SloSpec,
    /// Upload payload in bytes (prompt + conversation context).
    pub payload_bytes: u64,
    /// Multi-turn conversation identity (`None` for single-shot
    /// requests — the entire pre-session pipeline).
    pub session: Option<SessionRef>,
}

impl ServiceRequest {
    /// Total token work (prefill is cheaper per token than decode; the
    /// server model weighs them via its own rates — this is just the sum
    /// used for throughput accounting).
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens as u64 + self.output_tokens as u64
    }
}

/// Outcome of one completed (or failed) service.
#[derive(Debug, Clone)]
pub struct ServiceOutcome {
    pub id: u64,
    pub class: ServiceClass,
    pub server: usize,
    /// Transmission (upload) time actually experienced.
    pub tx_time: SimTime,
    /// Queueing + inference time on the server.
    pub infer_time: SimTime,
    /// End-to-end processing time (tx + queue + inference).
    pub processing_time: SimTime,
    /// Realized time from arrival to first token (`+inf` when no token
    /// was ever produced: sheds, queue drops, and work still waiting for
    /// its first token at the horizon).
    pub ttft_time: SimTime,
    /// The SLO contract this outcome is judged against.
    pub slo: SloSpec,
    /// Energy attributed to this service (transmission + inference share), J.
    pub energy_j: f64,
    pub tokens: u64,
    pub completed_at: SimTime,
}

impl ServiceOutcome {
    /// Sentinel `server` value for requests shed at decision time: no
    /// server was involved, so there is no arm to credit or blame.
    /// Schedulers must check [`Self::was_shed`] before indexing per-server
    /// state with `outcome.server`.
    pub const SHED_SERVER: usize = usize::MAX;

    /// True when the scheduler rejected this request outright
    /// (`Action::Shed`) rather than placing it.
    pub fn was_shed(&self) -> bool {
        self.server == Self::SHED_SERVER
    }

    /// The canonical outcome for a request shed at decision time: no
    /// server, no energy spent, infinite processing time. Both substrates
    /// (DES engine, live router) build shed feedback through this one
    /// constructor so the [`Self::SHED_SERVER`] contract cannot drift.
    pub fn shed(req: &ServiceRequest, completed_at: SimTime) -> ServiceOutcome {
        ServiceOutcome {
            id: req.id,
            class: req.class,
            server: Self::SHED_SERVER,
            tx_time: 0.0,
            infer_time: 0.0,
            processing_time: f64::INFINITY,
            ttft_time: f64::INFINITY,
            slo: req.slo,
            energy_j: 0.0,
            tokens: 0,
            completed_at,
        }
    }

    /// Whether the completion constraint was met, if the contract has one.
    pub fn completion_met(&self) -> Option<bool> {
        self.slo.completion.map(|d| self.processing_time <= d)
    }

    /// Whether the TTFT constraint was met, if the contract has one.
    pub fn ttft_met(&self) -> Option<bool> {
        self.slo.ttft.map(|t| self.ttft_time <= t)
    }

    /// Whether the energy budget held, if the contract has one.
    pub fn energy_met(&self) -> Option<bool> {
        self.slo.energy_budget_j.map(|b| self.energy_j <= b)
    }

    /// Paper's success criterion, generalized: every present *timing*
    /// constraint holds (completion under D∆, first token under the TTFT
    /// bound). The energy budget is a price preference, not a timing SLO —
    /// it is reported via [`Self::energy_met`] and the engine's
    /// `slo_energy_violations`, but does not flip success (the paper's
    /// success rate stays a timing metric).
    ///
    /// A completion-only contract reduces to the historical
    /// `processing_time <= deadline`.
    pub fn success(&self) -> bool {
        self.completion_met().unwrap_or(true) && self.ttft_met().unwrap_or(true)
    }

    /// Normalized completion slack: (D∆ - D) / D∆, the C1 term of f(y)
    /// (Eq. 3). Compat for completion-bound contracts — when the contract
    /// has no completion constraint this falls back to [`Self::slo_slack`]
    /// so reward shaping never divides by a missing deadline.
    pub fn slack(&self) -> f64 {
        match self.slo.completion {
            Some(d) => SloSpec::norm_slack(d, self.processing_time),
            None => self.slo_slack(),
        }
    }

    /// Realized minimum normalized slack across the present constraints —
    /// the vector generalization of [`Self::slack`] that SLO-aware reward
    /// shaping (`CsUcbSlo`) consumes.
    pub fn slo_slack(&self) -> f64 {
        self.slo
            .min_slack(self.ttft_time, self.processing_time, self.energy_j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(processing: f64, deadline: f64) -> ServiceOutcome {
        ServiceOutcome {
            id: 1,
            class: ServiceClass::Chat,
            server: 0,
            tx_time: 0.1,
            infer_time: processing - 0.1,
            processing_time: processing,
            ttft_time: 0.2,
            slo: SloSpec::completion_only(deadline),
            energy_j: 10.0,
            tokens: 100,
            completed_at: processing,
        }
    }

    #[test]
    fn success_iff_within_deadline() {
        assert!(outcome(1.9, 2.0).success());
        assert!(outcome(2.0, 2.0).success());
        assert!(!outcome(2.01, 2.0).success());
    }

    #[test]
    fn slack_sign_matches_success() {
        assert!(outcome(1.0, 2.0).slack() > 0.0);
        assert!(outcome(3.0, 2.0).slack() < 0.0);
    }

    #[test]
    fn class_indices_distinct() {
        let mut seen = [false; 4];
        for c in ServiceClass::ALL {
            assert!(!seen[c.index()]);
            seen[c.index()] = true;
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    fn shed_sentinel_detected() {
        let mut o = outcome(1.0, 2.0);
        assert!(!o.was_shed());
        o.server = ServiceOutcome::SHED_SERVER;
        assert!(o.was_shed());
    }

    #[test]
    fn total_tokens_sums() {
        let r = ServiceRequest {
            id: 0,
            class: ServiceClass::Code,
            arrival: 0.0,
            prompt_tokens: 10,
            output_tokens: 32,
            slo: SloSpec::completion_only(4.0),
            payload_bytes: 1024,
            session: None,
        };
        assert_eq!(r.total_tokens(), 42);
        assert_eq!(r.slo.completion, Some(4.0));
    }

    #[test]
    fn default_slos_split_interactive_from_batch() {
        for c in [ServiceClass::Chat, ServiceClass::Translate] {
            let s = c.default_slo(4.0);
            assert!(s.ttft.is_some(), "{c:?} must be TTFT-bound");
            assert_eq!(s.completion, Some(4.0));
        }
        for c in [ServiceClass::Summarize, ServiceClass::Code] {
            let s = c.default_slo(5.0);
            assert!(s.ttft.is_none(), "{c:?} must be completion-bound only");
            assert!(s.is_completion_only());
        }
        // Chat is tighter on first token than translate.
        assert!(
            ServiceClass::Chat.default_ttft().unwrap()
                < ServiceClass::Translate.default_ttft().unwrap()
        );
    }

    /// A ttft-violated-but-completed request fails success() even though
    /// its completion constraint held — the per-constraint accessors tell
    /// the two families apart.
    #[test]
    fn ttft_violation_fails_success_independently() {
        let mut o = outcome(1.5, 2.0);
        o.slo = SloSpec::completion_only(2.0).with_ttft(0.1);
        o.ttft_time = 0.5; // first token too late
        assert_eq!(o.completion_met(), Some(true));
        assert_eq!(o.ttft_met(), Some(false));
        assert!(!o.success());
        // slo_slack is bound by the violated TTFT constraint.
        assert!(o.slo_slack() < 0.0);
        // compat slack still reads the completion constraint.
        assert!(o.slack() > 0.0);
    }

    #[test]
    fn energy_budget_reported_but_not_success() {
        let mut o = outcome(1.0, 2.0);
        o.slo = o.slo.with_energy_budget(5.0); // energy_j is 10.0
        assert_eq!(o.energy_met(), Some(false));
        assert!(o.success(), "energy is a price preference, not timing");
        assert!(o.slo_slack() < 0.0, "but the vector slack sees it");
    }

    #[test]
    fn absent_constraints_never_bind() {
        let mut o = outcome(100.0, 2.0);
        o.slo = SloSpec::ttft_only(1.0);
        o.ttft_time = 0.4;
        assert_eq!(o.completion_met(), None);
        assert!(o.success(), "no completion constraint to violate");
        assert_eq!(o.slo.completion, None);
        // compat slack falls back to the vector (ttft) slack.
        assert!((o.slack() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn zero_target_norm_slack_is_neg_inf_not_nan() {
        assert_eq!(SloSpec::norm_slack(0.0, 0.0), f64::NEG_INFINITY);
        assert_eq!(SloSpec::norm_slack(-1.0, 3.0), f64::NEG_INFINITY);
        assert!(SloSpec::norm_slack(2.0, 1.0) > 0.0);
    }

    #[test]
    fn min_slack_is_binding_constraint() {
        let s = SloSpec {
            ttft: Some(1.0),
            completion: Some(4.0),
            energy_budget_j: Some(100.0),
        };
        // completion slack 0.5, ttft slack 0.2, energy slack 0.9 → ttft binds.
        let m = s.min_slack(0.8, 2.0, 10.0);
        assert!((m - 0.2).abs() < 1e-12, "got {m}");
        // Empty contract is always satisfied.
        assert_eq!(SloSpec::default().min_slack(9.0, 9.0, 9.0), f64::INFINITY);
    }

    #[test]
    fn usable_prefix_caps_and_composes_sources() {
        let s = SessionRef {
            session_id: 7,
            turn: 3,
            prefix_tokens: 100,
            xfer_tokens: 0,
        };
        assert_eq!(s.usable_prefix(0), 0, "nothing resident, nothing shipped");
        assert_eq!(s.usable_prefix(60), 60, "partial residency reused as-is");
        assert_eq!(s.usable_prefix(500), 100, "reuse capped by the prefix");
        let shipped = SessionRef {
            xfer_tokens: 80,
            ..s
        };
        assert_eq!(shipped.usable_prefix(0), 80, "shipped tokens count");
        assert_eq!(
            shipped.usable_prefix(15),
            95,
            "resident head + shipped tail compose additively"
        );
        assert_eq!(shipped.usable_prefix(90), 100, "sum capped by the prefix");
        assert_eq!(SessionRef::kv_bytes(4), 4 * KV_BYTES_PER_TOKEN);
    }

    #[test]
    fn completion_only_min_slack_matches_scalar_formula() {
        let s = SloSpec::completion_only(3.0);
        let direct = (3.0f64 - 1.25) / 3.0;
        assert_eq!(
            s.min_slack(f64::NAN, 1.25, f64::NAN).to_bits(),
            direct.to_bits(),
            "completion-only vector slack must be the pre-PR5 C1 float"
        );
    }
}
