//! Service request model: the "diverse LLM services" of the paper.
//!
//! Each request carries a service class (chat, summarization, translation,
//! code — the diversity the paper's intro motivates), token counts, a
//! personalized processing-time requirement D∆ drawn from [2 s, 6 s]
//! (paper §4.2), and the upload payload implied by its prompt.

use crate::sim::time::SimTime;

/// Service classes with distinct token profiles and deadline sensitivity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServiceClass {
    /// Short prompt, short answer, tight deadline (interactive).
    Chat,
    /// Long prompt, short answer (long-text quality users, paper §1).
    Summarize,
    /// Medium prompt, medium answer.
    Translate,
    /// Medium prompt, long answer, loose deadline.
    Code,
}

impl ServiceClass {
    pub const ALL: [ServiceClass; 4] = [
        ServiceClass::Chat,
        ServiceClass::Summarize,
        ServiceClass::Translate,
        ServiceClass::Code,
    ];

    pub fn index(self) -> usize {
        match self {
            ServiceClass::Chat => 0,
            ServiceClass::Summarize => 1,
            ServiceClass::Translate => 2,
            ServiceClass::Code => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServiceClass::Chat => "chat",
            ServiceClass::Summarize => "summarize",
            ServiceClass::Translate => "translate",
            ServiceClass::Code => "code",
        }
    }
}

/// One inference service request (one "arm pull context" for the bandit).
#[derive(Debug, Clone)]
pub struct ServiceRequest {
    pub id: u64,
    pub class: ServiceClass,
    /// Arrival time at the router.
    pub arrival: SimTime,
    /// Prompt length in tokens.
    pub prompt_tokens: u32,
    /// Expected/decoded output length in tokens.
    pub output_tokens: u32,
    /// Personalized processing-time requirement D∆ (paper C1).
    pub deadline: SimTime,
    /// Upload payload in bytes (prompt + conversation context).
    pub payload_bytes: u64,
}

impl ServiceRequest {
    /// Total token work (prefill is cheaper per token than decode; the
    /// server model weighs them via its own rates — this is just the sum
    /// used for throughput accounting).
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens as u64 + self.output_tokens as u64
    }
}

/// Outcome of one completed (or failed) service.
#[derive(Debug, Clone)]
pub struct ServiceOutcome {
    pub id: u64,
    pub class: ServiceClass,
    pub server: usize,
    /// Transmission (upload) time actually experienced.
    pub tx_time: SimTime,
    /// Queueing + inference time on the server.
    pub infer_time: SimTime,
    /// End-to-end processing time (tx + queue + inference).
    pub processing_time: SimTime,
    pub deadline: SimTime,
    /// Energy attributed to this service (transmission + inference share), J.
    pub energy_j: f64,
    pub tokens: u64,
    pub completed_at: SimTime,
}

impl ServiceOutcome {
    /// Sentinel `server` value for requests shed at decision time: no
    /// server was involved, so there is no arm to credit or blame.
    /// Schedulers must check [`Self::was_shed`] before indexing per-server
    /// state with `outcome.server`.
    pub const SHED_SERVER: usize = usize::MAX;

    /// True when the scheduler rejected this request outright
    /// (`Action::Shed`) rather than placing it.
    pub fn was_shed(&self) -> bool {
        self.server == Self::SHED_SERVER
    }

    /// The canonical outcome for a request shed at decision time: no
    /// server, no energy spent, infinite processing time. Both substrates
    /// (DES engine, live router) build shed feedback through this one
    /// constructor so the [`Self::SHED_SERVER`] contract cannot drift.
    pub fn shed(req: &ServiceRequest, completed_at: SimTime) -> ServiceOutcome {
        ServiceOutcome {
            id: req.id,
            class: req.class,
            server: Self::SHED_SERVER,
            tx_time: 0.0,
            infer_time: 0.0,
            processing_time: f64::INFINITY,
            deadline: req.deadline,
            energy_j: 0.0,
            tokens: 0,
            completed_at,
        }
    }

    /// Paper's success criterion: processing time under the requirement.
    pub fn success(&self) -> bool {
        self.processing_time <= self.deadline
    }

    /// Normalized slack: (D∆ - D) / D∆, the C1 term of f(y) (Eq. 3).
    pub fn slack(&self) -> f64 {
        (self.deadline - self.processing_time) / self.deadline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(processing: f64, deadline: f64) -> ServiceOutcome {
        ServiceOutcome {
            id: 1,
            class: ServiceClass::Chat,
            server: 0,
            tx_time: 0.1,
            infer_time: processing - 0.1,
            processing_time: processing,
            deadline,
            energy_j: 10.0,
            tokens: 100,
            completed_at: processing,
        }
    }

    #[test]
    fn success_iff_within_deadline() {
        assert!(outcome(1.9, 2.0).success());
        assert!(outcome(2.0, 2.0).success());
        assert!(!outcome(2.01, 2.0).success());
    }

    #[test]
    fn slack_sign_matches_success() {
        assert!(outcome(1.0, 2.0).slack() > 0.0);
        assert!(outcome(3.0, 2.0).slack() < 0.0);
    }

    #[test]
    fn class_indices_distinct() {
        let mut seen = [false; 4];
        for c in ServiceClass::ALL {
            assert!(!seen[c.index()]);
            seen[c.index()] = true;
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    fn shed_sentinel_detected() {
        let mut o = outcome(1.0, 2.0);
        assert!(!o.was_shed());
        o.server = ServiceOutcome::SHED_SERVER;
        assert!(o.was_shed());
    }

    #[test]
    fn total_tokens_sums() {
        let r = ServiceRequest {
            id: 0,
            class: ServiceClass::Code,
            arrival: 0.0,
            prompt_tokens: 10,
            output_tokens: 32,
            deadline: 4.0,
            payload_bytes: 1024,
        };
        assert_eq!(r.total_tokens(), 42);
    }
}
