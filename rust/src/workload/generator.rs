//! Workload generation: reproducible traces of diverse LLM services.
//!
//! The paper evaluates 10,000 concurrent-ish service requests with
//! personalized deadlines drawn from [2 s, 6 s] (§4.2). We generate
//! Poisson or bursty arrival processes over a class mix with per-class
//! token-length distributions (log-normal, heavy-tailed like production
//! traces), all pinned to a seed so every bench row is reproducible.

use super::service::{ServiceClass, ServiceRequest, SloSpec};
use super::ArrivalSource;
use crate::util::rng::Rng;

/// How per-request SLO contracts are drawn.
///
/// `CompletionOnly` is the paper's workload: one uniform completion
/// deadline per request, nothing else — byte-identical to the pre-PR5
/// generator (same RNG stream, same draws). `PerClass` layers the class's
/// interactive constraints on top: classes whose [`ClassProfile`] carries
/// a `ttft` range (chat, translate by default) draw a TTFT bound, classes
/// with an `energy_budget_j` range draw a price ceiling. The extra draws
/// come from a **separate RNG stream** (seeded `seed ^ SLO_STREAM_SALT`),
/// so switching modes never shifts the arrival/class/token/deadline
/// sequence — the two modes produce field-identical requests except for
/// the added constraints (pinned by test).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloSampling {
    CompletionOnly,
    PerClass,
}

/// Seed salt for the SLO side-stream (see [`SloSampling`]).
const SLO_STREAM_SALT: u64 = 0x510_C0_47AC7;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson with the given rate (req/s).
    Poisson { rate: f64 },
    /// On/off bursts: `burst_rate` during bursts of `burst_len` seconds,
    /// `base_rate` otherwise, period `period` seconds. Models flash crowds.
    Bursty {
        base_rate: f64,
        burst_rate: f64,
        burst_len: f64,
        period: f64,
    },
    /// All requests arrive at t=0 (the paper's "simultaneous uploading of
    /// large-scale services" stress case, Fig. 2).
    Simultaneous,
}

/// Time-varying arrival-intensity modulation layered on any
/// [`ArrivalProcess`] (PR 6 chaos scenarios): each inter-arrival
/// increment is rescaled by the instantaneous intensity m(t) evaluated at
/// the previous arrival — `dt' = dt / m(t)` — a first-order,
/// thinning-free approximation of an inhomogeneous process (exact when
/// m is constant across the increment). The rescaling is deterministic
/// and consumes **zero** extra RNG draws, so [`ArrivalModulation::None`]
/// leaves the arrival stream bit-identical and every other field
/// (classes, tokens, SLOs) is untouched by construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalModulation {
    /// No modulation: the increment is used verbatim.
    None,
    /// Diurnal load curve: m(t) = 1 + amplitude · sin(2πt / period_s).
    /// `amplitude` must be in [0, 1) so the intensity stays positive.
    DiurnalSine { period_s: f64, amplitude: f64 },
    /// Flash crowd: m(t) = factor inside [at_s, at_s + duration_s),
    /// 1 outside — the demand spike the chaos scenarios pair with a
    /// mid-run crash.
    FlashCrowd {
        at_s: f64,
        duration_s: f64,
        factor: f64,
    },
}

impl ArrivalModulation {
    /// Reject nonsensical parameters with a panic. A modulation is
    /// experiment configuration; a typo should fail at construction, at
    /// every layer that accepts one ([`WorkloadConfig::with_modulation`],
    /// `MergedArrivals::with_modulations`).
    pub fn validate(&self) {
        match *self {
            ArrivalModulation::None => {}
            ArrivalModulation::DiurnalSine {
                period_s,
                amplitude,
            } => {
                assert!(period_s > 0.0, "diurnal period must be positive");
                assert!(
                    (0.0..1.0).contains(&amplitude),
                    "diurnal amplitude must be in [0, 1) to keep intensity positive"
                );
            }
            ArrivalModulation::FlashCrowd {
                at_s,
                duration_s,
                factor,
            } => {
                assert!(at_s >= 0.0 && duration_s >= 0.0, "flash crowd window invalid");
                assert!(
                    factor > 0.0 && factor.is_finite(),
                    "flash crowd factor must be positive and finite"
                );
            }
        }
    }

    /// Instantaneous intensity multiplier at time `t`.
    pub fn intensity(&self, t: f64) -> f64 {
        match *self {
            ArrivalModulation::None => 1.0,
            ArrivalModulation::DiurnalSine {
                period_s,
                amplitude,
            } => 1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin(),
            ArrivalModulation::FlashCrowd {
                at_s,
                duration_s,
                factor,
            } => {
                if t >= at_s && t < at_s + duration_s {
                    factor
                } else {
                    1.0
                }
            }
        }
    }
}

/// Per-class token profile: log-normal prompt/output lengths.
#[derive(Debug, Clone, Copy)]
pub struct ClassProfile {
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
    /// Deadline range [lo, hi] seconds for this class.
    pub deadline_lo: f64,
    pub deadline_hi: f64,
    /// TTFT-bound range [lo, hi] seconds, drawn under
    /// [`SloSampling::PerClass`]; `None` = the class carries no TTFT
    /// constraint (batch classes).
    pub ttft: Option<(f64, f64)>,
    /// Energy-budget range [lo, hi] joules, drawn under
    /// [`SloSampling::PerClass`]; `None` = no price ceiling.
    pub energy_budget_j: Option<(f64, f64)>,
    /// Mix weight (relative frequency).
    pub weight: f64,
}

impl ClassProfile {
    fn default_for(class: ServiceClass) -> ClassProfile {
        // Medians chosen so that prompt ~ exp(mu) tokens, output likewise.
        match class {
            ServiceClass::Chat => ClassProfile {
                prompt_mu: 3.9, // ~50 tokens
                prompt_sigma: 0.5,
                output_mu: 3.4, // ~30 tokens
                output_sigma: 0.5,
                deadline_lo: 2.0,
                deadline_hi: 4.0,
                // Tight first-token bound: a conversational turn stalls on
                // it. Satisfiable on an idle edge (~0.1 s TTFT), marginal
                // through the shared cloud uplink (~0.36 s idle, worse
                // under load) — exactly the tier split TTFT routing exploits.
                ttft: Some((0.35, 0.85)),
                energy_budget_j: None,
                weight: 0.4,
            },
            ServiceClass::Summarize => ClassProfile {
                prompt_mu: 5.5, // ~245 tokens
                prompt_sigma: 0.4,
                output_mu: 3.7, // ~40 tokens
                output_sigma: 0.4,
                deadline_lo: 3.0,
                deadline_hi: 6.0,
                ttft: None, // batch class: completion-bound
                energy_budget_j: None,
                weight: 0.2,
            },
            ServiceClass::Translate => ClassProfile {
                prompt_mu: 4.6, // ~100 tokens
                prompt_sigma: 0.4,
                output_mu: 4.1, // ~60 tokens
                output_sigma: 0.4,
                deadline_lo: 2.0,
                deadline_hi: 5.0,
                ttft: Some((0.7, 1.5)), // interactive, looser than chat
                energy_budget_j: None,
                weight: 0.25,
            },
            ServiceClass::Code => ClassProfile {
                prompt_mu: 4.4, // ~80 tokens
                prompt_sigma: 0.6,
                output_mu: 4.5, // ~90 tokens
                output_sigma: 0.5,
                deadline_lo: 3.0,
                deadline_hi: 6.0, // loosest completion: nobody reads it live
                ttft: None,
                energy_budget_j: None,
                weight: 0.15,
            },
        }
    }
}

/// Full workload description.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    pub arrivals: ArrivalProcess,
    /// Time-varying intensity layered on `arrivals` (default: none,
    /// bit-identical to the unmodulated stream).
    pub modulation: ArrivalModulation,
    pub seed: u64,
    /// How SLO contracts are drawn (default: the paper's completion-only
    /// scalar, byte-identical to the pre-PR5 stream).
    pub slo: SloSampling,
    pub profiles: [ClassProfile; 4],
    /// Payload model: fixed header + per-prompt-token context bytes.
    pub payload_base_bytes: u64,
    pub payload_bytes_per_token: u64,
    /// Cap on token lengths (keeps the heavy tail inside model max_seq).
    pub max_prompt_tokens: u32,
    pub max_output_tokens: u32,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 10_000,
            arrivals: ArrivalProcess::Poisson { rate: 15.0 },
            modulation: ArrivalModulation::None,
            seed: 0x9E11,
            slo: SloSampling::CompletionOnly,
            profiles: [
                ClassProfile::default_for(ServiceClass::Chat),
                ClassProfile::default_for(ServiceClass::Summarize),
                ClassProfile::default_for(ServiceClass::Translate),
                ClassProfile::default_for(ServiceClass::Code),
            ],
            payload_base_bytes: 65_536,
            payload_bytes_per_token: 4096,
            max_prompt_tokens: 1024,
            max_output_tokens: 512,
        }
    }
}

impl WorkloadConfig {
    pub fn with_requests(mut self, n: usize) -> Self {
        self.n_requests = n;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_arrivals(mut self, a: ArrivalProcess) -> Self {
        self.arrivals = a;
        self
    }

    /// Layer a time-varying intensity over the arrival process (see
    /// [`ArrivalModulation`]). Panics on nonsensical parameters — a
    /// modulation is experiment configuration and a typo should fail at
    /// construction.
    pub fn with_modulation(mut self, m: ArrivalModulation) -> Self {
        m.validate();
        self.modulation = m;
        self
    }

    /// Poisson arrivals at `rate` req/s — the common case, and the knob
    /// topology-scaled runs turn (`TopologyConfig::scaled_rate`): one
    /// workload description per tier, each at its own capacity-matched
    /// rate, merged with `workload::MergedArrivals`.
    pub fn with_rate(self, rate: f64) -> Self {
        self.with_arrivals(ArrivalProcess::Poisson { rate })
    }

    /// Uniform deadline range override for every class (paper: U[2, 6] s).
    pub fn with_deadline_range(mut self, lo: f64, hi: f64) -> Self {
        for p in &mut self.profiles {
            p.deadline_lo = lo;
            p.deadline_hi = hi;
        }
        self
    }

    /// Select the SLO sampling mode (see [`SloSampling`]).
    pub fn with_slo_sampling(mut self, slo: SloSampling) -> Self {
        self.slo = slo;
        self
    }

    /// Shorthand: class-conditioned SLO vectors — chat/translate draw
    /// TTFT bounds from their profile ranges, summarize/code stay
    /// completion-bound. Non-SLO fields (arrivals, classes, tokens,
    /// completion deadlines) remain byte-identical to the
    /// completion-only stream.
    pub fn with_per_class_slos(self) -> Self {
        self.with_slo_sampling(SloSampling::PerClass)
    }

    /// Override one class's TTFT-bound range (drawn under
    /// [`SloSampling::PerClass`]); `None` removes the constraint.
    pub fn with_ttft_range(mut self, class: ServiceClass, range: Option<(f64, f64)>) -> Self {
        self.profiles[class.index()].ttft = range;
        self
    }

    /// Override one class's energy-budget range in joules (drawn under
    /// [`SloSampling::PerClass`]); `None` removes the ceiling.
    pub fn with_energy_budget_range(
        mut self,
        class: ServiceClass,
        range: Option<(f64, f64)>,
    ) -> Self {
        self.profiles[class.index()].energy_budget_j = range;
        self
    }

    /// Override the class mix weights, in [`ServiceClass::ALL`] order
    /// (Chat, Summarize, Translate, Code). Relative frequencies — they
    /// need not sum to 1. This is the per-tier knob behind
    /// `paper_scale_sim --mix tiered`: one `WorkloadConfig` per tier,
    /// each with its own locality-shaped mix, merged through
    /// `workload::MergedArrivals`.
    pub fn with_class_weights(mut self, weights: [f64; 4]) -> Self {
        for (p, w) in self.profiles.iter_mut().zip(weights) {
            p.weight = w;
        }
        self
    }
}

/// Streaming workload cursor: draws one request at a time from the same
/// RNG sequence `generate` uses, so `WorkloadGen::new(&cfg)` yields
/// exactly the trace `generate(&cfg)` materializes — request for request
/// — without ever holding the whole trace in memory. This is the
/// [`ArrivalSource`] the DES consumes for million-request runs.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    rng: Rng,
    /// Side-stream for SLO-vector draws (TTFT bounds, energy budgets):
    /// independent of `rng`, so [`SloSampling::PerClass`] adds constraints
    /// without shifting the arrival/class/token/deadline sequence.
    slo_rng: Rng,
    t: f64,
    emitted: usize,
    wsum: f64,
}

impl WorkloadGen {
    pub fn new(cfg: &WorkloadConfig) -> Self {
        WorkloadGen {
            rng: Rng::new(cfg.seed), // lint: allow(raw-seed) the generator owns the primary arrival stream; side-streams salt off it
            slo_rng: Rng::new(cfg.seed ^ SLO_STREAM_SALT),
            t: 0.0,
            emitted: 0,
            wsum: cfg.profiles.iter().map(|p| p.weight).sum(),
            cfg: cfg.clone(),
        }
    }
}

impl ArrivalSource for WorkloadGen {
    fn next_arrival(&mut self) -> Option<ServiceRequest> {
        if self.emitted >= self.cfg.n_requests {
            return None;
        }
        let id = self.emitted as u64;
        self.emitted += 1;
        let t_next = next_arrival(&self.cfg.arrivals, self.t, &mut self.rng);
        self.t = if self.cfg.modulation == ArrivalModulation::None {
            // Verbatim, not `dt / 1.0`: re-deriving the increment from the
            // absolute times is not float-exact, and the unmodulated
            // stream must stay bit-identical.
            t_next
        } else {
            let m = self.cfg.modulation.intensity(self.t);
            self.t + (t_next - self.t) / m
        };
        // Class by weighted draw.
        let mut u = self.rng.f64() * self.wsum;
        let mut class = ServiceClass::Chat;
        for (i, c) in ServiceClass::ALL.iter().enumerate() {
            u -= self.cfg.profiles[i].weight;
            if u <= 0.0 {
                class = *c;
                break;
            }
        }
        let p = self.cfg.profiles[class.index()];
        let prompt = self
            .rng
            .lognormal(p.prompt_mu, p.prompt_sigma)
            .round()
            .clamp(1.0, self.cfg.max_prompt_tokens as f64) as u32;
        let output = self
            .rng
            .lognormal(p.output_mu, p.output_sigma)
            .round()
            .clamp(1.0, self.cfg.max_output_tokens as f64) as u32;
        let deadline = self.rng.uniform(p.deadline_lo, p.deadline_hi);
        let mut slo = SloSpec::completion_only(deadline);
        if self.cfg.slo == SloSampling::PerClass {
            // Side-stream draws only: the main sequence above is
            // byte-identical across sampling modes.
            if let Some((lo, hi)) = p.ttft {
                slo.ttft = Some(self.slo_rng.uniform(lo, hi));
            }
            if let Some((lo, hi)) = p.energy_budget_j {
                slo.energy_budget_j = Some(self.slo_rng.uniform(lo, hi));
            }
        }
        Some(ServiceRequest {
            id,
            class,
            arrival: self.t,
            prompt_tokens: prompt,
            output_tokens: output,
            slo,
            payload_bytes: self.cfg.payload_base_bytes
                + prompt as u64 * self.cfg.payload_bytes_per_token,
            session: None,
        })
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.cfg.n_requests - self.emitted)
    }
}

impl Iterator for WorkloadGen {
    type Item = ServiceRequest;

    fn next(&mut self) -> Option<ServiceRequest> {
        self.next_arrival()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.cfg.n_requests - self.emitted;
        (n, Some(n))
    }
}

/// Generate the full trace, sorted by arrival time, ids dense from 0.
/// Materializing wrapper around [`WorkloadGen`]; million-request runs
/// should stream the generator through the engine instead.
pub fn generate(cfg: &WorkloadConfig) -> Vec<ServiceRequest> {
    WorkloadGen::new(cfg).collect()
}

fn next_arrival(process: &ArrivalProcess, t: f64, rng: &mut Rng) -> f64 {
    match *process {
        ArrivalProcess::Poisson { rate } => t + rng.exp(rate),
        ArrivalProcess::Simultaneous => 0.0,
        ArrivalProcess::Bursty {
            base_rate,
            burst_rate,
            burst_len,
            period,
        } => {
            let phase = t % period;
            let rate = if phase < burst_len { burst_rate } else { base_rate };
            t + rng.exp(rate)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_sorted() {
        let cfg = WorkloadConfig::default().with_requests(500);
        let trace = generate(&cfg);
        assert_eq!(trace.len(), 500);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(trace.iter().enumerate().all(|(i, r)| r.id == i as u64));
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default().with_requests(100).with_seed(9);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.slo, y.slo);
        }
        let c = generate(&cfg.clone().with_seed(10));
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt_tokens != y.prompt_tokens));
    }

    #[test]
    fn deadlines_in_configured_range() {
        let cfg = WorkloadConfig::default()
            .with_requests(2000)
            .with_deadline_range(2.0, 6.0);
        for r in generate(&cfg) {
            let d = r.slo.completion.expect("scalar mode sets completion");
            assert!((2.0..=6.0).contains(&d), "d={d}");
            assert!(r.slo.is_completion_only(), "default mode is scalar");
        }
    }

    #[test]
    fn token_caps_respected() {
        let mut cfg = WorkloadConfig::default().with_requests(3000);
        cfg.max_prompt_tokens = 100;
        cfg.max_output_tokens = 64;
        for r in generate(&cfg) {
            assert!(r.prompt_tokens >= 1 && r.prompt_tokens <= 100);
            assert!(r.output_tokens >= 1 && r.output_tokens <= 64);
        }
    }

    #[test]
    fn with_rate_is_poisson_shorthand() {
        let cfg = WorkloadConfig::default().with_rate(42.0);
        assert_eq!(cfg.arrivals, ArrivalProcess::Poisson { rate: 42.0 });
    }

    #[test]
    fn class_weights_shape_the_mix() {
        // All weight on Code: every request draws that class.
        let cfg = WorkloadConfig::default()
            .with_requests(300)
            .with_class_weights([0.0, 0.0, 0.0, 1.0]);
        assert!(generate(&cfg)
            .iter()
            .all(|r| r.class == ServiceClass::Code));
        // Skewed weights skew the empirical mix.
        let cfg = WorkloadConfig::default()
            .with_requests(4000)
            .with_class_weights([0.8, 0.1, 0.05, 0.05])
            .with_seed(4);
        let trace = generate(&cfg);
        let chat = trace.iter().filter(|r| r.class == ServiceClass::Chat).count();
        assert!(chat > trace.len() / 2, "chat {} of {}", chat, trace.len());
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let cfg = WorkloadConfig::default()
            .with_requests(20_000)
            .with_arrivals(ArrivalProcess::Poisson { rate: 100.0 });
        let trace = generate(&cfg);
        let span = trace.last().unwrap().arrival;
        let rate = trace.len() as f64 / span;
        assert!((rate - 100.0).abs() < 5.0, "rate={rate}");
    }

    #[test]
    fn simultaneous_all_at_zero() {
        let cfg = WorkloadConfig::default()
            .with_requests(50)
            .with_arrivals(ArrivalProcess::Simultaneous);
        assert!(generate(&cfg).iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn all_classes_present() {
        let cfg = WorkloadConfig::default().with_requests(1000);
        let trace = generate(&cfg);
        for c in ServiceClass::ALL {
            assert!(trace.iter().any(|r| r.class == c), "missing {c:?}");
        }
    }

    #[test]
    fn streaming_generator_matches_materialized_trace() {
        let cfg = WorkloadConfig::default().with_requests(300).with_seed(77);
        let trace = generate(&cfg);
        let mut stream = WorkloadGen::new(&cfg);
        assert_eq!(stream.len_hint(), Some(300));
        for want in &trace {
            let got = stream.next_arrival().expect("request");
            assert_eq!(got.id, want.id);
            assert_eq!(got.arrival, want.arrival);
            assert_eq!(got.class, want.class);
            assert_eq!(got.prompt_tokens, want.prompt_tokens);
            assert_eq!(got.output_tokens, want.output_tokens);
            assert_eq!(got.slo, want.slo);
            assert_eq!(got.payload_bytes, want.payload_bytes);
        }
        assert!(stream.next_arrival().is_none());
        assert_eq!(stream.len_hint(), Some(0));
    }

    /// The class-conditioned SLO mode draws from a *separate* RNG stream:
    /// every non-SLO field — arrival instants, classes, token lengths,
    /// payloads, and the completion deadline itself — is bit-identical to
    /// the completion-only stream; only the constraint vector grows.
    #[test]
    fn per_class_mode_only_adds_constraints() {
        let base = WorkloadConfig::default().with_requests(800).with_seed(31);
        let scalar = generate(&base);
        let vector = generate(&base.clone().with_per_class_slos());
        assert_eq!(scalar.len(), vector.len());
        for (s, v) in scalar.iter().zip(&vector) {
            assert_eq!(s.id, v.id);
            assert_eq!(s.arrival.to_bits(), v.arrival.to_bits());
            assert_eq!(s.class, v.class);
            assert_eq!(s.prompt_tokens, v.prompt_tokens);
            assert_eq!(s.output_tokens, v.output_tokens);
            assert_eq!(s.payload_bytes, v.payload_bytes);
            assert_eq!(
                s.slo.completion.unwrap().to_bits(),
                v.slo.completion.unwrap().to_bits(),
                "completion draw moved between modes"
            );
            assert!(s.slo.is_completion_only());
            // Interactive classes gained a TTFT bound inside the profile
            // range; batch classes stayed scalar.
            match v.class {
                ServiceClass::Chat | ServiceClass::Translate => {
                    let (lo, hi) = base.profiles[v.class.index()].ttft.unwrap();
                    let t = v.slo.ttft.expect("interactive class is TTFT-bound");
                    assert!((lo..=hi).contains(&t), "ttft {t} outside [{lo}, {hi}]");
                }
                ServiceClass::Summarize | ServiceClass::Code => {
                    assert!(v.slo.is_completion_only());
                }
            }
            assert!(v.slo.energy_budget_j.is_none(), "no default price ceiling");
        }
    }

    /// Bit-determinism of the new side-stream draws: same seed, same SLO
    /// vectors to the bit; different seed, different TTFT draws.
    #[test]
    fn slo_side_stream_deterministic_per_seed() {
        let cfg = WorkloadConfig::default()
            .with_requests(400)
            .with_seed(77)
            .with_per_class_slos();
        let a = generate(&cfg);
        let b = generate(&cfg);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.slo, y.slo);
            if let (Some(tx), Some(ty)) = (x.slo.ttft, y.slo.ttft) {
                assert_eq!(tx.to_bits(), ty.to_bits());
            }
        }
        let c = generate(&cfg.clone().with_seed(78));
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.slo.ttft.map(f64::to_bits)
                != y.slo.ttft.map(f64::to_bits)),
            "TTFT draws must depend on the seed"
        );
    }

    /// Per-class overrides: TTFT ranges can be reshaped or removed and
    /// energy budgets added, per class.
    #[test]
    fn slo_range_overrides_apply() {
        let cfg = WorkloadConfig::default()
            .with_requests(600)
            .with_seed(5)
            .with_per_class_slos()
            .with_ttft_range(ServiceClass::Chat, Some((0.1, 0.2)))
            .with_ttft_range(ServiceClass::Translate, None)
            .with_energy_budget_range(ServiceClass::Code, Some((50.0, 120.0)));
        for r in generate(&cfg) {
            match r.class {
                ServiceClass::Chat => {
                    let t = r.slo.ttft.unwrap();
                    assert!((0.1..=0.2).contains(&t), "ttft {t}");
                }
                ServiceClass::Translate => assert!(r.slo.ttft.is_none()),
                ServiceClass::Code => {
                    let b = r.slo.energy_budget_j.unwrap();
                    assert!((50.0..=120.0).contains(&b), "budget {b}");
                }
                ServiceClass::Summarize => assert!(r.slo.is_completion_only()),
            }
        }
    }

    /// A flash crowd compresses inter-arrival gaps inside its window:
    /// the window holds roughly `factor`× the unmodulated arrival count,
    /// and the stream stays sorted.
    #[test]
    fn flash_crowd_compresses_arrivals_inside_the_window() {
        let base = WorkloadConfig::default()
            .with_requests(2000)
            .with_arrivals(ArrivalProcess::Poisson { rate: 10.0 })
            .with_seed(17);
        let plain = generate(&base);
        let crowd = generate(&base.clone().with_modulation(ArrivalModulation::FlashCrowd {
            at_s: 50.0,
            duration_s: 10.0,
            factor: 5.0,
        }));
        assert!(crowd.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let in_window = |t: &[ServiceRequest]| {
            t.iter()
                .filter(|r| (50.0..60.0).contains(&r.arrival))
                .count()
        };
        let (p, c) = (in_window(&plain), in_window(&crowd));
        assert!(
            c > 2 * p,
            "flash crowd must pack the window: {c} vs {p} plain"
        );
    }

    /// Diurnal modulation shifts density toward the positive half of the
    /// sine without breaking monotonicity; `None` stays the verbatim
    /// (bit-identical) stream.
    #[test]
    fn diurnal_sine_shapes_density_and_none_is_verbatim() {
        let base = WorkloadConfig::default()
            .with_requests(2000)
            .with_arrivals(ArrivalProcess::Poisson { rate: 10.0 })
            .with_seed(23);
        let sine = generate(&base.clone().with_modulation(ArrivalModulation::DiurnalSine {
            period_s: 100.0,
            amplitude: 0.8,
        }));
        assert!(sine.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let count = |lo: f64, hi: f64| {
            sine.iter()
                .filter(|r| (lo..hi).contains(&r.arrival))
                .count()
        };
        let (peak, trough) = (count(0.0, 50.0), count(50.0, 100.0));
        assert!(
            peak > 2 * trough,
            "sine peak half-period must be denser: {peak} vs {trough}"
        );
        // Explicit None is the same code path as the default: verbatim.
        let a = generate(&base);
        let b = generate(&base.clone().with_modulation(ArrivalModulation::None));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "amplitude")]
    fn diurnal_amplitude_of_one_is_rejected() {
        let _ = WorkloadConfig::default().with_modulation(ArrivalModulation::DiurnalSine {
            period_s: 60.0,
            amplitude: 1.0,
        });
    }

    #[test]
    fn bursty_arrivals_monotone() {
        let cfg = WorkloadConfig::default()
            .with_requests(1000)
            .with_arrivals(ArrivalProcess::Bursty {
                base_rate: 20.0,
                burst_rate: 400.0,
                burst_len: 1.0,
                period: 10.0,
            });
        let trace = generate(&cfg);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }
}
