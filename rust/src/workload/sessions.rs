//! Multi-turn conversation workloads (PR 10, ROADMAP item 3).
//!
//! PerLLM evaluates i.i.d. single requests, but "millions of users" means
//! *sessions*: chains of turns whose context grows monotonically, making
//! prefill the dominant cost and KV-prefix reuse (edge-inference survey,
//! arXiv:2604.22906) the dominant lever. [`SessionSource`] is the
//! [`ArrivalSource`] that generates those chains.
//!
//! # Determinism contract
//!
//! Every draw comes from **one** RNG seeded `seed ^ SESSION_STREAM_SALT`
//! — a side-stream of the workload seed — so enabling sessions can never
//! shift the single-turn generator's sequence (`WorkloadGen` does not
//! change at all; sessions are a *separate* source). A whole chain is
//! materialized at its session-start instant in one fixed draw order
//! (class, turn count, then per-turn tokens/SLO/think-gap), so the
//! stream is bit-reproducible regardless of how chains interleave.
//!
//! # Chain shape
//!
//! Turn `k`'s prompt is the conversation so far plus the new user
//! tokens: `prompt(k) = context(k) + new_user(k)` with
//! `context(k+1) = prompt(k) + output(k)` (capped by the config's
//! `max_prompt_tokens`). `context(k)` is exactly the KV-reusable prefix
//! carried as [`SessionRef::prefix_tokens`] — a server still holding the
//! session's KV tokens skips that much prefill (`sim::prefix`).
//! Think-time gaps between turns are log-normal, clamped positive, so
//! per-session turn order is strict even after id-relabeling merges
//! (`MergedArrivals`).

use super::generator::{SloSampling, WorkloadConfig};
use super::service::{ServiceClass, ServiceRequest, SessionRef, SloSpec};
use super::ArrivalSource;
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Seed salt for the session side-stream: the conversation workload draws
/// from `seed ^ SESSION_STREAM_SALT`, never from the primary stream.
pub const SESSION_STREAM_SALT: u64 = 0x5E55_10C4_57A1;

/// Per-class session shape: how many turns a conversation runs and how
/// long the user thinks between them.
#[derive(Debug, Clone, Copy)]
pub struct SessionProfile {
    /// Inclusive turn-count range [lo, hi].
    pub turns_lo: u32,
    pub turns_hi: u32,
    /// Log-normal think-time parameters (seconds between turns).
    pub think_mu: f64,
    pub think_sigma: f64,
}

impl SessionProfile {
    fn default_for(class: ServiceClass) -> SessionProfile {
        match class {
            // Chat is the session workload: long back-and-forth chains
            // with short gaps — the KV-affinity case.
            ServiceClass::Chat => SessionProfile {
                turns_lo: 3,
                turns_hi: 8,
                think_mu: 1.8, // ~6 s median
                think_sigma: 0.6,
            },
            // Long-text users rarely follow up.
            ServiceClass::Summarize => SessionProfile {
                turns_lo: 1,
                turns_hi: 2,
                think_mu: 2.5, // ~12 s median
                think_sigma: 0.5,
            },
            ServiceClass::Translate => SessionProfile {
                turns_lo: 2,
                turns_hi: 4,
                think_mu: 2.0,
                think_sigma: 0.5,
            },
            // Iterating on generated code: fewer, slower turns.
            ServiceClass::Code => SessionProfile {
                turns_lo: 2,
                turns_hi: 5,
                think_mu: 3.0, // ~20 s median
                think_sigma: 0.6,
            },
        }
    }
}

/// Session workload description: a [`WorkloadConfig`] (token profiles,
/// class mix, payload model, SLO sampling — `n_requests` counts *turns*)
/// plus the conversation-shape knobs.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub base: WorkloadConfig,
    /// Session starts per second (Poisson).
    pub session_rate: f64,
    /// Per-class conversation shapes, [`ServiceClass::ALL`] order.
    pub sessions: [SessionProfile; 4],
    /// Think-time clamp [lo, hi] seconds — keeps the log-normal tail
    /// from parking a turn past the horizon.
    pub think_clamp: (f64, f64),
}

impl SessionConfig {
    /// Derive a session workload from a single-turn config, holding the
    /// *turn* volume roughly equal: the session-start rate is the base
    /// arrival rate divided by the mix's mean turn count, so a
    /// `--sessions` run drives comparable load through the fleet.
    pub fn from_workload(base: WorkloadConfig) -> SessionConfig {
        let sessions = [
            SessionProfile::default_for(ServiceClass::Chat),
            SessionProfile::default_for(ServiceClass::Summarize),
            SessionProfile::default_for(ServiceClass::Translate),
            SessionProfile::default_for(ServiceClass::Code),
        ];
        let mut wsum = 0.0;
        let mut mean_turns = 0.0;
        for (p, s) in base.profiles.iter().zip(&sessions) {
            wsum += p.weight;
            mean_turns += p.weight * (s.turns_lo + s.turns_hi) as f64 * 0.5;
        }
        let mean_turns = if wsum > 0.0 { mean_turns / wsum } else { 1.0 };
        let rate = match base.arrivals {
            super::generator::ArrivalProcess::Poisson { rate } => rate,
            // Sessions need a spread-out start process; non-Poisson base
            // shapes fall back to the default request rate.
            _ => 15.0,
        };
        SessionConfig {
            base,
            session_rate: (rate / mean_turns).max(1e-6),
            sessions,
            think_clamp: (0.5, 120.0),
        }
    }

    pub fn with_session_rate(mut self, rate: f64) -> SessionConfig {
        self.session_rate = rate;
        self
    }
}

/// One materialized future turn waiting in the chain heap. Ordered by
/// (time, session, turn) — f64 times are finite by construction, compared
/// via `total_cmp`, so the pop order is fully deterministic.
#[derive(Debug, Clone)]
struct PendingTurn {
    at: f64,
    session_id: u64,
    turn: u32,
    class: ServiceClass,
    prompt_tokens: u32,
    output_tokens: u32,
    prefix_tokens: u32,
    slo: SloSpec,
}

impl PartialEq for PendingTurn {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for PendingTurn {}
impl PartialOrd for PendingTurn {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingTurn {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.session_id.cmp(&other.session_id))
            .then(self.turn.cmp(&other.turn))
    }
}

/// Streaming conversation-chain generator. See the module docs for the
/// determinism and chain-shape contracts.
pub struct SessionSource {
    cfg: SessionConfig,
    rng: Rng,
    /// Future turns of already-started sessions (min-heap by time).
    pending: BinaryHeap<Reverse<PendingTurn>>,
    /// Next session-start instant (prefetched; the start gap is drawn
    /// when the previous session is materialized).
    next_start: f64,
    wsum: f64,
    emitted: usize,
    next_session: u64,
}

impl SessionSource {
    pub fn new(cfg: &SessionConfig) -> SessionSource {
        let mut rng = Rng::new(cfg.base.seed ^ SESSION_STREAM_SALT);
        let first = rng.exp(cfg.session_rate);
        SessionSource {
            rng,
            pending: BinaryHeap::new(),
            next_start: first,
            wsum: cfg.base.profiles.iter().map(|p| p.weight).sum(),
            emitted: 0,
            next_session: 0,
            cfg: cfg.clone(),
        }
    }

    /// Materialize a whole chain at its start instant: one fixed draw
    /// order per session, so interleaving never shifts the stream. The
    /// first turn is returned, later turns go to the heap.
    fn start_session(&mut self) -> PendingTurn {
        let t0 = self.next_start;
        let sid = self.next_session;
        self.next_session += 1;

        // Class by weighted draw (same scheme as WorkloadGen, own stream).
        let mut u = self.rng.f64() * self.wsum;
        let mut class = ServiceClass::Chat;
        for (i, c) in ServiceClass::ALL.iter().enumerate() {
            u -= self.cfg.base.profiles[i].weight;
            if u <= 0.0 {
                class = *c;
                break;
            }
        }
        let p = self.cfg.base.profiles[class.index()];
        let sp = self.cfg.sessions[class.index()];
        let n_turns = self
            .rng
            .range_i64(sp.turns_lo.max(1) as i64, sp.turns_hi.max(1) as i64)
            as u32;

        let mut first = None;
        let mut at = t0;
        // Conversation context accumulated before the current turn — the
        // KV-reusable prefix.
        let mut context: u32 = 0;
        for turn in 1..=n_turns {
            let new_user = self
                .rng
                .lognormal(p.prompt_mu, p.prompt_sigma)
                .round()
                .clamp(1.0, self.cfg.base.max_prompt_tokens as f64)
                as u32;
            let prompt = (context.saturating_add(new_user))
                .clamp(1, self.cfg.base.max_prompt_tokens);
            let output = self
                .rng
                .lognormal(p.output_mu, p.output_sigma)
                .round()
                .clamp(1.0, self.cfg.base.max_output_tokens as f64)
                as u32;
            let deadline = self.rng.uniform(p.deadline_lo, p.deadline_hi);
            let mut slo = SloSpec::completion_only(deadline);
            if self.cfg.base.slo == SloSampling::PerClass {
                if let Some((lo, hi)) = p.ttft {
                    slo.ttft = Some(self.rng.uniform(lo, hi));
                }
                if let Some((lo, hi)) = p.energy_budget_j {
                    slo.energy_budget_j = Some(self.rng.uniform(lo, hi));
                }
            }
            let pt = PendingTurn {
                at,
                session_id: sid,
                turn,
                class,
                prompt_tokens: prompt,
                output_tokens: output,
                // The prefix can never exceed the (capped) prompt.
                prefix_tokens: context.min(prompt),
                slo,
            };
            if turn == 1 {
                first = Some(pt);
            } else {
                self.pending.push(Reverse(pt));
            }
            // Grow the context deterministically from the chain state
            // (never a fresh i.i.d. draw — the PR-10 bugfix guard).
            context = prompt
                .saturating_add(output)
                .min(self.cfg.base.max_prompt_tokens);
            if turn < n_turns {
                let (lo, hi) = self.cfg.think_clamp;
                let gap = self.rng.lognormal(sp.think_mu, sp.think_sigma).clamp(lo, hi);
                at += gap;
            }
        }

        // Prefetch the next session start (nondecreasing by construction).
        self.next_start = t0 + self.rng.exp(self.cfg.session_rate);
        first.expect("n_turns >= 1")
    }

    fn emit(&mut self, pt: PendingTurn) -> ServiceRequest {
        let id = self.emitted as u64;
        self.emitted += 1;
        ServiceRequest {
            id,
            class: pt.class,
            arrival: pt.at,
            prompt_tokens: pt.prompt_tokens,
            output_tokens: pt.output_tokens,
            slo: pt.slo,
            payload_bytes: self.cfg.base.payload_base_bytes
                + pt.prompt_tokens as u64 * self.cfg.base.payload_bytes_per_token,
            session: Some(SessionRef {
                session_id: pt.session_id,
                turn: pt.turn,
                prefix_tokens: pt.prefix_tokens,
                xfer_tokens: 0,
            }),
        }
    }
}

impl ArrivalSource for SessionSource {
    fn next_arrival(&mut self) -> Option<ServiceRequest> {
        if self.emitted >= self.cfg.base.n_requests {
            return None;
        }
        // Earlier of: the next pending turn, the next session start.
        // Ties prefer the pending turn (older session), deterministically.
        let take_pending = self
            .pending
            .peek()
            .is_some_and(|Reverse(pt)| pt.at <= self.next_start);
        let pt = if take_pending {
            self.pending.pop().expect("peeked").0
        } else {
            self.start_session()
        };
        Some(self.emit(pt))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.cfg.base.n_requests - self.emitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize, seed: u64) -> SessionConfig {
        SessionConfig::from_workload(
            WorkloadConfig::default().with_requests(n).with_seed(seed),
        )
    }

    fn collect(c: &SessionConfig) -> Vec<ServiceRequest> {
        let mut src = SessionSource::new(c);
        let mut out = Vec::new();
        while let Some(r) = src.next_arrival() {
            out.push(r);
        }
        out
    }

    #[test]
    fn stream_is_sorted_dense_and_sized() {
        let c = cfg(500, 3);
        let mut src = SessionSource::new(&c);
        assert_eq!(src.len_hint(), Some(500));
        let trace = collect(&c);
        assert_eq!(trace.len(), 500);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(trace.iter().enumerate().all(|(i, r)| r.id == i as u64));
        assert!(trace.iter().all(|r| r.session.is_some()));
        let _ = src.next_arrival();
        assert_eq!(src.len_hint(), Some(499));
    }

    #[test]
    fn deterministic_per_seed_and_seed_sensitive() {
        let a = collect(&cfg(400, 9));
        let b = collect(&cfg(400, 9));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.session, y.session);
        }
        let c = collect(&cfg(400, 10));
        assert!(a
            .iter()
            .zip(&c)
            .any(|(x, y)| x.arrival.to_bits() != y.arrival.to_bits()));
    }

    /// Context growth is the chain recurrence, not an i.i.d. redraw:
    /// within a session, turn k+1's prefix equals min(prompt_k +
    /// output_k, cap), prefixes are nondecreasing, turn 1 has none, and
    /// think gaps are strictly positive.
    #[test]
    fn chains_grow_context_deterministically() {
        use std::collections::HashMap;
        let trace = collect(&cfg(1500, 21));
        let mut last: HashMap<u64, (u32, f64, u32, u32)> = HashMap::new();
        let cap = WorkloadConfig::default().max_prompt_tokens;
        let mut multi_turn = 0usize;
        for r in &trace {
            let s = r.session.unwrap();
            assert!(s.prefix_tokens <= r.prompt_tokens);
            assert_eq!(s.xfer_tokens, 0, "source never pre-stamps transfers");
            // Point lookup per request (not iteration): D2-clean.
            match last.get(&s.session_id) {
                None => {
                    assert_eq!(s.turn, 1, "chains start at turn 1");
                    assert_eq!(s.prefix_tokens, 0, "no context before turn 1");
                }
                Some(&(turn, at, prompt, output)) => {
                    multi_turn += 1;
                    assert_eq!(s.turn, turn + 1, "turns in order under the merge");
                    assert!(r.arrival > at, "think gap must be positive");
                    assert_eq!(
                        s.prefix_tokens,
                        (prompt + output).min(cap).min(r.prompt_tokens),
                        "prefix is the chain recurrence"
                    );
                    assert!(s.prefix_tokens > 0, "follow-up turns carry context");
                }
            }
            last.insert(s.session_id, (s.turn, r.arrival, r.prompt_tokens, r.output_tokens));
        }
        assert!(multi_turn > 200, "mix must be chain-heavy: {multi_turn}");
    }

    /// Session turns survive a MergedArrivals relabel: ids move, the
    /// SessionRef chain structure does not.
    #[test]
    fn merge_relabels_ids_but_not_chains() {
        let ca = cfg(200, 5);
        let cb = cfg(120, 6);
        let mut sa = SessionSource::new(&ca);
        let mut sb = SessionSource::new(&cb);
        let mut merged = super::super::MergedArrivals::new(vec![&mut sa, &mut sb]);
        let mut got = Vec::new();
        while let Some(r) = merged.next_arrival() {
            got.push(r);
        }
        assert_eq!(got.len(), 320);
        assert!(got.iter().enumerate().all(|(i, r)| r.id == i as u64));
        assert!(got.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // Per-source chain order is preserved by the stable merge; we
        // can't tell the two sources' session ids apart after merging,
        // but every first-seen session id must appear at turn 1 — twice
        // (once per source) at most.
        use std::collections::HashMap;
        let mut seen: HashMap<u64, u32> = HashMap::new();
        for r in &got {
            let s = r.session.unwrap();
            let e = seen.entry(s.session_id).or_insert(0);
            // Each source contributes turn sequences 1,2,.. for a given
            // sid; interleaved (the two sources share the sid namespace)
            // a turn k still can't appear before some source emitted its
            // turn k-1.
            if s.turn > 1 {
                assert!(*e >= s.turn - 1, "turn skipped for sid {}", s.session_id);
            }
            *e = (*e).max(s.turn);
        }
    }

    #[test]
    fn from_workload_scales_session_rate_down() {
        let c = cfg(100, 1);
        let base_rate = 15.0;
        assert!(c.session_rate > 0.0 && c.session_rate < base_rate);
    }
}
