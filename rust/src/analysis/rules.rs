//! The pallas-lint rule engine.
//!
//! Six rules enforce the crate's determinism/allocation/panic contracts
//! (see the crate docs in `lib.rs` for the invariant each one guards):
//!
//! * **D1** — no wall-clock (`Instant::now`) or ambient-entropy sources
//!   outside `coordinator/` and `util/logging.rs`.
//! * **D2** — no order-sensitive iteration of `HashMap`/`HashSet` in
//!   `sim/`, `scheduler/`, `workload/` or `coordinator/kv.rs`.
//! * **D3** — seed construction in feature code goes through the
//!   `seed ^ <X>_STREAM_SALT` side-stream idiom.
//! * **A1** — marker-delimited no-alloc regions ban allocating calls.
//! * **P1** — panic paths in `sim/` + `scheduler/` carry justifications.
//! * **N1** — NaN-unsafe comparisons on slack-typed values.
//!
//! Suppression is annotation-only (see [`parse_directive`]); module
//! scoping is path-based (see [`Scope::for_path`]). `#[cfg(test)]`
//! regions are exempt from every rule. Malformed annotations surface as
//! unsuppressible `lint-syntax` diagnostics.

use super::lexer::{lex, Lexed, Tok, TokKind};
use std::collections::HashMap;

/// Canonical rule ids, as printed in diagnostics and named (long or
/// short, case-insensitively) in suppression annotations.
pub const RULES: &[(&str, &str)] = &[
    ("D1", "wall-clock"),
    ("D2", "unordered-iter"),
    ("D3", "raw-seed"),
    ("A1", "alloc"),
    ("P1", "panic"),
    ("N1", "nan-cmp"),
];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Which rules apply to a file, derived from its path relative to the
/// lint root (`src/`), with `/` separators.
#[derive(Debug, Clone, Copy)]
struct Scope {
    d1: bool,
    d2: bool,
    d3: bool,
    p1: bool,
    n1: bool,
}

impl Scope {
    fn for_path(path: &str) -> Scope {
        let in_sim = path.starts_with("sim/");
        let in_sched = path.starts_with("scheduler/");
        let in_work = path.starts_with("workload/");
        let core = in_sim || in_sched || in_work;
        Scope {
            d1: !(path.starts_with("coordinator/") || path == "util/logging.rs"),
            d2: core || path == "coordinator/kv.rs",
            d3: core,
            p1: in_sim || in_sched,
            n1: core,
        }
    }
}

#[derive(Debug)]
enum Directive {
    /// `lint: allow(<rules>) <reason>` or `lint: order-insensitive <reason>`.
    Allow(Vec<&'static str>),
    /// `lint: no-alloc [reason]` — opens an A1 region.
    RegionStart,
    /// `lint: end-no-alloc` — closes it.
    RegionEnd,
}

/// Parse a lint control comment. `None` when the comment is not a lint
/// directive at all; `Some(Err(_))` for a malformed one.
fn parse_directive(text: &str) -> Option<Result<Directive, String>> {
    let rest = text.trim().strip_prefix("lint:")?.trim_start();
    if rest.strip_prefix("end-no-alloc").is_some() {
        return Some(Ok(Directive::RegionEnd));
    }
    if rest.strip_prefix("no-alloc").is_some() {
        // The reason is recommended but optional on region markers.
        return Some(Ok(Directive::RegionStart));
    }
    if let Some(r) = rest.strip_prefix("order-insensitive") {
        if r.trim().is_empty() {
            return Some(Err("`order-insensitive` needs a reason".to_string()));
        }
        return Some(Ok(Directive::Allow(vec!["D2"])));
    }
    if let Some(r) = rest.strip_prefix("allow") {
        let Some(r) = r.trim_start().strip_prefix('(') else {
            return Some(Err("expected `allow(<rules>) <reason>`".to_string()));
        };
        let Some(close) = r.find(')') else {
            return Some(Err("unclosed `allow(` rule list".to_string()));
        };
        let (list, after) = r.split_at(close);
        if after[1..].trim().is_empty() {
            return Some(Err(
                "`allow(..)` needs a justification after the rule list".to_string(),
            ));
        }
        let mut rules = Vec::new();
        for part in list.split(',') {
            match canon_rule(part.trim()) {
                Some(id) => rules.push(id),
                None => return Some(Err(format!("unknown rule {:?}", part.trim()))),
            }
        }
        if rules.is_empty() {
            return Some(Err("empty rule list in `allow()`".to_string()));
        }
        return Some(Ok(Directive::Allow(rules)));
    }
    Some(Err(format!(
        "unrecognized lint directive {:?} (expected allow/order-insensitive/no-alloc/end-no-alloc)",
        text.trim()
    )))
}

fn canon_rule(name: &str) -> Option<&'static str> {
    let lower = name.to_ascii_lowercase();
    RULES
        .iter()
        .find(|(id, long)| lower == id.to_ascii_lowercase() || lower == *long)
        .map(|(id, _)| *id)
}

fn is_punct(t: &Tok, c: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1 && t.text.starts_with(c)
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

/// Match a mixed ident/punct pattern starting at `from`. Single-char
/// non-alphanumeric entries match punctuation; the rest match idents.
fn matches_seq(toks: &[Tok], from: usize, pat: &[&str]) -> bool {
    if from + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, p)| {
        let t = &toks[from + k];
        if p.chars().all(|c| c.is_alphanumeric() || c == '_') {
            is_ident(t, p)
        } else {
            t.kind == TokKind::Punct && t.text == *p
        }
    })
}

fn match_delim(toks: &[Tok], open: usize, oc: char, cc: char) -> usize {
    let mut depth = 0i32;
    let mut k = open;
    while k < toks.len() {
        if is_punct(&toks[k], oc) {
            depth += 1;
        } else if is_punct(&toks[k], cc) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    toks.len().saturating_sub(1)
}

/// Mark every token belonging to a `#[cfg(test)]` item (attribute through
/// the end of the following `{..}` block or `;`).
fn mark_test_regions(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if is_punct(&toks[i], '#') && matches_seq(toks, i + 1, &["[", "cfg", "(", "test", ")", "]"])
        {
            let mut k = i + 7;
            let mut end = toks.len().saturating_sub(1);
            while k < toks.len() {
                if is_punct(&toks[k], ';') {
                    end = k;
                    break;
                }
                if is_punct(&toks[k], '{') {
                    end = match_delim(toks, k, '{', '}');
                    break;
                }
                k += 1;
            }
            for m in mask.iter_mut().take(end + 1).skip(i) {
                *m = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    mask
}

/// Line spans covered by test regions (for exempting comments).
fn test_line_spans(toks: &[Tok], mask: &[bool]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if mask[i] {
            let start = toks[i].line;
            let mut j = i;
            while j + 1 < toks.len() && mask[j + 1] {
                j += 1;
            }
            spans.push((start, toks[j].line));
            i = j + 1;
        } else {
            i += 1;
        }
    }
    spans
}

/// Lint one file. `path` is relative to the lint root with `/` separators
/// (the harness passes virtual paths like `sim/fixture.rs` to pick scope).
pub fn lint_source(path: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let scope = Scope::for_path(path);
    let toks = &lexed.toks;
    let in_test = mark_test_regions(toks);
    let test_spans = test_line_spans(toks, &in_test);

    let mut diags: Vec<Diagnostic> = Vec::new();
    let (allows, regions) = collect_directives(path, &lexed, &test_spans, &mut diags);

    let allowed = |line: u32, rule: &str| {
        allows
            .get(&line)
            .is_some_and(|v| v.iter().any(|r| *r == rule))
    };
    let mut pending: Vec<Diagnostic> = Vec::new();
    let mut emit = |line: u32, rule: &'static str, msg: String| {
        pending.push(Diagnostic {
            path: path.to_string(),
            line,
            rule,
            msg,
        });
    };

    // ---- D1: wall-clock / ambient entropy --------------------------------
    const D1_BANNED: &[&str] = &[
        "SystemTime",
        "UNIX_EPOCH",
        "thread_rng",
        "from_entropy",
        "getrandom",
        "RandomState",
    ];
    if scope.d1 {
        for i in 0..toks.len() {
            if in_test[i] || toks[i].kind != TokKind::Ident {
                continue;
            }
            let t = &toks[i];
            if t.text == "Instant" && matches_seq(toks, i + 1, &[":", ":", "now"]) {
                emit(
                    t.line,
                    "D1",
                    "wall-clock `Instant::now` in deterministic code; move timing to \
                     coordinator/ or util/logging.rs, or justify"
                        .to_string(),
                );
            } else if D1_BANNED.contains(&t.text.as_str()) {
                emit(
                    t.line,
                    "D1",
                    format!("ambient time/entropy source `{}`", t.text),
                );
            }
        }
    }

    // ---- D2: unordered hash-container iteration --------------------------
    if scope.d2 {
        let names = collect_hash_names(toks, &in_test);
        const METHODS: &[&str] = &[
            "iter",
            "iter_mut",
            "keys",
            "values",
            "values_mut",
            "drain",
            "into_iter",
        ];
        for i in 0..toks.len() {
            if in_test[i] || toks[i].kind != TokKind::Ident || !names.contains(&toks[i].text) {
                continue;
            }
            let name = &toks[i].text;
            if i + 3 < toks.len()
                && is_punct(&toks[i + 1], '.')
                && toks[i + 2].kind == TokKind::Ident
                && METHODS.contains(&toks[i + 2].text.as_str())
                && is_punct(&toks[i + 3], '(')
            {
                emit(
                    toks[i + 2].line,
                    "D2",
                    format!(
                        "unordered iteration `{}.{}()` on a hash container; sort first or \
                         annotate order-insensitive",
                        name, toks[i + 2].text
                    ),
                );
            } else if i + 1 < toks.len() && is_punct(&toks[i + 1], '{') {
                // `for pat in [&][mut] [self.]name {` — direct iteration.
                let mut k = i;
                while k > 0 {
                    k -= 1;
                    let p = &toks[k];
                    if is_punct(p, '.')
                        || is_punct(p, '&')
                        || is_ident(p, "self")
                        || is_ident(p, "mut")
                    {
                        continue;
                    }
                    if is_ident(p, "in") {
                        emit(
                            toks[i].line,
                            "D2",
                            format!("unordered `for .. in {name}` over a hash container"),
                        );
                    }
                    break;
                }
            }
        }
    }

    // ---- D3: raw seed construction ---------------------------------------
    if scope.d3 {
        for i in 0..toks.len() {
            if in_test[i] {
                continue;
            }
            if is_ident(&toks[i], "Rng") && matches_seq(toks, i + 1, &[":", ":", "new", "("]) {
                let close = match_delim(toks, i + 4, '(', ')');
                let salted = toks[i + 4..=close].iter().any(|t| {
                    t.kind == TokKind::Ident && t.text.to_ascii_uppercase().contains("SALT")
                });
                if !salted {
                    emit(
                        toks[i].line,
                        "D3",
                        "raw seed construction; derive side-streams as \
                         `seed ^ <X>_STREAM_SALT`, or justify the primary stream"
                            .to_string(),
                    );
                }
            }
        }
    }

    // ---- A1: allocation inside no-alloc regions --------------------------
    {
        let in_region = |l: u32| regions.iter().any(|&(a, b)| l > a && l < b);
        for i in 0..toks.len() {
            if in_test[i] || !in_region(toks[i].line) {
                continue;
            }
            let t = &toks[i];
            if (is_ident(t, "Vec") || is_ident(t, "Box"))
                && matches_seq(toks, i + 1, &[":", ":", "new"])
            {
                emit(
                    t.line,
                    "A1",
                    format!("`{}::new` inside a no-alloc region", t.text),
                );
            } else if (is_ident(t, "vec") || is_ident(t, "format"))
                && i + 1 < toks.len()
                && is_punct(&toks[i + 1], '!')
            {
                emit(
                    t.line,
                    "A1",
                    format!("`{}!` inside a no-alloc region", t.text),
                );
            } else if is_punct(t, '.')
                && i + 2 < toks.len()
                && (is_ident(&toks[i + 1], "collect") || is_ident(&toks[i + 1], "to_string"))
                && is_punct(&toks[i + 2], '(')
            {
                emit(
                    toks[i + 1].line,
                    "A1",
                    format!("`.{}()` inside a no-alloc region", toks[i + 1].text),
                );
            }
        }
    }

    // ---- P1: justified panic paths ---------------------------------------
    if scope.p1 {
        for i in 0..toks.len() {
            if in_test[i] || toks[i].kind != TokKind::Ident {
                continue;
            }
            let t = &toks[i];
            let bang = i + 1 < toks.len() && is_punct(&toks[i + 1], '!');
            let method_call = i > 0
                && is_punct(&toks[i - 1], '.')
                && i + 1 < toks.len()
                && is_punct(&toks[i + 1], '(');
            match t.text.as_str() {
                "panic" | "unreachable" | "todo" | "unimplemented" if bang => emit(
                    t.line,
                    "P1",
                    format!("`{}!` in sim/scheduler needs a justification annotation", t.text),
                ),
                "unwrap" | "expect" if method_call => emit(
                    t.line,
                    "P1",
                    format!(
                        "`.{}()` in sim/scheduler: justify why it cannot fire, or recover",
                        t.text
                    ),
                ),
                _ => {}
            }
        }
    }

    // ---- N1: NaN-unsafe comparisons on slack values ----------------------
    if scope.n1 {
        let slackish = |s: &str| {
            let l = s.to_ascii_lowercase();
            l.contains("slack") || l.contains("satisf") || l.split('_').any(|seg| seg == "fy")
        };
        let mut cur_fn = String::new();
        for i in 0..toks.len() {
            if in_test[i] {
                continue;
            }
            let t = &toks[i];
            if is_ident(t, "fn") && i + 1 < toks.len() && toks[i + 1].kind == TokKind::Ident {
                cur_fn = toks[i + 1].text.clone();
            }
            // N1a: `partial_cmp(..).unwrap()` / `.expect(..)`.
            if is_ident(t, "partial_cmp")
                && (i == 0 || !is_ident(&toks[i - 1], "fn"))
                && i + 1 < toks.len()
                && is_punct(&toks[i + 1], '(')
            {
                let close = match_delim(toks, i + 1, '(', ')');
                if close + 2 < toks.len()
                    && is_punct(&toks[close + 1], '.')
                    && (is_ident(&toks[close + 2], "unwrap")
                        || is_ident(&toks[close + 2], "expect"))
                {
                    emit(
                        toks[close + 2].line,
                        "N1",
                        "NaN-unsafe `partial_cmp(..).unwrap()`; document why operands are \
                         finite or handle None"
                            .to_string(),
                    );
                }
            }
            // N1b: `.min(`/`.max(` or `f64::min`/`f64::max` in a slack context.
            let mm_line = if is_punct(t, '.')
                && i + 2 < toks.len()
                && (is_ident(&toks[i + 1], "min") || is_ident(&toks[i + 1], "max"))
                && is_punct(&toks[i + 2], '(')
            {
                Some(toks[i + 1].line)
            } else if is_ident(t, "f64")
                && (matches_seq(toks, i + 1, &[":", ":", "min"])
                    || matches_seq(toks, i + 1, &[":", ":", "max"]))
            {
                Some(t.line)
            } else {
                None
            };
            if let Some(line) = mm_line {
                let mut hit = slackish(&cur_fn);
                let mut k = i;
                while !hit && k > 0 {
                    k -= 1;
                    let p = &toks[k];
                    if p.kind == TokKind::Punct && matches!(p.text.as_str(), ";" | "{" | "}") {
                        break;
                    }
                    if p.kind == TokKind::Ident && slackish(&p.text) {
                        hit = true;
                    }
                }
                if hit {
                    emit(
                        line,
                        "N1",
                        "`min`/`max` on a slack-typed value silently drops NaN; uphold the \
                         -inf-not-NaN convention or justify"
                            .to_string(),
                    );
                }
            }
        }
    }

    diags.extend(pending.into_iter().filter(|d| !allowed(d.line, d.rule)));
    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

/// Names declared (or bound) in this file with `HashMap`/`HashSet` type or
/// initializer — the receiver set rule D2 watches.
fn collect_hash_names(toks: &[Tok], in_test: &[bool]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if in_test[i] {
            continue;
        }
        // `name: ..HashMap/HashSet..` up to a depth-0 `,;{}=` terminator
        // (fields, params, typed lets). `::` paths are excluded by the
        // second-colon check.
        if toks[i].kind == TokKind::Ident
            && i + 2 < toks.len()
            && is_punct(&toks[i + 1], ':')
            && !is_punct(&toks[i + 2], ':')
            && (i == 0 || !is_punct(&toks[i - 1], ':'))
        {
            let mut depth = 0i32;
            let mut saw = false;
            for (steps, t) in toks[i + 2..].iter().enumerate() {
                if steps > 64 {
                    break;
                }
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "<" | "(" | "[" => depth += 1,
                        ">" | ")" | "]" if depth == 0 => break,
                        ">" | ")" | "]" => depth -= 1,
                        "," | ";" | "{" | "}" | "=" if depth == 0 => break,
                        _ => {}
                    }
                } else if is_ident(t, "HashMap") || is_ident(t, "HashSet") {
                    saw = true;
                }
            }
            if saw && !names.contains(&toks[i].text) {
                names.push(toks[i].text.clone());
            }
        }
        // `let [mut] name = ..HashMap/HashSet..;`
        if is_ident(&toks[i], "let") {
            let mut j = i + 1;
            if j < toks.len() && is_ident(&toks[j], "mut") {
                j += 1;
            }
            if j + 1 < toks.len()
                && toks[j].kind == TokKind::Ident
                && is_punct(&toks[j + 1], '=')
            {
                let saw = toks[j + 2..]
                    .iter()
                    .take(64)
                    .take_while(|t| !is_punct(t, ';'))
                    .any(|t| is_ident(t, "HashMap") || is_ident(t, "HashSet"));
                if saw && !names.contains(&toks[j].text) {
                    names.push(toks[j].text.clone());
                }
            }
        }
    }
    names
}

type AllowMap = HashMap<u32, Vec<&'static str>>;

/// Walk the comments: build the per-line allow map and the A1 region list,
/// pushing unsuppressible `lint-syntax` diagnostics for malformed input.
fn collect_directives(
    path: &str,
    lexed: &Lexed,
    test_spans: &[(u32, u32)],
    diags: &mut Vec<Diagnostic>,
) -> (AllowMap, Vec<(u32, u32)>) {
    let mut allows: AllowMap = HashMap::new();
    let mut regions: Vec<(u32, u32)> = Vec::new();
    let mut open: Option<u32> = None;
    let in_test = |l: u32| test_spans.iter().any(|&(a, b)| l >= a && l <= b);
    let mut syntax = |line: u32, msg: String| {
        diags.push(Diagnostic {
            path: path.to_string(),
            line,
            rule: "lint-syntax",
            msg,
        });
    };

    for c in &lexed.comments {
        if c.doc || in_test(c.line) {
            continue;
        }
        let Some(parsed) = parse_directive(&c.text) else {
            continue;
        };
        match parsed {
            Err(msg) => syntax(c.line, msg),
            Ok(Directive::Allow(rules)) => {
                // Trailing annotations cover their own line; standalone
                // ones cover the next line that has code on it.
                let covered = if c.trailing {
                    c.line
                } else {
                    lexed
                        .toks
                        .iter()
                        .find(|t| t.line > c.line)
                        .map(|t| t.line)
                        .unwrap_or(c.line)
                };
                allows.entry(covered).or_default().extend(rules);
            }
            Ok(Directive::RegionStart) => {
                if open.is_some() {
                    syntax(
                        c.line,
                        "nested `no-alloc` region; close the previous one first".to_string(),
                    );
                } else {
                    open = Some(c.line);
                }
            }
            Ok(Directive::RegionEnd) => match open.take() {
                Some(s) => regions.push((s, c.line)),
                None => syntax(
                    c.line,
                    "`end-no-alloc` without an open `no-alloc` region".to_string(),
                ),
            },
        }
    }
    if let Some(s) = open {
        syntax(s, "unclosed `no-alloc` region".to_string());
    }
    (allows, regions)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(path: &str, src: &str) -> Vec<&'static str> {
        lint_source(path, src).into_iter().map(|d| d.rule).collect()
    }

    #[test]
    fn scope_table_matches_the_module_layout() {
        let s = Scope::for_path("sim/engine.rs");
        assert!(s.d1 && s.d2 && s.d3 && s.p1 && s.n1);
        let s = Scope::for_path("coordinator/router.rs");
        assert!(!s.d1 && !s.d2 && !s.p1);
        let s = Scope::for_path("coordinator/kv.rs");
        assert!(s.d2 && !s.p1);
        let s = Scope::for_path("util/logging.rs");
        assert!(!s.d1);
        let s = Scope::for_path("workload/generator.rs");
        assert!(s.d3 && s.n1 && !s.p1);
    }

    #[test]
    fn trailing_and_standalone_annotations_bind_correctly() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   // lint: allow(p1) standalone covers the next line\n\
                   \x20   x.unwrap()\n\
                   }\n\
                   fn g(x: Option<u32>) -> u32 {\n\
                   \x20   x.unwrap() // lint: allow(p1) trailing covers its own line\n\
                   }\n";
        assert!(rules_fired("sim/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        assert!(rules_fired("sim/x.rs", src).is_empty());
    }

    #[test]
    fn malformed_directives_are_unsuppressible_syntax_errors() {
        let src = "// lint: allow(p1)\nfn f() {}\n";
        let d = lint_source("sim/x.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "lint-syntax");
        let src = "// lint: allow(bogus) reason\nfn f() {}\n";
        assert_eq!(rules_fired("sim/x.rs", src), vec!["lint-syntax"]);
    }

    #[test]
    fn long_rule_names_are_accepted() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   x.unwrap() // lint: allow(panic) long name for P1\n\
                   }\n";
        assert!(rules_fired("sim/x.rs", src).is_empty());
    }
}
