//! Minimal Rust lexer for `pallas-lint`.
//!
//! Tokenizes just enough of the language to drive the rule engine:
//! identifiers, punctuation, and literals, each stamped with a 1-based
//! line number, plus the line comments the annotation grammar lives in.
//! It is deliberately not a full lexer — float suffixes and exponents may
//! split into several tokens — but the identifier/punctuation stream the
//! rules match on is exact, and strings/chars/comments are consumed as
//! units so their contents can never masquerade as code.

/// Token class. Literal tokens carry no text (the rules never look inside
/// them); identifiers and punctuation carry their exact source text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Number,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A `//` line comment (block comments are skipped outright — the
/// annotation grammar is line-comment only).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    /// Text after the leading `//`, untrimmed.
    pub text: String,
    /// `///` or `//!` doc comment — never an annotation carrier.
    pub doc: bool,
    /// A code token precedes this comment on its own line.
    pub trailing: bool,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut out = Lexed::default();

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            let text: String = cs[start..j].iter().collect();
            let doc = matches!(text.chars().next(), Some('/') | Some('!'));
            let trailing = out.toks.last().is_some_and(|t| t.line == line);
            out.comments.push(Comment {
                line,
                text,
                doc,
                trailing,
            });
            i = j;
            continue;
        }
        // Block comment (nesting-aware, counts newlines).
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if cs[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if cs[j] == '/' && j + 1 < n && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && j + 1 < n && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw (and byte-raw) strings must beat plain ident lexing of the
        // `r`/`b` prefix.
        if c == 'r' || c == 'b' {
            if let Some((end, nl)) = raw_string(&cs, i) {
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line,
                });
                line += nl;
                i = end;
                continue;
            }
        }
        // Plain (and byte) string literal.
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let tok_line = line;
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < n {
                match cs[j] {
                    '\\' => {
                        if j + 1 < n && cs[j + 1] == '\n' {
                            line += 1;
                        }
                        j += 2;
                    }
                    '"' => {
                        j += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        j += 1;
                    }
                    _ => j += 1,
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line: tok_line,
            });
            i = j;
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            let next_is_name = i + 1 < n && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_');
            let closes = i + 2 < n && cs[i + 2] == '\'';
            if next_is_name && !closes {
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: String::new(),
                    line,
                });
                i = j;
                continue;
            }
            let mut j = i + 1;
            if j < n && cs[j] == '\\' {
                j += 2;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
                j += 1;
            } else {
                j += 1; // the char itself (multibyte-safe: one `char`)
                if j < n && cs[j] == '\'' {
                    j += 1;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        // Number: digits, `_`, hex/suffix letters; `.` only when a digit
        // follows (so `0..n` ranges survive as three tokens).
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n {
                let d = cs[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    j += 1;
                } else if d == '.' && j + 1 < n && cs[j + 1].is_ascii_digit() {
                    j += 1;
                } else {
                    break;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Number,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: cs[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // Everything else: single-char punctuation.
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }

    out
}

/// If `cs[i..]` starts a raw string (`r"`, `r#"`, `br"`, ...), return the
/// index one past the closing quote+hashes and the newline count inside.
fn raw_string(cs: &[char], i: usize) -> Option<(usize, u32)> {
    let n = cs.len();
    let mut j = i;
    if cs[j] == 'b' {
        j += 1;
    }
    if j >= n || cs[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < n && cs[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || cs[j] != '"' {
        return None;
    }
    j += 1;
    let mut nl = 0u32;
    while j < n {
        if cs[j] == '\n' {
            nl += 1;
            j += 1;
        } else if cs[j] == '"' {
            let mut k = j + 1;
            let mut h = 0usize;
            while k < n && h < hashes && cs[k] == '#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return Some((k, nl));
            }
            j += 1;
        } else {
            j += 1;
        }
    }
    Some((n, nl))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_their_contents() {
        let src = "let x = \"panic! inside\"; // trailing panic! note\n/* block panic! */ call();\n";
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "x", "call"]);
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].trailing);
    }

    #[test]
    fn raw_strings_and_chars_lex_as_units() {
        let src = "let s = r#\"quote \" inside\"#; let c = 'x'; let nl = '\\n'; fn f<'a>(x: &'a str) {}";
        let ids = idents(src);
        assert_eq!(
            ids,
            vec!["let", "s", "let", "c", "let", "nl", "fn", "f", "x", "str"]
        );
    }

    #[test]
    fn line_numbers_survive_multiline_literals() {
        let src = "let a = \"two\nlines\";\nmarker();\n";
        let lx = lex(src);
        let m = lx.toks.iter().find(|t| t.text == "marker").expect("marker");
        assert_eq!(m.line, 3);
    }

    #[test]
    fn ranges_do_not_eat_dots() {
        let src = "for i in 0..10 { x.min(1.5); }";
        let lx = lex(src);
        let dots = lx.toks.iter().filter(|t| t.text == ".").count();
        // `0..10` contributes two dot puncts, `x.min` one, `1.5` none.
        assert_eq!(dots, 3);
    }

    #[test]
    fn doc_comments_are_marked() {
        let lx = lex("/// docs\n//! inner\n// plain\n");
        let flags: Vec<bool> = lx.comments.iter().map(|c| c.doc).collect();
        assert_eq!(flags, vec![true, true, false]);
    }
}
