//! `pallas-lint` — a dependency-free static-analysis pass over this
//! crate's own sources.
//!
//! The simulator's bit-identity pins (`ps_equivalence`, `slo_identity`,
//! `faults_identity`) and the fixed-seed ⇒ bit-identical-outcomes goal
//! rest on source-level invariants that no type checker sees: no
//! wall-clock reads in the DES, no unordered hash-map iteration on
//! result paths, salted RNG side-streams, allocation-free decide/route
//! loops, and the `-inf`-not-NaN slack convention. This module turns
//! those norms into checked rules (see [`rules`] for the rule list and
//! `lib.rs` for the crate-level invariant docs).
//!
//! The pass is a lightweight lexer + token-pattern engine — deliberately
//! not `syn`-based, so it builds under the offline vendored-shim Cargo
//! setup with zero new dependencies. Run it as `cargo run --bin
//! pallas-lint` (defaults to this crate's `src/`), or via the
//! `tests/lint.rs` harness which makes a clean tree part of tier-1
//! `cargo test`.

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, Diagnostic};

use std::io;
use std::path::{Path, PathBuf};

/// Outcome of linting a file tree.
#[derive(Debug)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files: usize,
    pub diagnostics: Vec<Diagnostic>,
}

/// Lint every `.rs` file under `root` (sorted walk, so output order and
/// diagnostics are stable across platforms and runs).
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut diagnostics = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = f.strip_prefix(root).unwrap_or(f.as_path());
        let rel = rel.to_string_lossy().replace('\\', "/");
        diagnostics.extend(lint_source(&rel, &src));
    }
    Ok(LintReport {
        files: files.len(),
        diagnostics,
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<Vec<_>>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}
