//! perllm — leader entrypoint.
//!
//! `perllm serve`   — serve real AOT models (edge + cloud engines) behind
//!                    the CS-UCB router, report latency/throughput.
//! `perllm sim`     — paper-scale DES experiment over all four schedulers.
//! `perllm version` — build info.

use std::time::Duration;

use anyhow::{bail, Result};

use perllm::cli;
use perllm::coordinator::server::{ServeRequest, ServingCluster};
use perllm::runtime::{self, Artifacts, ModelEngine};
use perllm::scheduler::{
    agod::Agod, csucb::CsUcb, fineinfer::FineInfer, rewardless::RewardlessGuidance, Scheduler,
};
use perllm::sim::cluster::BandwidthMode;
use perllm::sim::engine::{simulate_stream, simulate_stream_sharded};
use perllm::sim::server::ServerKind;
use perllm::sim::{ShardCount, TopologyConfig};
use perllm::util::rng::Rng;
use perllm::workload::generator::{ArrivalProcess, WorkloadConfig, WorkloadGen};
use perllm::workload::service::ServiceClass;

fn main() {
    perllm::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd_name) = args.first() else {
        print!("{}", cli::global_help());
        return Ok(());
    };
    if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
        print!("{}", cli::global_help());
        return Ok(());
    }
    let Some(spec) = cli::commands().into_iter().find(|c| c.name == cmd_name) else {
        bail!("unknown command {cmd_name:?}\n{}", cli::global_help());
    };
    let rest = &args[1..];
    if rest.iter().any(|a| a == "--help") {
        print!("{}", spec.help());
        return Ok(());
    }
    let parsed = spec.parse(rest)?;
    match spec.name {
        "version" => {
            println!("perllm {}", perllm::version());
            Ok(())
        }
        "sim" => cmd_sim(&parsed),
        "serve" => cmd_serve(&parsed),
        _ => unreachable!(),
    }
}

fn make_scheduler(name: &str, n_servers: usize, cloud: usize, seed: u64) -> Result<Box<dyn Scheduler>> {
    Ok(match name {
        "cs-ucb" => Box::new(CsUcb::with_defaults(n_servers)),
        "fineinfer" => Box::new(FineInfer::new(cloud)),
        "agod" => Box::new(Agod::new(n_servers, seed)),
        "rewardless" => Box::new(RewardlessGuidance::new(n_servers)),
        other => bail!("unknown scheduler {other:?}"),
    })
}

fn cmd_sim(p: &cli::Parsed) -> Result<()> {
    let n = p.usize_or("requests", 10_000)?;
    let model = p.str_or("model", "llama2-7b");
    let seed = p.u64_or("seed", 42)?;
    let topology = p.str_or("topology", "paper");
    let mode = if p.flag("fluctuating") {
        BandwidthMode::Fluctuating
    } else {
        BandwidthMode::Stable
    };
    let topo = TopologyConfig::by_name(&topology, &model, mode)
        .ok_or_else(|| anyhow::anyhow!("unknown --topology {topology:?}"))?;
    // Arrival rate scales with topology capacity unless pinned, so the
    // offered load stays comparable across fleet sizes.
    let rate = match p.get("rate") {
        Some(r) => r.parse::<f64>().map_err(|_| anyhow::anyhow!("bad --rate {r:?}"))?,
        None => topo.scaled_rate(15.0),
    };
    // `--shards` selects the sharded parallel engine (bit-identical to the
    // sequential one at every count — pinned by tests/sharded_identity.rs).
    let shards = p
        .get("shards")
        .map(|s| {
            ShardCount::parse(s).ok_or_else(|| anyhow::anyhow!("bad --shards {s:?} (N or auto)"))
        })
        .transpose()?;
    // Streamed workload: each scheduler gets a fresh cursor over the same
    // seeded sequence, so nothing is materialized and the event heap stays
    // bounded at any --requests scale.
    let workload = WorkloadConfig::default()
        .with_requests(n)
        .with_arrivals(ArrivalProcess::Poisson { rate })
        .with_deadline_range(2.0, 6.0)
        .with_seed(seed);
    let cfg = topo.build();
    println!(
        "perllm sim: {n} requests, topology {topology} ({} servers), edge model {model}, \
         {mode:?} bandwidth, rate {rate:.1}/s{}",
        cfg.n_servers(),
        match shards {
            Some(c) => format!(", sharded engine ({c:?})"),
            None => String::new(),
        }
    );
    for name in ["fineinfer", "agod", "rewardless", "cs-ucb"] {
        let mut s = make_scheduler(name, cfg.n_servers(), cfg.cloud_index(), seed)?;
        let mut source = WorkloadGen::new(&workload);
        let rep = match shards {
            Some(count) => {
                let splan = topo.shard_plan(count);
                simulate_stream_sharded(&cfg, &splan, &mut source, s.as_mut())
            }
            None => simulate_stream(&cfg, &mut source, s.as_mut()),
        };
        println!("{}", rep.summary_row());
    }
    Ok(())
}

fn report_reply(got: &mut usize, sent_prompts: &[&str], r: &perllm::coordinator::ServeReply) {
    if *got < 4 {
        println!(
            "[worker {}] {:?} + {:?} ({} tok, {:.0} ms)",
            r.worker,
            sent_prompts.get(r.id as usize).copied().unwrap_or(""),
            r.text.chars().take(60).collect::<String>(),
            r.tokens,
            r.latency_ms
        );
    }
    *got += 1;
}

fn cmd_serve(p: &cli::Parsed) -> Result<()> {
    let art_dir = p
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(runtime::default_artifact_dir);
    let n = p.usize_or("requests", 64)?;
    let edge_workers = p.usize_or("edge-workers", 2)?;
    let max_new = p.usize_or("max-new-tokens", 48)?;
    let seed = p.u64_or("seed", 42)?;
    let sched_name = p.str_or("scheduler", "cs-ucb");

    println!("loading artifacts from {art_dir:?}");
    Artifacts::discover(&art_dir)?; // fail fast before spawning workers
    type Factory = Box<dyn FnOnce() -> Result<ModelEngine> + Send>;
    let mut engines: Vec<(ServerKind, Factory)> = Vec::new();
    for _ in 0..edge_workers {
        let dir = art_dir.clone();
        engines.push((
            ServerKind::Edge,
            Box::new(move || {
                let arts = Artifacts::discover(&dir)?;
                ModelEngine::load(&runtime::cpu_client()?, &arts, "edge")
            }),
        ));
    }
    {
        let dir = art_dir.clone();
        engines.push((
            ServerKind::Cloud,
            Box::new(move || {
                let arts = Artifacts::discover(&dir)?;
                ModelEngine::load(&runtime::cpu_client()?, &arts, "cloud")
            }),
        ));
    }
    let n_workers = engines.len();
    println!("{n_workers} workers ({edge_workers} edge + 1 cloud), scheduler {sched_name}");

    let scheduler = make_scheduler(&sched_name, n_workers, n_workers - 1, seed)?;
    let mut cluster = ServingCluster::start(engines, scheduler, seed)?;

    let prompts = [
        "Edge-cloud collaboration ",
        "The scheduler learns ",
        "Diverse services ask for ",
        "PerLLM schedules each request ",
    ];
    let classes = [
        ServiceClass::Chat,
        ServiceClass::Summarize,
        ServiceClass::Translate,
        ServiceClass::Code,
    ];
    let mut rng = Rng::new(seed);
    let mut sent_prompts: Vec<&str> = Vec::with_capacity(n);
    let mut ok = 0usize;
    let mut got = 0usize;
    let mut shed = 0usize;
    for i in 0..n {
        let k = rng.index(prompts.len());
        sent_prompts.push(prompts[k]);
        let outcome = cluster.submit(ServeRequest {
            id: i as u64,
            prompt: prompts[k].to_string(),
            max_new_tokens: max_new,
            deadline_s: rng.uniform(2.0, 6.0),
            ttft_slo_s: None,
            class: classes[k],
            temperature: 0.8,
            top_k: 200,
        })?;
        // Shed requests resolve immediately — no completion will arrive.
        if outcome.worker().is_none() {
            shed += 1;
        }
        // Paced open-loop arrivals so queueing reflects routing, not a
        // single burst.
        while let Some(r) = cluster.recv_completion(Duration::from_millis(1)) {
            if r.met_deadline() {
                ok += 1;
            }
            report_reply(&mut got, &sent_prompts, &r);
        }
    }
    while got + shed < n {
        let Some(r) = cluster.recv_completion(Duration::from_secs(120)) else {
            bail!("timed out waiting for completions ({got}/{n})");
        };
        if r.met_deadline() {
            ok += 1;
        }
        report_reply(&mut got, &sent_prompts, &r);
    }
    if shed > 0 {
        println!("{shed} requests shed by the scheduling policy");
    }
    println!("\n{}", cluster.metrics.report());
    println!("deadline success: {:.1}%", 100.0 * ok as f64 / n as f64);
    for (k, v) in cluster.diagnostics() {
        println!("  {k}: {v:.2}");
    }
    cluster.shutdown();
    Ok(())
}
