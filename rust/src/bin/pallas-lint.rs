//! `pallas-lint` — invariant checker for the perllm crate.
//!
//! Usage: `cargo run --bin pallas-lint [root]`. With no argument it lints
//! this crate's `src/` tree. Exit codes: 0 clean, 1 violations, 2 I/O
//! error. Diagnostics print as `path:line: RULE: message`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("src"));
    match perllm::analysis::lint_tree(&root) {
        Ok(report) => {
            for d in &report.diagnostics {
                println!("{d}");
            }
            if report.diagnostics.is_empty() {
                println!("pallas-lint: {} files clean", report.files);
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "pallas-lint: {} violation(s) across {} files scanned",
                    report.diagnostics.len(),
                    report.files
                );
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("pallas-lint: cannot read {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}
