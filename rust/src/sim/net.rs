//! Network model: per-edge dedicated links and the shared cloud uplink.
//!
//! Paper §4.1: edge links 100 Mbps, cloud 300 Mbps, with a "fluctuating"
//! mode varying within ±20 %. The cloud uplink is *shared* by every request
//! routed to the cloud — fair-share division across concurrent uploads is
//! exactly the congestion mechanism behind the Figure-2 surge. Edge links
//! are LAN-local: short RTT and ~3x lower energy per bit than the WAN path.

use super::ps::PsQueue;
use super::time::{Generation, SimTime};

/// Static link description.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    pub name: String,
    /// Nominal bandwidth, bits per second.
    pub bandwidth_bps: f64,
    /// Per-flow throughput ceiling, bits per second (TCP window × RTT
    /// limits a single WAN flow well below the aggregate pipe).
    pub per_flow_cap_bps: f64,
    /// Propagation / protocol round-trip added to every upload, seconds.
    pub rtt_s: f64,
    /// Fluctuation amplitude: multiplier drawn from U[1-a, 1+a].
    pub fluctuation: f64,
    /// Seconds between fluctuation re-draws.
    pub fluct_period: f64,
    /// Transmission energy, joules per megabit (WAN ≫ LAN).
    pub energy_j_per_mbit: f64,
}

impl LinkSpec {
    pub fn edge(i: usize, fluctuating: bool) -> LinkSpec {
        LinkSpec {
            name: format!("edge-link-{i}"),
            bandwidth_bps: 100.0e6,
            per_flow_cap_bps: 40.0e6,
            rtt_s: 0.005,
            fluctuation: if fluctuating { 0.2 } else { 0.0 },
            fluct_period: 0.5,
            energy_j_per_mbit: 0.6,
        }
    }

    pub fn cloud(fluctuating: bool) -> LinkSpec {
        LinkSpec {
            name: "cloud-uplink".into(),
            bandwidth_bps: 300.0e6,
            per_flow_cap_bps: 8.0e6,
            rtt_s: 0.08,
            fluctuation: if fluctuating { 0.2 } else { 0.0 },
            fluct_period: 0.5,
            energy_j_per_mbit: 4.0,
        }
    }

    /// Solo transfer time for a payload (no sharing, per-flow-capped rate).
    pub fn solo_time(&self, payload_bytes: u64) -> f64 {
        let rate = self.per_flow_cap_bps.min(self.bandwidth_bps);
        self.rtt_s + payload_bytes as f64 * 8.0 / rate
    }

    /// Transmission energy for a payload, joules.
    pub fn tx_energy(&self, payload_bytes: u64) -> f64 {
        payload_bytes as f64 * 8.0 / 1.0e6 * self.energy_j_per_mbit
    }
}

/// Dynamic link state in the DES: a PS queue over payload bytes.
#[derive(Debug)]
pub struct LinkSim {
    pub spec: LinkSpec,
    pub queue: PsQueue,
    pub gen: Generation,
    /// Current fluctuation multiplier.
    pub mult: f64,
    last_update: SimTime,
    /// Integrated bytes moved (utilization accounting).
    pub bytes_moved: f64,
}

impl LinkSim {
    /// Links carry unbounded concurrent flows (TCP fair share), so the PS
    /// concurrency cap is effectively infinite.
    pub fn new(spec: LinkSpec) -> Self {
        LinkSim {
            spec,
            queue: PsQueue::new(usize::MAX >> 1),
            gen: Generation::new(),
            mult: 1.0,
            last_update: 0.0,
            bytes_moved: 0.0,
        }
    }

    /// Bytes/s each concurrent upload receives right now: fair share of the
    /// (fluctuating) pipe, capped per flow.
    pub fn per_flow_rate(&self) -> f64 {
        let n = self.queue.n_active();
        if n == 0 {
            return 0.0;
        }
        let share = self.spec.bandwidth_bps * self.mult / n as f64;
        share.min(self.spec.per_flow_cap_bps * self.mult) / 8.0
    }

    /// Advance upload progress and the utilization integral to `now`.
    /// O(1): the queue advance is a virtual-work-time counter bump even
    /// with hundreds of concurrent flows mid-congestion-collapse.
    pub fn advance_to(&mut self, now: SimTime) {
        let dt = now - self.last_update;
        if dt <= 0.0 {
            return;
        }
        let rate = self.per_flow_rate();
        let n = self.queue.n_active();
        self.queue.advance(dt, rate);
        self.bytes_moved += rate * dt * n as f64;
        self.last_update = now;
    }

    /// Predicted upload time for a payload arriving now (shared fairly with
    /// the flows already in flight) — scheduler-visible bandwidth estimate.
    pub fn predict_tx_time(&self, payload_bytes: u64) -> f64 {
        let n = self.queue.n_active() + 1;
        let share = self.spec.bandwidth_bps * self.mult.max(1e-9) / n as f64;
        let rate = share.min(self.spec.per_flow_cap_bps * self.mult.max(1e-9)) / 8.0;
        self.spec.rtt_s + payload_bytes as f64 / rate
    }

    /// Paper C3: bandwidth headroom as a fraction of nominal capacity.
    pub fn bandwidth_headroom(&self) -> f64 {
        let n = self.queue.n_active() as f64;
        // Treat each active flow as consuming a fair share; headroom decays
        // towards zero as the link saturates.
        (self.spec.bandwidth_bps * self.mult) / (n + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_time_includes_rtt() {
        let l = LinkSpec::cloud(false);
        // Per-flow cap (8 Mbps) binds, not the 300 Mbps aggregate.
        let t = l.solo_time(8_000_000 / 8); // exactly 1 s at the flow cap
        assert!((t - (1.0 + 0.08)).abs() < 1e-9);
    }

    #[test]
    fn fair_share_below_cap() {
        let mut l = LinkSim::new(LinkSpec::edge(0, false));
        // 1-2 flows: the 40 Mbps per-flow cap binds, not the share.
        l.queue.push(1, 1.0e6, 0.0);
        let r1 = l.per_flow_rate();
        assert!((r1 - 40.0e6 / 8.0).abs() < 1e-6);
        // 4 flows: fair share 25 Mbps < cap.
        for i in 2..=4 {
            l.queue.push(i, 1.0e6, 0.0);
        }
        let r4 = l.per_flow_rate();
        assert!((r4 - 100.0e6 / 4.0 / 8.0).abs() < 1e-6);
        // 8 flows: share halves again.
        for i in 5..=8 {
            l.queue.push(i, 1.0e6, 0.0);
        }
        assert!((r4 / l.per_flow_rate() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn advance_moves_bytes() {
        let mut l = LinkSim::new(LinkSpec::edge(0, false));
        l.queue.push(1, 5.0e6, 0.0); // 1 s at the 40 Mbps flow cap
        l.advance_to(0.5);
        assert!((l.bytes_moved - 2.5e6).abs() < 1.0);
        l.advance_to(1.0);
        let done = l.queue.reap(1.0, l.per_flow_rate());
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn congestion_slows_everyone() {
        let mut l = LinkSim::new(LinkSpec::cloud(false));
        let t_solo = l.predict_tx_time(1_000_000);
        for i in 0..99 {
            l.queue.push(i, 1.0e6, 0.0);
        }
        let t_crowded = l.predict_tx_time(1_000_000);
        // 100 flows share 300 Mbps -> 3 Mbps each vs the 8 Mbps solo cap.
        assert!(t_crowded > 2.0 * t_solo, "{t_crowded} vs {t_solo}");
    }

    #[test]
    fn tx_energy_scales_with_bytes() {
        let l = LinkSpec::cloud(false);
        assert!((l.tx_energy(2_000_000) - 2.0 * l.tx_energy(1_000_000)).abs() < 1e-9);
        // WAN costs more per bit than LAN.
        assert!(l.tx_energy(1_000_000) > LinkSpec::edge(0, false).tx_energy(1_000_000));
    }

    #[test]
    fn headroom_decays_with_flows() {
        let mut l = LinkSim::new(LinkSpec::cloud(false));
        let h0 = l.bandwidth_headroom();
        l.queue.push(1, 1.0e6, 0.0);
        l.queue.push(2, 1.0e6, 0.0);
        assert!(l.bandwidth_headroom() < h0);
    }
}
