//! Pluggable token-level service models: the contract a simulated server
//! must fulfil for the DES engine and the scheduler view, extracted from
//! the PS-specific internals `ServerSim` used to hard-code.
//!
//! The engine never cared that a server was a processor-sharing fluid —
//! it needs exactly six capabilities: admit a request, advance work and
//! per-job energy attribution through time, name the next completion (and
//! a *reschedule key* certifying when an already-scheduled completion
//! event is still correct), reap finished jobs, predict service time for
//! an arriving request, and report occupancy. [`ServiceModel`] is that
//! contract. Two implementations ship:
//!
//! * [`PsServiceModel`] — the historical virtual-time processor-sharing
//!   fluid over [`PsQueue`], **bit-identical** to the pre-trait
//!   `ServerSim` (every formula is the same float expression; the
//!   executable-spec run-identity test in
//!   `rust/tests/service_model_identity.rs` pins `ClusterConfig::paper`
//!   runs outcome-for-outcome, exactly as PR 3 pinned topology lowering).
//! * [`super::token_batch::TokenBatchModel`] — a discrete-iteration
//!   continuous-batching server (Orca-style, like the live coordinator's
//!   `Batcher`): prefill admission into bounded lanes, batch-size-
//!   dependent per-iteration token rate on the [`batch_efficiency`]
//!   curve, and KV-token-budget admission mirroring `KvPool::can_admit`.
//!
//! Model choice is part of the server description
//! ([`ServiceModelKind`] in [`super::server::ServerSpec`]), so
//! `TopologyConfig` tiers can mix models (token-batch edge tiers under PS
//! cloud tiers) and every layer above — cluster views, engine, CLI,
//! benches — works unchanged.
//!
//! # Reschedule key
//!
//! The engine keeps at most one live completion event per server and must
//! decide, on every occupancy touch, whether that event is still correct.
//! [`ServiceModel::completion_key`] returns the model-defined pair of
//! floats that *determines* the next completion instant: if the pair is
//! identical before and after a touch, the completion provably did not
//! move and the event is kept (the churn guard). For PS that pair is
//! (heap-top finish work, per-job rate); for the token-batch model it is
//! (absolute finish-iteration index, effective iteration period).

use super::ps::{batch_efficiency, PsJob, PsQueue};
use super::server::ServerSpec;
use super::time::SimTime;
use crate::workload::service::ServiceRequest;

/// What a service model predicts for a request arriving now: time to
/// first token and total completion time (both *additional* seconds from
/// now, excluding network transfer — the view layer adds link terms).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServicePrediction {
    /// Queue wait + (stretched) prefill: when the first output token
    /// would appear. TTFT-sensitive scenarios (interactive SLOs) read
    /// this; it is `<= total_s` by construction.
    pub ttft_s: f64,
    /// Queue wait + full stretched service: when the request completes.
    pub total_s: f64,
}

/// Which service model a server runs — part of [`ServerSpec`], so
/// topologies select models per tier and configs stay `PartialEq`-
/// comparable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServiceModelKind {
    /// Virtual-time processor-sharing fluid (the historical default).
    Ps,
    /// Discrete-iteration continuous batching with a KV-token admission
    /// budget (see [`super::token_batch::TokenBatchModel`]).
    TokenBatch {
        /// Total KV tokens resident sequences may hold (prompt + output
        /// per request, mirroring `KvPool::can_admit`'s page budget).
        kv_tokens: u32,
    },
}

impl ServiceModelKind {
    /// A token-batch kind with a KV budget sized for `slots` worst-case
    /// sequences of the default workload caps (1024 prompt + 512 output):
    /// KV then only binds under deliberately shrunk budgets or heavy
    /// tails, matching how the live `Batcher` sizes its `KvPool`.
    pub fn token_batch_for(slots: usize) -> ServiceModelKind {
        ServiceModelKind::TokenBatch {
            kv_tokens: (slots as u32).saturating_mul(1536),
        }
    }
}

/// The server-side service contract the DES engine and the scheduler
/// snapshot are written against. One boxed instance lives inside each
/// `ServerSim`; the outage multiplier (`rate_mult`) stays owner-side and
/// is threaded into every rate-sensitive call, so models never observe a
/// stale multiplier.
pub trait ServiceModel: std::fmt::Debug + Send {
    /// Admit `req` as job `id` at `now` (slot if available, else the
    /// bounded FIFO wait queue). The engine guarantees it checked
    /// [`Self::would_drop`] first.
    fn admit(&mut self, id: u64, req: &ServiceRequest, now: SimTime);

    /// Would an arrival right now be shed? (bounded queue at its limit
    /// with no way to start service)
    fn would_drop(&self) -> bool;

    /// Advance job progress by `dt` seconds at outage multiplier
    /// `rate_mult`, attributing `energy_per_job` joules to every job in
    /// service (marginal per-service energy accounting — attributed even
    /// at rate 0, matching the busy-power integral upstream).
    fn advance(&mut self, dt: SimTime, rate_mult: f64, energy_per_job: f64);

    /// Seconds until the earliest job finishes, `None` if nothing can
    /// complete (idle, or zero rate with nothing already finished).
    fn next_completion_in(&self, rate_mult: f64) -> Option<SimTime>;

    /// Reschedule-guard key: the float pair the next completion instant
    /// is a pure function of (see the module docs). `Some` exactly when
    /// [`Self::next_completion_in`] is `Some`.
    fn completion_key(&self, rate_mult: f64) -> Option<(f64, f64)>;

    /// Move finished jobs into `out` (cleared first), promote waiters
    /// into freed capacity with `now` as their service start.
    fn reap_into(&mut self, now: SimTime, rate_mult: f64, out: &mut Vec<PsJob>);

    /// Predicted TTFT / completion time for `req` arriving now, with
    /// `extra_n` requests (of `extra_work_s` total solo-seconds) already
    /// dispatched toward this server but still on the network.
    fn predict(
        &self,
        req: &ServiceRequest,
        extra_n: usize,
        extra_work_s: f64,
        rate_mult: f64,
    ) -> ServicePrediction;

    /// Jobs currently in service (batch occupancy).
    fn n_active(&self) -> usize;

    /// Jobs waiting for a slot.
    fn n_waiting(&self) -> usize;

    /// Max concurrent jobs in service (batch slots / lanes).
    fn slot_capacity(&self) -> usize;

    /// Bounded wait-queue capacity.
    fn queue_capacity(&self) -> usize;

    /// Total remaining work across active + waiting jobs, in
    /// solo-service seconds (scheduler backlog estimate).
    fn backlog_s(&self) -> f64;
}

/// Build the model a [`ServerSpec`] asks for.
pub fn build_model(spec: &ServerSpec) -> Box<dyn ServiceModel> {
    match spec.service_model {
        ServiceModelKind::Ps => Box::new(PsServiceModel::new(spec.clone())),
        ServiceModelKind::TokenBatch { kv_tokens } => Box::new(
            super::token_batch::TokenBatchModel::new(spec.clone(), kv_tokens as u64),
        ),
    }
}

/// The historical processor-sharing fluid behind the trait: a
/// [`PsQueue`] over solo-service seconds with the sub-linear
/// [`batch_efficiency`] rate split. Every formula here is copied verbatim
/// from the pre-trait `ServerSim`, so a PS-default cluster is
/// bit-identical pre/post refactor (pinned by
/// `rust/tests/service_model_identity.rs`).
#[derive(Debug)]
pub struct PsServiceModel {
    spec: ServerSpec,
    queue: PsQueue,
}

impl PsServiceModel {
    pub fn new(spec: ServerSpec) -> Self {
        let slots = spec.slots;
        PsServiceModel {
            spec,
            queue: PsQueue::new(slots),
        }
    }

    /// Work/s granted to each active job at outage multiplier `mult` —
    /// the exact pre-trait `ServerSim::per_job_rate`.
    fn per_job_rate(&self, mult: f64) -> f64 {
        let n = self.queue.n_active();
        if n == 0 {
            return 0.0;
        }
        mult * batch_efficiency(n, self.spec.batch_alpha) / n as f64
    }

    /// Direct access to the underlying queue (differential tests and the
    /// PS-equivalence executable spec).
    pub fn queue(&self) -> &PsQueue {
        &self.queue
    }
}

impl ServiceModel for PsServiceModel {
    fn admit(&mut self, id: u64, req: &ServiceRequest, now: SimTime) {
        let work = self.spec.solo_work(req);
        self.queue.push(id, work, now);
    }

    fn would_drop(&self) -> bool {
        self.queue.n_active() >= self.queue.max_active()
            && self.queue.n_waiting() >= self.spec.queue_limit
    }

    fn advance(&mut self, dt: SimTime, rate_mult: f64, energy_per_job: f64) {
        let rate = self.per_job_rate(rate_mult);
        self.queue.advance_energy(dt, rate, energy_per_job);
    }

    fn next_completion_in(&self, rate_mult: f64) -> Option<SimTime> {
        self.queue.next_completion_in(self.per_job_rate(rate_mult))
    }

    fn completion_key(&self, rate_mult: f64) -> Option<(f64, f64)> {
        let rate = self.per_job_rate(rate_mult);
        if rate > 0.0 {
            self.queue.peek_finish_work().map(|fw| (fw, rate))
        } else {
            None
        }
    }

    fn reap_into(&mut self, now: SimTime, rate_mult: f64, out: &mut Vec<PsJob>) {
        let rate = self.per_job_rate(rate_mult);
        self.queue.reap_into(now, rate, out);
    }

    fn predict(
        &self,
        req: &ServiceRequest,
        extra_n: usize,
        extra_work_s: f64,
        rate_mult: f64,
    ) -> ServicePrediction {
        let work = self.spec.solo_work(req);
        let occupied = self.queue.n_active() + extra_n;
        let n_after = (occupied + 1).min(self.queue.max_active());
        let eff = batch_efficiency(n_after, self.spec.batch_alpha).max(1e-9);
        let stretch = n_after as f64 / eff;
        let mult = if rate_mult > 0.0 { rate_mult } else { 1e-9 };
        // Queue wait: backlog ahead of us divided by total service rate.
        // backlog() is an O(1) incremental aggregate, so this predictor is
        // constant-time even on a saturated server.
        let wait = if occupied >= self.queue.max_active() {
            (self.queue.backlog() + extra_work_s) / (eff * mult)
        } else {
            0.0
        };
        // TTFT on a fluid server: the prefill share of the stretched
        // service, after the queue wait.
        let prefill_s = req.prompt_tokens as f64 / self.spec.prefill_rate;
        ServicePrediction {
            ttft_s: wait + prefill_s * stretch / mult,
            total_s: wait + work * stretch / mult,
        }
    }

    fn n_active(&self) -> usize {
        self.queue.n_active()
    }

    fn n_waiting(&self) -> usize {
        self.queue.n_waiting()
    }

    fn slot_capacity(&self) -> usize {
        self.queue.max_active()
    }

    fn queue_capacity(&self) -> usize {
        self.spec.queue_limit
    }

    fn backlog_s(&self) -> f64 {
        self.queue.backlog()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::server::paper_testbed;
    use crate::workload::service::{ServiceClass, ServiceRequest, SloSpec};

    fn req(id: u64, prompt: u32, output: u32) -> ServiceRequest {
        ServiceRequest {
            id,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: prompt,
            output_tokens: output,
            slo: SloSpec::completion_only(4.0),
            payload_bytes: 10_000,
            session: None,
        }
    }

    #[test]
    fn kind_selects_implementation() {
        let mut spec = paper_testbed("llama2-7b")[0].clone();
        assert_eq!(spec.service_model, ServiceModelKind::Ps);
        let m = build_model(&spec);
        assert_eq!(m.slot_capacity(), spec.slots);
        spec.service_model = ServiceModelKind::token_batch_for(spec.slots);
        let t = build_model(&spec);
        assert_eq!(t.slot_capacity(), spec.slots);
        assert_eq!(t.n_active(), 0);
    }

    #[test]
    fn token_batch_for_scales_kv_with_slots() {
        match ServiceModelKind::token_batch_for(8) {
            ServiceModelKind::TokenBatch { kv_tokens } => assert_eq!(kv_tokens, 8 * 1536),
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn ps_model_matches_raw_queue_formulas() {
        // The trait wrapper must reproduce the raw-queue numbers exactly:
        // same admitted work, same per-job rate, same completion estimate.
        let spec = paper_testbed("llama2-7b")[0].clone();
        let mut m = PsServiceModel::new(spec.clone());
        let r = req(1, 130, 10);
        let work = spec.solo_work(&r);
        m.admit(1, &r, 0.0);
        assert_eq!(m.n_active(), 1);
        let eta = m.next_completion_in(1.0).unwrap();
        assert!((eta - work).abs() < 1e-12);
        let key = m.completion_key(1.0).unwrap();
        assert_eq!(key.0, m.queue().peek_finish_work().unwrap());
        assert_eq!(key.1, 1.0); // solo: eff(1)/1 = 1
        // Outage: no completion, no key.
        assert!(m.next_completion_in(0.0).is_none());
        assert!(m.completion_key(0.0).is_none());
    }

    #[test]
    fn ps_predict_matches_pre_trait_formula() {
        let spec = paper_testbed("llama2-7b")[0].clone();
        let mut m = PsServiceModel::new(spec.clone());
        let probe = req(99, 100, 40);
        let empty = m.predict(&probe, 0, 0.0, 1.0);
        assert!((empty.total_s - spec.solo_work(&probe)).abs() < 1e-12);
        assert!(empty.ttft_s <= empty.total_s);
        for i in 0..spec.slots as u64 {
            m.admit(i, &req(i, 100, 100), 0.0);
        }
        let loaded = m.predict(&probe, 0, 0.0, 1.0);
        assert!(loaded.total_s > empty.total_s);
        // Saturated + in-flight work raises the wait term further.
        let inflight = m.predict(&probe, 2, 10.0, 1.0);
        assert!(inflight.total_s > loaded.total_s);
    }

    #[test]
    fn ps_would_drop_mirrors_bounds() {
        let spec = paper_testbed("llama2-7b")[0].clone();
        let cap = spec.slots + spec.queue_limit;
        let mut m = PsServiceModel::new(spec);
        for i in 0..cap as u64 {
            assert!(!m.would_drop(), "dropped too early at {i}");
            m.admit(i, &req(i, 50, 20), 0.0);
        }
        assert!(m.would_drop());
        assert_eq!(m.n_active() + m.n_waiting(), cap);
    }

    #[test]
    fn ps_energy_attribution_flows_to_reaped_jobs() {
        let spec = paper_testbed("llama2-7b")[0].clone();
        let mut m = PsServiceModel::new(spec.clone());
        let r = req(1, 100, 10);
        let work = spec.solo_work(&r);
        m.admit(1, &r, 0.0);
        // Run to completion in two advances; 3 J per interval.
        m.advance(work / 2.0, 1.0, 3.0);
        m.advance(work / 2.0, 1.0, 3.0);
        let mut out = Vec::new();
        m.reap_into(work, 1.0, &mut out);
        assert_eq!(out.len(), 1);
        assert!((out[0].energy_j - 6.0).abs() < 1e-12);
        assert_eq!(m.n_active(), 0);
        assert!((m.backlog_s() - 0.0).abs() < 1e-12);
    }
}
