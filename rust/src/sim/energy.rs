//! Energy accounting: the three terms of the paper's objective (Eq. 2) —
//! transmission, inference, and idle energy — with the weight factors
//! ω_tran, ω_infer, ω_idle.

/// Weighted energy objective (Eq. 2). Defaults weigh the terms equally.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyWeights {
    pub w_tran: f64,
    pub w_infer: f64,
    pub w_idle: f64,
}

impl Default for EnergyWeights {
    fn default() -> Self {
        EnergyWeights {
            w_tran: 1.0,
            w_infer: 1.0,
            w_idle: 1.0,
        }
    }
}

/// Accumulated energy, joules, split by objective term.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub tran_j: f64,
    pub infer_j: f64,
    pub idle_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.tran_j + self.infer_j + self.idle_j
    }

    /// Weighted objective value (the quantity CS-UCB minimizes).
    pub fn weighted(&self, w: &EnergyWeights) -> f64 {
        w.w_tran * self.tran_j + w.w_infer * self.infer_j + w.w_idle * self.idle_j
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.tran_j += other.tran_j;
        self.infer_j += other.infer_j;
        self.idle_j += other.idle_j;
    }

    /// Kilowatt-hours, for report readability.
    pub fn total_kwh(&self) -> f64 {
        self.total_j() / 3.6e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_weights() {
        let e = EnergyBreakdown {
            tran_j: 1.0,
            infer_j: 2.0,
            idle_j: 3.0,
        };
        assert!((e.total_j() - 6.0).abs() < 1e-12);
        let w = EnergyWeights {
            w_tran: 2.0,
            w_infer: 0.5,
            w_idle: 1.0,
        };
        assert!((e.weighted(&w) - (2.0 + 1.0 + 3.0)).abs() < 1e-12);
    }

    #[test]
    fn add_accumulates() {
        let mut a = EnergyBreakdown::default();
        a.add(&EnergyBreakdown {
            tran_j: 1.0,
            infer_j: 1.0,
            idle_j: 1.0,
        });
        a.add(&EnergyBreakdown {
            tran_j: 0.5,
            infer_j: 0.0,
            idle_j: 0.0,
        });
        assert!((a.tran_j - 1.5).abs() < 1e-12);
        assert!((a.total_j() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn kwh_conversion() {
        let e = EnergyBreakdown {
            tran_j: 3.6e6,
            infer_j: 0.0,
            idle_j: 0.0,
        };
        assert!((e.total_kwh() - 1.0).abs() < 1e-12);
    }
}
