//! Processor-sharing queue with bounded concurrency and FIFO overflow,
//! implemented on **virtual work time** so every hot-path operation is
//! O(1) or O(log n) regardless of how many jobs share the resource.
//!
//! Both resource types in the cluster are PS systems:
//! * a network link divides its (fluctuating) bandwidth across concurrent
//!   uploads — this is what produces the paper's cloud-uplink congestion
//!   collapse (Fig. 2);
//! * a server divides its token throughput across the requests in its batch
//!   (continuous batching), with a sub-linear batching-efficiency curve and
//!   at most `max_active` concurrent slots; excess requests wait FIFO.
//!
//! Jobs carry "remaining work" in owner-defined units (bytes for links,
//! solo-service seconds for servers). The owner advances the queue between
//! events with the per-job rate that held over that interval and schedules
//! the next completion through a [`Generation`]-stamped event.
//!
//! # Virtual work time
//!
//! Under processor sharing every active job receives the *same* service
//! rate, so instead of decrementing each job's `remaining` on every
//! `advance` (O(active jobs) — quadratic over a congestion collapse where
//! hundreds of uploads share one pipe) we keep one cumulative counter
//! `attained`: the total service each continuously-active job has received.
//! A job admitted when the counter reads `A` with `work` units to do is
//! finished exactly when the counter reaches its **finish work**
//! `A + work`; its current remaining work is `finish_work - attained`.
//! `advance` then just bumps the counter (O(1)), the earliest completion is
//! the minimum finish work (a binary heap peek, O(1), with O(log n)
//! maintenance), and per-job energy attribution becomes the difference of a
//! second cumulative integral sampled at admission and at removal.
//! Aggregate backlog is maintained incrementally so scheduler snapshots
//! stop summing every job.
//!
//! [`Generation`]: super::time::Generation

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use super::time::SimTime;

/// Time threshold (seconds of service at the current rate) below which a
/// job counts as finished. Work-unit magnitudes differ wildly between
/// owners (bytes ~1e5 vs solo-seconds ~1), so the "done" tolerance must be
/// expressed in *time*: a job with less than a nanosecond of service left
/// is complete. Guards against float drift producing zero-width event
/// storms.
const DONE_EPS_S: f64 = 1e-9;

/// Snapshot of one job handed back to the owner on reap/cancel (and from
/// [`PsQueue::job`] for inspection).
#[derive(Debug, Clone)]
pub struct PsJob {
    pub id: u64,
    pub remaining: f64,
    /// Time the job entered the queue (for queue-wait accounting).
    pub enqueued_at: SimTime,
    /// Time the job entered service (first moment it received rate).
    pub started_at: Option<SimTime>,
    /// Energy attributed to this job by the owner (J), accrued while in
    /// service and realized at reap/cancel from the cumulative integral.
    pub energy_j: f64,
}

/// An in-service job: everything is expressed relative to the queue's
/// cumulative counters so no per-job state needs touching on advance.
#[derive(Debug, Clone, Copy)]
struct ActiveJob {
    /// Value of `attained` at which this job completes.
    finish_work: f64,
    /// Admission sequence number: unique, monotone; FIFO tie-break for
    /// equal finish work and staleness stamp for heap entries.
    seq: u64,
    enqueued_at: SimTime,
    started_at: SimTime,
    /// Value of `energy_acc` when this job entered service.
    energy_offset: f64,
}

/// A job waiting for a slot: untouched by service, so it keeps raw work.
#[derive(Debug, Clone, Copy)]
struct WaitingJob {
    id: u64,
    work: f64,
    enqueued_at: SimTime,
}

/// Min-ordering key for the completion heap: earliest finish work first,
/// FIFO (admission order) on ties. `finish_work` is never NaN — `push`
/// rejects non-finite work and the counters only accumulate finite values.
#[derive(Debug, Clone, Copy)]
struct HeapKey {
    finish_work: f64,
    seq: u64,
    id: u64,
}

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for HeapKey {}

impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-finish-first.
        other
            .finish_work
            .partial_cmp(&self.finish_work)
            // lint: allow(p1, n1) push asserts finite finish_work, so the ordering is total
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug)]
pub struct PsQueue {
    /// In-service jobs by id. Never iterated at all (pallas-lint rule D2
    /// enforces this): completion order comes from the heap, aggregates
    /// from the incremental sums. The old `active_ids()` accessor, which
    /// leaked `keys()` in arbitrary order, was removed when the lint
    /// landed — a sorted snapshot can be rebuilt from `reap` results if a
    /// caller ever needs one.
    active: HashMap<u64, ActiveJob>,
    /// Completion order over `active`, keyed by (finish_work, seq). Kept
    /// exactly in sync with `active` (cancel retains the heap), so the top
    /// is always the next completion.
    heap: BinaryHeap<HeapKey>,
    waiting: VecDeque<WaitingJob>,
    max_active: usize,
    /// Cumulative service attained by every continuously-active job
    /// (virtual work time). Reset to zero whenever the queue drains, which
    /// bounds float growth over long runs.
    attained: f64,
    /// Cumulative per-job energy integral (J), same lifecycle as
    /// `attained`.
    energy_acc: f64,
    /// Admission sequence counter.
    seq: u64,
    /// Sum of `finish_work` over active jobs: active backlog is
    /// `active_finish_sum - n_active * attained`.
    active_finish_sum: f64,
    /// Sum of raw work over waiting jobs.
    waiting_work: f64,
}

impl PsQueue {
    pub fn new(max_active: usize) -> Self {
        assert!(max_active > 0);
        PsQueue {
            active: HashMap::new(),
            heap: BinaryHeap::new(),
            waiting: VecDeque::new(),
            max_active,
            attained: 0.0,
            energy_acc: 0.0,
            seq: 0,
            active_finish_sum: 0.0,
            waiting_work: 0.0,
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    /// Cumulative attained service per continuously-active job (virtual
    /// work time). Exposed for diagnostics and the differential tests.
    pub fn attained(&self) -> f64 {
        self.attained
    }

    /// Total remaining work across active + waiting jobs (backlog estimate
    /// used by the schedulers' processing-time predictor). O(1): maintained
    /// incrementally instead of summing every job.
    pub fn backlog(&self) -> f64 {
        let active = self.active_finish_sum - self.active.len() as f64 * self.attained;
        active.max(0.0) + self.waiting_work
    }

    /// Admit a job: straight to service if a slot is free, else FIFO wait.
    pub fn push(&mut self, id: u64, work: f64, now: SimTime) {
        assert!(work.is_finite() && work > 0.0, "bad work {work}");
        if self.active.len() < self.max_active {
            self.start_service(id, work, now, now);
        } else {
            self.waiting.push_back(WaitingJob {
                id,
                work,
                enqueued_at: now,
            });
            self.waiting_work += work;
        }
    }

    /// Put a job in service at `now`: stamp its finish work and energy
    /// offset against the cumulative counters.
    fn start_service(&mut self, id: u64, work: f64, enqueued_at: SimTime, now: SimTime) {
        self.seq += 1;
        let job = ActiveJob {
            finish_work: self.attained + work,
            seq: self.seq,
            enqueued_at,
            started_at: now,
            energy_offset: self.energy_acc,
        };
        self.heap.push(HeapKey {
            finish_work: job.finish_work,
            seq: job.seq,
            id,
        });
        self.active_finish_sum += job.finish_work;
        let prev = self.active.insert(id, job);
        debug_assert!(prev.is_none(), "duplicate ps job id {id}");
    }

    /// Remove a job from service, realizing its remaining work and energy
    /// from the counters. The caller is responsible for its heap entry
    /// (reap pops it; cancel retains it away).
    fn finish_service(&mut self, id: u64, job: ActiveJob) -> PsJob {
        self.active_finish_sum -= job.finish_work;
        if self.active.is_empty() {
            // Drained: clear accumulated rounding residue.
            self.active_finish_sum = 0.0;
        }
        PsJob {
            id,
            remaining: job.finish_work - self.attained,
            enqueued_at: job.enqueued_at,
            started_at: Some(job.started_at),
            energy_j: self.energy_acc - job.energy_offset,
        }
    }

    /// Promote waiters into free slots. `now` stamps their service start.
    fn promote_waiters(&mut self, now: SimTime) {
        while self.active.len() < self.max_active {
            match self.waiting.pop_front() {
                Some(w) => {
                    self.waiting_work -= w.work;
                    if self.waiting.is_empty() {
                        self.waiting_work = 0.0;
                    }
                    self.start_service(w.id, w.work, w.enqueued_at, now);
                }
                None => break,
            }
        }
        if self.is_idle() {
            // Fully drained: renormalize the counters so `attained` and
            // `energy_acc` stay small over arbitrarily long simulations.
            self.attained = 0.0;
            self.energy_acc = 0.0;
        }
    }

    /// Advance all active jobs by `dt` seconds at `per_job_rate` work/s.
    /// The caller guarantees the rate was constant over the interval (it
    /// bumps the generation and re-advances on every occupancy change).
    /// O(1): bumps the cumulative counter, touches no job.
    pub fn advance(&mut self, dt: SimTime, per_job_rate: f64) {
        self.advance_energy(dt, per_job_rate, 0.0);
    }

    /// Advance and additionally attribute `energy_per_job` joules to every
    /// active job (marginal per-service energy accounting). O(1): the
    /// per-job energy is realized lazily at reap/cancel time as the
    /// difference of the cumulative integral.
    pub fn advance_energy(&mut self, dt: SimTime, per_job_rate: f64, energy_per_job: f64) {
        // lint: no-alloc O(1) per-event bookkeeping on the DES hot path
        debug_assert!(dt >= 0.0 && per_job_rate >= 0.0);
        if dt == 0.0 || self.active.is_empty() {
            return;
        }
        self.attained += dt * per_job_rate;
        self.energy_acc += energy_per_job;
        // lint: end-no-alloc
    }

    /// Remove finished jobs, promote waiters into freed slots, and return
    /// the finished jobs. `now` stamps promoted waiters' service start.
    /// `per_job_rate` is the rate that applied up to `now`; jobs within
    /// `DONE_EPS_S` seconds of completion at that rate are done.
    ///
    /// Completion order is (finish work, admission order) — earliest
    /// finisher first, FIFO on exact ties. O(k log n) for k completions.
    pub fn reap(&mut self, now: SimTime, per_job_rate: f64) -> Vec<PsJob> {
        let mut out = Vec::new();
        self.reap_into(now, per_job_rate, &mut out);
        out
    }

    /// Allocation-free variant of [`reap`](Self::reap): clears and fills a
    /// caller-owned buffer so the event loop can reuse one Vec across every
    /// completion event.
    pub fn reap_into(&mut self, now: SimTime, per_job_rate: f64, out: &mut Vec<PsJob>) {
        // lint: no-alloc completion reaping runs per event; `out` is caller-owned
        out.clear();
        let eps = (per_job_rate * DONE_EPS_S).max(f64::MIN_POSITIVE);
        let threshold = self.attained + eps;
        while let Some(top) = self.heap.peek() {
            // Defensive staleness check: `heap` mirrors `active` exactly
            // (cancel retains), so this only skips entries if an invariant
            // was broken upstream (e.g. a duplicate id in release mode).
            let valid = self
                .active
                .get(&top.id)
                .is_some_and(|j| j.seq == top.seq);
            if !valid {
                self.heap.pop();
                continue;
            }
            if top.finish_work > threshold {
                break;
            }
            let key = self.heap.pop().expect("peeked entry"); // lint: allow(p1) peek above proved the heap non-empty
            let job = self.active.remove(&key.id).expect("validated entry"); // lint: allow(p1) the staleness check above proved membership
            let done = self.finish_service(key.id, job);
            out.push(done);
        }
        self.promote_waiters(now);
        // lint: end-no-alloc
    }

    /// Finish-work stamp of the earliest active job (the heap top), in
    /// virtual work units. Together with the per-job rate this is the
    /// *exact input* that determines the next completion time, which is
    /// what the engine's reschedule guard compares to decide whether an
    /// already-scheduled completion event is still correct (a float-exact
    /// comparison, immune to the clock-advance drift that comparing
    /// recomputed times would suffer).
    pub fn peek_finish_work(&self) -> Option<f64> {
        self.heap.peek().map(|k| k.finish_work)
    }

    /// Seconds until the earliest active job finishes at `per_job_rate`.
    /// O(1): the earliest finisher is the heap top.
    pub fn next_completion_in(&self, per_job_rate: f64) -> Option<SimTime> {
        if per_job_rate <= 0.0 {
            return None;
        }
        self.heap
            .peek()
            .map(|k| (k.finish_work - self.attained).max(0.0) / per_job_rate)
    }

    /// Remove a job wherever it is (failure injection / cancellation).
    /// O(n) — cancellation is rare (it is not on the event hot path).
    pub fn cancel(&mut self, id: u64, now: SimTime) -> Option<PsJob> {
        if let Some(job) = self.active.remove(&id) {
            let seq = job.seq;
            self.heap.retain(|k| k.seq != seq);
            let out = self.finish_service(id, job);
            // Freed a slot: promote a waiter.
            self.promote_waiters(now);
            return Some(out);
        }
        if let Some(i) = self.waiting.iter().position(|w| w.id == id) {
            let w = self.waiting.remove(i)?;
            self.waiting_work -= w.work;
            if self.waiting.is_empty() {
                self.waiting_work = 0.0;
            }
            if self.is_idle() {
                self.attained = 0.0;
                self.energy_acc = 0.0;
            }
            return Some(PsJob {
                id: w.id,
                remaining: w.work,
                enqueued_at: w.enqueued_at,
                started_at: None,
                energy_j: 0.0,
            });
        }
        None
    }

    /// Snapshot one job (active or waiting) by id, with its remaining work
    /// and energy realized against the current counters.
    pub fn job(&self, id: u64) -> Option<PsJob> {
        if let Some(j) = self.active.get(&id) {
            return Some(PsJob {
                id,
                remaining: j.finish_work - self.attained,
                enqueued_at: j.enqueued_at,
                started_at: Some(j.started_at),
                energy_j: self.energy_acc - j.energy_offset,
            });
        }
        self.waiting.iter().find(|w| w.id == id).map(|w| PsJob {
            id: w.id,
            remaining: w.work,
            enqueued_at: w.enqueued_at,
            started_at: None,
            energy_j: 0.0,
        })
    }
}

/// Sub-linear batching efficiency: total service rate multiplier for `n`
/// concurrent jobs, eff(n) = n^alpha, clamped to [1, n]. alpha ~ 0.85 for a
/// GPU with continuous batching (near-linear until memory-bound), ~ 0.25
/// for a CPU edge box (little parallel headroom).
pub fn batch_efficiency(n: usize, alpha: f64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (n as f64).powf(alpha).clamp(1.0, n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_overflow_and_promotion() {
        let mut q = PsQueue::new(2);
        q.push(1, 10.0, 0.0);
        q.push(2, 10.0, 0.0);
        q.push(3, 10.0, 0.0);
        assert_eq!(q.n_active(), 2);
        assert_eq!(q.n_waiting(), 1);
        q.advance(10.0, 1.0);
        // Both active jobs finish together (same work, same rate); ties
        // reap in admission order.
        let done = q.reap(10.0, 1.0);
        assert_eq!(done.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(q.n_active(), 1);
        let promoted = q.job(3).unwrap();
        assert_eq!(promoted.started_at, Some(10.0));
        assert!((promoted.remaining - 10.0).abs() < 1e-12);
    }

    #[test]
    fn next_completion_is_min() {
        let mut q = PsQueue::new(4);
        q.push(1, 8.0, 0.0);
        q.push(2, 4.0, 0.0);
        q.push(3, 6.0, 0.0);
        let t = q.next_completion_in(2.0).unwrap();
        assert!((t - 2.0).abs() < 1e-12); // job 2: 4.0 work / 2.0 rate
    }

    #[test]
    fn advance_respects_rate() {
        let mut q = PsQueue::new(1);
        q.push(1, 10.0, 0.0);
        q.advance(3.0, 2.0);
        assert!((q.job(1).unwrap().remaining - 4.0).abs() < 1e-12);
        assert!(q.reap(3.0, 2.0).is_empty());
        q.advance(2.0, 2.0);
        assert_eq!(q.reap(5.0, 2.0).len(), 1);
        assert!(q.is_idle());
    }

    #[test]
    fn backlog_counts_waiting() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        q.push(2, 7.0, 0.0);
        assert!((q.backlog() - 12.0).abs() < 1e-12);
        // Backlog tracks progress incrementally.
        q.advance(2.0, 1.0);
        assert!((q.backlog() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn cancel_active_promotes_waiter() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        q.push(2, 7.0, 0.0);
        let c = q.cancel(1, 1.0).unwrap();
        assert_eq!(c.id, 1);
        assert_eq!(q.n_active(), 1);
        let promoted = q.job(2).unwrap();
        assert_eq!(promoted.started_at, Some(1.0));
        assert!((promoted.remaining - 7.0).abs() < 1e-12);
    }

    #[test]
    fn cancel_waiting() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        q.push(2, 7.0, 0.0);
        assert_eq!(q.cancel(2, 0.5).unwrap().id, 2);
        assert_eq!(q.n_active(), 1);
        assert_eq!(q.n_waiting(), 0);
        assert!(q.cancel(99, 0.5).is_none());
    }

    #[test]
    fn zero_rate_never_completes() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        assert!(q.next_completion_in(0.0).is_none());
        q.advance(100.0, 0.0);
        assert!(q.reap(100.0, 0.0).is_empty());
        // Remaining work untouched by the zero-rate interval.
        assert!((q.job(1).unwrap().remaining - 5.0).abs() < 1e-12);
    }

    #[test]
    fn energy_attributed_over_service_intervals() {
        let mut q = PsQueue::new(4);
        q.push(1, 2.0, 0.0);
        // Job 1 alone for 1 s: 5 J.
        q.advance_energy(1.0, 1.0, 5.0);
        q.push(2, 2.0, 1.0);
        // Both for 1 s: 3 J each. Job 1 reaches its finish work.
        q.advance_energy(1.0, 1.0, 3.0);
        let done = q.reap(2.0, 1.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert!((done[0].energy_j - 8.0).abs() < 1e-12);
        // Job 2 only saw the second interval.
        assert!((q.job(2).unwrap().energy_j - 3.0).abs() < 1e-12);
        assert!((q.job(2).unwrap().remaining - 1.0).abs() < 1e-12);
    }

    #[test]
    fn counters_renormalize_when_drained() {
        let mut q = PsQueue::new(2);
        q.push(1, 3.0, 0.0);
        q.advance_energy(3.0, 1.0, 7.0);
        assert_eq!(q.reap(3.0, 1.0).len(), 1);
        assert!(q.is_idle());
        assert_eq!(q.attained(), 0.0);
        // A fresh busy period starts from clean counters.
        q.push(2, 4.0, 5.0);
        assert!((q.next_completion_in(2.0).unwrap() - 2.0).abs() < 1e-12);
        assert!((q.job(2).unwrap().energy_j - 0.0).abs() < 1e-12);
    }

    #[test]
    fn equal_finish_ties_complete_fifo() {
        let mut q = PsQueue::new(8);
        for id in [4u64, 7, 9] {
            q.push(id, 1.0, 0.0);
        }
        q.advance(1.0, 1.0);
        let done = q.reap(1.0, 1.0);
        assert_eq!(done.iter().map(|j| j.id).collect::<Vec<_>>(), vec![4, 7, 9]);
    }

    #[test]
    fn reap_into_reuses_buffer() {
        let mut q = PsQueue::new(4);
        let mut buf = Vec::new();
        q.push(1, 1.0, 0.0);
        q.advance(1.0, 1.0);
        q.reap_into(1.0, 1.0, &mut buf);
        assert_eq!(buf.len(), 1);
        // The buffer is cleared on the next call, not appended to.
        q.push(2, 1.0, 1.0);
        q.advance(1.0, 1.0);
        q.reap_into(2.0, 1.0, &mut buf);
        assert_eq!(buf.len(), 1);
        assert_eq!(buf[0].id, 2);
    }

    #[test]
    fn batch_efficiency_shape() {
        assert_eq!(batch_efficiency(0, 0.85), 0.0);
        assert_eq!(batch_efficiency(1, 0.85), 1.0);
        let e4 = batch_efficiency(4, 0.85);
        assert!(e4 > 1.0 && e4 < 4.0);
        // Higher alpha -> closer to linear.
        assert!(batch_efficiency(8, 0.9) > batch_efficiency(8, 0.3));
    }
}
