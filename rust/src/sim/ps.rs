//! Processor-sharing queue with bounded concurrency and FIFO overflow.
//!
//! Both resource types in the cluster are PS systems:
//! * a network link divides its (fluctuating) bandwidth across concurrent
//!   uploads — this is what produces the paper's cloud-uplink congestion
//!   collapse (Fig. 2);
//! * a server divides its token throughput across the requests in its batch
//!   (continuous batching), with a sub-linear batching-efficiency curve and
//!   at most `max_active` concurrent slots; excess requests wait FIFO.
//!
//! Jobs carry "remaining work" in owner-defined units (bytes for links,
//! solo-service seconds for servers). The owner advances the queue between
//! events with the per-job rate that held over that interval and schedules
//! the next completion through a [`Generation`]-stamped event.

use std::collections::VecDeque;

use super::time::SimTime;

/// Time threshold (seconds of service at the current rate) below which a
/// job counts as finished. Work-unit magnitudes differ wildly between
/// owners (bytes ~1e5 vs solo-seconds ~1), so the "done" tolerance must be
/// expressed in *time*: a job with less than a nanosecond of service left
/// is complete. Guards against float drift producing zero-width event
/// storms.
const DONE_EPS_S: f64 = 1e-9;

#[derive(Debug, Clone)]
pub struct PsJob {
    pub id: u64,
    pub remaining: f64,
    /// Time the job entered the queue (for queue-wait accounting).
    pub enqueued_at: SimTime,
    /// Time the job entered service (first moment it received rate).
    pub started_at: Option<SimTime>,
    /// Energy attributed to this job by the owner (J), accrued in advance().
    pub energy_j: f64,
}

#[derive(Debug)]
pub struct PsQueue {
    active: Vec<PsJob>,
    waiting: VecDeque<PsJob>,
    max_active: usize,
}

impl PsQueue {
    pub fn new(max_active: usize) -> Self {
        assert!(max_active > 0);
        PsQueue {
            active: Vec::new(),
            waiting: VecDeque::new(),
            max_active,
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    /// Total remaining work across active + waiting jobs (backlog estimate
    /// used by the schedulers' processing-time predictor).
    pub fn backlog(&self) -> f64 {
        self.active.iter().map(|j| j.remaining).sum::<f64>()
            + self.waiting.iter().map(|j| j.remaining).sum::<f64>()
    }

    /// Admit a job: straight to service if a slot is free, else FIFO wait.
    pub fn push(&mut self, id: u64, work: f64, now: SimTime) {
        assert!(work.is_finite() && work > 0.0, "bad work {work}");
        let mut job = PsJob {
            id,
            remaining: work,
            enqueued_at: now,
            started_at: None,
            energy_j: 0.0,
        };
        if self.active.len() < self.max_active {
            job.started_at = Some(now);
            self.active.push(job);
        } else {
            self.waiting.push_back(job);
        }
    }

    /// Advance all active jobs by `dt` seconds at `per_job_rate` work/s.
    /// The caller guarantees the rate was constant over the interval (it
    /// bumps the generation and re-advances on every occupancy change).
    pub fn advance(&mut self, dt: SimTime, per_job_rate: f64) {
        self.advance_energy(dt, per_job_rate, 0.0);
    }

    /// Advance and additionally attribute `energy_per_job` joules to every
    /// active job (marginal per-service energy accounting).
    pub fn advance_energy(&mut self, dt: SimTime, per_job_rate: f64, energy_per_job: f64) {
        debug_assert!(dt >= 0.0 && per_job_rate >= 0.0);
        if dt == 0.0 {
            return;
        }
        let dec = dt * per_job_rate;
        for j in &mut self.active {
            j.remaining -= dec;
            j.energy_j += energy_per_job;
        }
    }

    /// Remove finished jobs, promote waiters into freed slots, and return
    /// the finished jobs. `now` stamps promoted waiters' service start.
    /// `per_job_rate` is the rate that applied up to `now`; jobs within
    /// `DONE_EPS_S` seconds of completion at that rate are done.
    pub fn reap(&mut self, now: SimTime, per_job_rate: f64) -> Vec<PsJob> {
        let eps = (per_job_rate * DONE_EPS_S).max(f64::MIN_POSITIVE);
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].remaining <= eps {
                done.push(self.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        while self.active.len() < self.max_active {
            match self.waiting.pop_front() {
                Some(mut j) => {
                    j.started_at = Some(now);
                    self.active.push(j);
                }
                None => break,
            }
        }
        done
    }

    /// Seconds until the earliest active job finishes at `per_job_rate`.
    pub fn next_completion_in(&self, per_job_rate: f64) -> Option<SimTime> {
        if per_job_rate <= 0.0 {
            return None;
        }
        self.active
            .iter()
            .map(|j| (j.remaining.max(0.0)) / per_job_rate)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Remove a job wherever it is (failure injection / cancellation).
    pub fn cancel(&mut self, id: u64, now: SimTime) -> Option<PsJob> {
        if let Some(i) = self.active.iter().position(|j| j.id == id) {
            let job = self.active.swap_remove(i);
            // Freed a slot: promote a waiter.
            if let Some(mut w) = self.waiting.pop_front() {
                w.started_at = Some(now);
                self.active.push(w);
            }
            return Some(job);
        }
        if let Some(i) = self.waiting.iter().position(|j| j.id == id) {
            return self.waiting.remove(i);
        }
        None
    }

    pub fn active_jobs(&self) -> &[PsJob] {
        &self.active
    }
}

/// Sub-linear batching efficiency: total service rate multiplier for `n`
/// concurrent jobs, eff(n) = n^alpha, clamped to [1, n]. alpha ~ 0.85 for a
/// GPU with continuous batching (near-linear until memory-bound), ~ 0.25
/// for a CPU edge box (little parallel headroom).
pub fn batch_efficiency(n: usize, alpha: f64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (n as f64).powf(alpha).clamp(1.0, n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_overflow_and_promotion() {
        let mut q = PsQueue::new(2);
        q.push(1, 10.0, 0.0);
        q.push(2, 10.0, 0.0);
        q.push(3, 10.0, 0.0);
        assert_eq!(q.n_active(), 2);
        assert_eq!(q.n_waiting(), 1);
        // Finish job 1.
        q.advance(10.0, 1.0);
        // Both active jobs finish together (same work, same rate).
        let done = q.reap(10.0, 1.0);
        assert_eq!(done.len(), 2);
        assert_eq!(q.n_active(), 1);
        assert_eq!(q.active_jobs()[0].id, 3);
        assert_eq!(q.active_jobs()[0].started_at, Some(10.0));
    }

    #[test]
    fn next_completion_is_min() {
        let mut q = PsQueue::new(4);
        q.push(1, 8.0, 0.0);
        q.push(2, 4.0, 0.0);
        q.push(3, 6.0, 0.0);
        let t = q.next_completion_in(2.0).unwrap();
        assert!((t - 2.0).abs() < 1e-12); // job 2: 4.0 work / 2.0 rate
    }

    #[test]
    fn advance_respects_rate() {
        let mut q = PsQueue::new(1);
        q.push(1, 10.0, 0.0);
        q.advance(3.0, 2.0);
        assert!((q.active_jobs()[0].remaining - 4.0).abs() < 1e-12);
        assert!(q.reap(3.0, 2.0).is_empty());
        q.advance(2.0, 2.0);
        assert_eq!(q.reap(5.0, 2.0).len(), 1);
        assert!(q.is_idle());
    }

    #[test]
    fn backlog_counts_waiting() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        q.push(2, 7.0, 0.0);
        assert!((q.backlog() - 12.0).abs() < 1e-12);
    }

    #[test]
    fn cancel_active_promotes_waiter() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        q.push(2, 7.0, 0.0);
        let c = q.cancel(1, 1.0).unwrap();
        assert_eq!(c.id, 1);
        assert_eq!(q.n_active(), 1);
        assert_eq!(q.active_jobs()[0].id, 2);
        assert_eq!(q.active_jobs()[0].started_at, Some(1.0));
    }

    #[test]
    fn cancel_waiting() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        q.push(2, 7.0, 0.0);
        assert_eq!(q.cancel(2, 0.5).unwrap().id, 2);
        assert_eq!(q.n_active(), 1);
        assert_eq!(q.n_waiting(), 0);
        assert!(q.cancel(99, 0.5).is_none());
    }

    #[test]
    fn zero_rate_never_completes() {
        let mut q = PsQueue::new(1);
        q.push(1, 5.0, 0.0);
        assert!(q.next_completion_in(0.0).is_none());
        q.advance(100.0, 0.0);
        assert!(q.reap(100.0, 0.0).is_empty());
    }

    #[test]
    fn batch_efficiency_shape() {
        assert_eq!(batch_efficiency(0, 0.85), 0.0);
        assert_eq!(batch_efficiency(1, 0.85), 1.0);
        let e4 = batch_efficiency(4, 0.85);
        assert!(e4 > 1.0 && e4 < 4.0);
        // Higher alpha -> closer to linear.
        assert!(batch_efficiency(8, 0.9) > batch_efficiency(8, 0.3));
    }
}
