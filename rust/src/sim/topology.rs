//! Parameterized multi-tier cluster topologies: the generalization of the
//! paper's fixed 5-edge + 1-cloud testbed to EdgeShard-style fleets
//! (arXiv:2405.14371 evaluates multi-tier, many-instance deployments; so
//! does the cloud-edge routing study arXiv:2507.15553).
//!
//! A [`TopologyConfig`] is a list of [`TierSpec`]s — each a server
//! template, a link template, and an instance count — that [`build`]s
//! into the flat [`ClusterConfig`] every other layer already consumes
//! (DES engine, schedulers, workload scaling, the live router via
//! `Router::from_topology`). The paper testbed itself is the smallest
//! preset, and `TopologyConfig::paper(..).build()` reproduces
//! `ClusterConfig::paper(..)` field for field, so paper-scale runs are
//! decision-identical whichever constructor they start from.
//!
//! Presets: [`TopologyConfig::paper`] (6 servers),
//! [`TopologyConfig::edgeshard_10x`] (60 servers: 48 edge + 10 regional
//! hubs + 2 cloud), [`TopologyConfig::edgeshard_100x`] (600 servers).
//! "Hub" servers are mid-tier aggregation boxes — edge-kind (they sit on
//! the LAN side of the WAN boundary, and edge-only baselines like AGOD
//! may use them) with throughput, batching, and link specs between the
//! paper's two extremes.
//!
//! [`build`]: TopologyConfig::build

use super::cluster::{BandwidthMode, ClusterConfig};
use super::energy::EnergyWeights;
use super::net::LinkSpec;
use super::server::{paper_testbed, ServerKind, ServerSpec};
use super::service_model::ServiceModelKind;

/// One homogeneous tier: `count` instances stamped from the server and
/// link templates. Instance names are `{name}-{i}` (and `{name}-link-{i}`
/// for links); a single-instance tier keeps the bare template names, so
/// the paper preset reproduces the historical "cloud" / "cloud-uplink"
/// names exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct TierSpec {
    pub name: String,
    pub count: usize,
    pub server: ServerSpec,
    pub link: LinkSpec,
}

/// A multi-tier topology description that lowers to [`ClusterConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyConfig {
    pub name: String,
    pub tiers: Vec<TierSpec>,
    pub bandwidth: BandwidthMode,
    pub weights: EnergyWeights,
    pub seed: u64,
}

/// Total batch slots of the paper testbed (5×8 edge + 12 cloud) — the
/// denominator of [`TopologyConfig::capacity_scale`].
const PAPER_SLOTS: usize = 52;

impl TopologyConfig {
    /// An empty topology; add tiers with [`Self::with_tier`].
    pub fn new(name: &str, bandwidth: BandwidthMode) -> Self {
        TopologyConfig {
            name: name.to_string(),
            tiers: Vec::new(),
            bandwidth,
            weights: EnergyWeights::default(),
            seed: 0xC1A0,
        }
    }

    pub fn with_tier(mut self, tier: TierSpec) -> Self {
        self.tiers.push(tier);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run every tier's servers on `kind` (one literal kind for all
    /// tiers; use [`Self::with_service_model_by_name`] to derive per-tier
    /// KV budgets from each tier's slot count).
    pub fn with_service_model(mut self, kind: ServiceModelKind) -> Self {
        for tier in &mut self.tiers {
            tier.server.service_model = kind;
        }
        self
    }

    /// Run only tiers of the given server kind on `model` — e.g.
    /// token-batch edge tiers under PS cloud tiers, the mixed deployment
    /// the batching/quantization edge studies evaluate.
    pub fn with_service_model_for_kind(
        mut self,
        server_kind: ServerKind,
        model: ServiceModelKind,
    ) -> Self {
        for tier in &mut self.tiers {
            if tier.server.kind == server_kind {
                tier.server.service_model = model;
            }
        }
        self
    }

    /// Apply a whole-fleet service model by CLI name: "ps" (default),
    /// "token-batch" (every tier, per-tier KV budgets), or
    /// "token-batch-edge" (edge-kind tiers only; cloud stays PS).
    pub fn with_service_model_by_name(self, name: &str) -> Option<Self> {
        match name {
            "ps" => Some(self),
            "token-batch" => {
                let mut topo = self;
                for tier in &mut topo.tiers {
                    tier.server.service_model =
                        ServiceModelKind::token_batch_for(tier.server.slots);
                }
                Some(topo)
            }
            "token-batch-edge" => {
                let mut topo = self;
                for tier in &mut topo.tiers {
                    if tier.server.kind == ServerKind::Edge {
                        tier.server.service_model =
                            ServiceModelKind::token_batch_for(tier.server.slots);
                    }
                }
                Some(topo)
            }
            _ => None,
        }
    }

    /// The paper's testbed as a topology: one 5-instance edge tier + one
    /// cloud server. `build()` equals `ClusterConfig::paper(..)` exactly.
    pub fn paper(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        let servers = paper_testbed(edge_model);
        Self::new("paper", bandwidth)
            .with_tier(TierSpec {
                name: "edge".into(),
                count: 5,
                server: servers[0].clone(),
                link: LinkSpec::edge(0, false),
            })
            .with_tier(TierSpec {
                name: "cloud".into(),
                count: 1,
                server: servers[5].clone(),
                link: LinkSpec::cloud(false),
            })
    }

    /// EdgeShard-style three-tier fleet at ~10x paper scale: 48 edge
    /// devices, 10 regional hubs, 2 cloud instances (60 servers,
    /// capacity_scale ≈ 10.2).
    pub fn edgeshard_10x(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        Self::edgeshard(edge_model, bandwidth, "edgeshard-10x", 48, 10, 2)
    }

    /// EdgeShard-style three-tier fleet at ~100x paper scale: 480 edge
    /// devices, 100 regional hubs, 20 cloud instances (600 servers,
    /// capacity_scale ≈ 101.5).
    pub fn edgeshard_100x(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        Self::edgeshard(edge_model, bandwidth, "edgeshard-100x", 480, 100, 20)
    }

    fn edgeshard(
        edge_model: &str,
        bandwidth: BandwidthMode,
        name: &str,
        edges: usize,
        hubs: usize,
        clouds: usize,
    ) -> Self {
        let paper = paper_testbed(edge_model);
        let edge = paper[0].clone();
        let cloud = paper[5].clone();
        // Regional hub: LAN-side aggregation box between the paper's two
        // extremes — faster and better-batched than an edge device, far
        // cheaper per watt than the cloud GPU.
        let hub = ServerSpec {
            name: "hub".into(),
            kind: ServerKind::Edge,
            prefill_rate: edge.prefill_rate * 2.2,
            decode_rate: edge.decode_rate * 1.25,
            slots: 12,
            batch_alpha: 0.68,
            p_infer: 120.0,
            p_idle: 14.0,
            compute_capacity: 12.0,
            queue_limit: 3,
            service_model: ServiceModelKind::Ps,
        };
        let hub_link = LinkSpec {
            name: "hub-link".into(),
            bandwidth_bps: 400.0e6,
            per_flow_cap_bps: 25.0e6,
            rtt_s: 0.02,
            fluctuation: 0.0,
            fluct_period: 0.5,
            energy_j_per_mbit: 1.5,
        };
        Self::new(name, bandwidth)
            .with_tier(TierSpec {
                name: "edge".into(),
                count: edges,
                server: edge,
                link: LinkSpec::edge(0, false),
            })
            .with_tier(TierSpec {
                name: "hub".into(),
                count: hubs,
                server: hub,
                link: hub_link,
            })
            .with_tier(TierSpec {
                name: "cloud".into(),
                count: clouds,
                server: cloud,
                link: LinkSpec::cloud(false),
            })
    }

    /// Preset lookup for CLI flags: "paper" | "edgeshard-10x" |
    /// "edgeshard-100x".
    pub fn by_name(name: &str, edge_model: &str, bandwidth: BandwidthMode) -> Option<Self> {
        match name {
            "paper" => Some(Self::paper(edge_model, bandwidth)),
            "edgeshard-10x" | "10x" => Some(Self::edgeshard_10x(edge_model, bandwidth)),
            "edgeshard-100x" | "100x" => Some(Self::edgeshard_100x(edge_model, bandwidth)),
            _ => None,
        }
    }

    pub fn n_servers(&self) -> usize {
        self.tiers.iter().map(|t| t.count).sum()
    }

    pub fn total_slots(&self) -> usize {
        self.tiers.iter().map(|t| t.count * t.server.slots).sum()
    }

    /// Serving capacity relative to the paper testbed, by batch slots —
    /// the factor per-tier arrival rates should scale by to keep offered
    /// load comparable across topologies.
    pub fn capacity_scale(&self) -> f64 {
        self.total_slots() as f64 / PAPER_SLOTS as f64
    }

    /// A paper-calibrated arrival rate (req/s) scaled to this topology's
    /// capacity.
    pub fn scaled_rate(&self, paper_rate: f64) -> f64 {
        paper_rate * self.capacity_scale()
    }

    /// Lower to the flat per-server [`ClusterConfig`] every simulation
    /// layer consumes. The bandwidth mode is applied to each link template
    /// here (Fluctuating grants a template's own amplitude when it has
    /// one, else the paper's ±20 %), mirroring what
    /// `ClusterConfig::paper` does with `LinkSpec::edge`/`cloud`.
    pub fn build(&self) -> ClusterConfig {
        assert!(!self.tiers.is_empty(), "topology has at least one tier");
        let mut servers = Vec::with_capacity(self.n_servers());
        let mut links = Vec::with_capacity(self.n_servers());
        for tier in &self.tiers {
            for i in 0..tier.count {
                let mut server = tier.server.clone();
                let mut link = tier.link.clone();
                if tier.count == 1 {
                    server.name = tier.name.clone();
                } else {
                    server.name = format!("{}-{i}", tier.name);
                    link.name = format!("{}-link-{i}", tier.name);
                }
                link.fluctuation = match self.bandwidth {
                    BandwidthMode::Stable => 0.0,
                    BandwidthMode::Fluctuating => {
                        if tier.link.fluctuation > 0.0 {
                            tier.link.fluctuation
                        } else {
                            0.2
                        }
                    }
                };
                servers.push(server);
                links.push(link);
            }
        }
        ClusterConfig {
            servers,
            links,
            bandwidth: self.bandwidth,
            weights: self.weights,
            outages: Vec::new(),
            seed: self.seed,
            churn_guard: true,
        }
    }
}

pub const TOPOLOGY_PRESETS: [&str; 3] = ["paper", "edgeshard-10x", "edgeshard-100x"];

/// Shard-count selection for the sharded DES engine
/// (`--shards N|auto|weighted[:N]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardCount {
    /// One shard per tier — the natural EdgeShard decomposition: tier
    /// boundaries are exactly where cross-shard traffic pays a
    /// `LinkSpec` latency, so per-tier shards maximize the conservative
    /// lookahead window. Since PR 9 the tier plan is *volume-aware*: when
    /// the [`EventVolumeModel`] imbalance of the raw tier partition
    /// exceeds [`AUTO_REBALANCE_IMBALANCE`], the same shard count is
    /// re-cut on cumulative event weight (see
    /// [`TopologyConfig::weighted_plan`]).
    Auto,
    /// Exactly `N` shards (contiguous, server-count-balanced chunks) —
    /// the PR-8 lowering, kept for A/B runs against the weighted plans.
    Fixed(usize),
    /// Volume-weighted contiguous split on the [`EventVolumeModel`]:
    /// `Weighted(n)` cuts `n` shards on cumulative event weight;
    /// `Weighted(0)` (CLI form "weighted") uses one shard per tier as
    /// the count, i.e. "auto's shard count, always rebalanced".
    Weighted(usize),
}

impl ShardCount {
    /// Parse a `--shards` flag value: "auto", "weighted", "weighted:N",
    /// or a positive integer.
    pub fn parse(s: &str) -> Option<ShardCount> {
        if s.eq_ignore_ascii_case("auto") {
            return Some(ShardCount::Auto);
        }
        if s.eq_ignore_ascii_case("weighted") {
            return Some(ShardCount::Weighted(0));
        }
        if let Some(n) = s
            .strip_prefix("weighted:")
            .or_else(|| s.strip_prefix("WEIGHTED:"))
        {
            return match n.parse::<usize>() {
                Ok(n) if n >= 1 => Some(ShardCount::Weighted(n)),
                _ => None,
            };
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Some(ShardCount::Fixed(n)),
            _ => None,
        }
    }
}

/// Rebalance threshold for [`ShardCount::Auto`]: when the raw one-shard-
/// per-tier partition's [`ShardPlan::imbalance`] (max/min per-shard event
/// weight) exceeds this, the same shard count is re-cut on cumulative
/// volume via [`TopologyConfig::weighted_plan`]. 2.0 means "the critical
/// shard carries at least twice the lightest shard's events" — past that
/// point the tier plan's lookahead advantage cannot recover the wall-clock
/// lost to the straggler.
pub const AUTO_REBALANCE_IMBALANCE: f64 = 2.0;

/// DES events per completed request under the PS fluid model: upload
/// dispatch + link completion + compute arrival + server completion. The
/// absolute value cancels out of every balanced-cut decision (only
/// *ratios* between tiers matter); it is kept literal so the model's
/// per-server weights read as events/simulated-second.
const EVENTS_PER_REQUEST: f64 = 4.0;

/// Event multiplier for token-batch servers vs PS: the discrete-iteration
/// model reschedules per batch iteration instead of per fluid completion,
/// roughly tripling per-request event counts at calibrated loads (see
/// `sim/token_batch.rs`).
const TOKEN_BATCH_EVENT_MULT: f64 = 3.0;

/// The paper-calibrated arrival rate (req/s) the volume model assumes when
/// estimating per-tier arrival shares — the same 15 req/s that
/// `paper_scale_sim` scales by capacity. The model only consumes rate
/// *shares*, so runs at other absolute rates still balance correctly.
const CALIBRATED_PAPER_RATE: f64 = 15.0;

/// Per-server event-volume estimate lowered from what [`TopologyConfig`]
/// already knows — the input to [`ShardPlan::weighted`] and the
/// volume-aware `Auto` rebalance.
///
/// Per server of a tier, the weight is
/// `arrival_share · EVENTS_PER_REQUEST · model_mult + fluct_ticks_per_s`:
///
/// - **arrival share**: capacity-proportional per-server rate, mirroring
///   exactly how `--mix tiered` lowers the scaled rate onto tiers
///   (`scaled_rate(15.0) · server_slots / total_slots`);
/// - **model mult**: 1.0 for PS fluid completions,
///   [`TOKEN_BATCH_EVENT_MULT`] for discrete-iteration token batching;
/// - **fluct ticks**: `1 / fluct_period` when the topology runs
///   [`BandwidthMode::Fluctuating`] (each link re-arms a FluctTick every
///   period), 0 in Stable mode.
///
/// Fault-plan and health-probe events are uniform background across
/// servers (probes scan the whole fleet; generative MTTF/MTTR streams are
/// per-server i.i.d.), so they shift every weight equally and barely move
/// a balanced cut; [`Self::with_background`] adds that density when a
/// caller wants it reflected anyway. Weights allocate at lowering time
/// only — nothing here runs on the per-event hot path.
#[derive(Debug, Clone, PartialEq)]
pub struct EventVolumeModel {
    /// Estimated events/simulated-second per server, global server order.
    pub per_server: Vec<f64>,
}

impl EventVolumeModel {
    /// Estimate per-server event weights from the topology's tier
    /// templates (arrival shares, service-model kinds, fluctuation
    /// cadence).
    pub fn from_topology(topo: &TopologyConfig) -> EventVolumeModel {
        let total_slots = topo.total_slots() as f64;
        let rate = topo.scaled_rate(CALIBRATED_PAPER_RATE);
        let mut per_server = Vec::with_capacity(topo.n_servers());
        for tier in &topo.tiers {
            let mult = match tier.server.service_model {
                ServiceModelKind::Ps => 1.0,
                ServiceModelKind::TokenBatch { .. } => TOKEN_BATCH_EVENT_MULT,
            };
            let arrivals = if total_slots > 0.0 {
                rate * tier.server.slots as f64 / total_slots
            } else {
                0.0
            };
            let ticks = match topo.bandwidth {
                BandwidthMode::Fluctuating if tier.link.fluct_period > 0.0 => {
                    1.0 / tier.link.fluct_period
                }
                _ => 0.0,
            };
            let w = arrivals * EVENTS_PER_REQUEST * mult + ticks;
            for _ in 0..tier.count {
                per_server.push(w);
            }
        }
        EventVolumeModel { per_server }
    }

    /// Add a uniform background event density (events/s per server) for
    /// fault-plan replay and health-probe traffic. Uniform additions
    /// cannot *unbalance* a weighted cut, but they damp the relative
    /// spread between tiers, so callers with probe-heavy plans may want
    /// the honesty.
    pub fn with_background(mut self, events_per_s: f64) -> Self {
        for w in &mut self.per_server {
            *w += events_per_s;
        }
        self
    }
}

/// The per-shard lookahead decomposition (PR 9): the distinct inbound
/// `LinkSpec::rtt_s` values among a shard's own uplinks, ascending, plus
/// each local link's index into that table.
///
/// PR 8 collapsed this to one number — the min RTT — and applied it
/// unconditionally to every non-boundary head. But the only events the
/// `head + lookahead` grant-bound term must cover are compute arrivals
/// produced by reaps of the shard's own *currently draining* uplinks
/// (uploads start only at merge barriers, so the draining set can only
/// shrink inside a grant window — see `sim/shard.rs` docs). Keeping the
/// RTTs per class lets the shard bound by the smallest RTT among links
/// that are *actually draining* — typically no bound at all on an idle
/// shard, and the hub/cloud RTT instead of the 5 ms edge floor on a mixed
/// chunk whose edge links are dry.
#[derive(Debug, Clone, PartialEq)]
pub struct LookaheadClasses {
    /// Distinct inbound RTTs (seconds), strictly ascending. Never empty
    /// for a non-empty shard.
    pub rtts: Vec<f64>,
    /// For each local link (shard-relative index), the index of its RTT
    /// in `rtts`.
    pub link_class: Vec<usize>,
}

impl LookaheadClasses {
    /// Decompose a shard's link slice into RTT classes.
    pub fn of(links: &[LinkSpec]) -> LookaheadClasses {
        let mut rtts: Vec<f64> = links.iter().map(|l| l.rtt_s).collect();
        rtts.sort_by(|a, b| a.total_cmp(b));
        rtts.dedup();
        let link_class = links
            .iter()
            .map(|l| rtts.partition_point(|r| *r < l.rtt_s))
            .collect();
        LookaheadClasses { rtts, link_class }
    }

    pub fn n_classes(&self) -> usize {
        self.rtts.len()
    }

    /// The PR-8 scalar lookahead: the smallest inbound RTT. Still the
    /// unconditional safe floor (equals `ShardPlan::lookahead_s`).
    pub fn floor_s(&self) -> f64 {
        self.rtts.first().copied().unwrap_or(f64::INFINITY)
    }
}

/// Tier→shard lowering: which contiguous server ranges each engine shard
/// owns, plus the conservative lookahead each shard derives from its
/// inbound links.
///
/// Ranges are always contiguous and cover `0..n_servers` exactly — the
/// engine's bit-identity holds for *any* contiguous partition (the merge
/// barrier serializes every scheduler interaction), so the partition
/// choice is purely a load-balance / lookahead question, never a
/// correctness one. That is pinned by `rust/tests/sharded_identity.rs`
/// across shard counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Half-open server ranges `[lo, hi)`, one per shard, ascending and
    /// adjoining. Never empty; every range is non-empty.
    pub ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// `n_shards` contiguous chunks over `n_servers` servers, balanced to
    /// within one server. Degenerate requests clamp instead of producing
    /// empty shards (an empty shard is a worker that can never advance the
    /// global bound): `n_shards == 0` becomes 1, counts above the server
    /// count become one shard per server.
    pub fn contiguous(n_servers: usize, n_shards: usize) -> ShardPlan {
        assert!(n_servers > 0, "cannot shard an empty cluster");
        let k = n_shards.clamp(1, n_servers);
        let ranges = (0..k)
            .map(|i| (i * n_servers / k, (i + 1) * n_servers / k))
            .collect();
        ShardPlan { ranges }
    }

    /// `n_shards` contiguous chunks balanced on *cumulative weight*
    /// instead of server count: cut points sit where the weight prefix
    /// sum crosses each `j/k` share of the total, refined to the nearer
    /// neighboring server boundary. The same degenerate clamps as
    /// [`Self::contiguous`] apply (`n_shards == 0` → 1; `n_shards >
    /// n_servers` → one per server; every range non-empty by
    /// construction). An all-zero weight vector falls back to the
    /// server-count split — there is nothing to balance.
    ///
    /// Weights must be finite and non-negative; this runs at lowering
    /// time only (allocation here is fine, per the shard-path no-alloc
    /// contract).
    pub fn weighted(n_servers: usize, weights: &[f64], n_shards: usize) -> ShardPlan {
        assert!(n_servers > 0, "cannot shard an empty cluster");
        assert_eq!(weights.len(), n_servers, "one weight per server");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "event weights must be finite and non-negative"
        );
        let k = n_shards.clamp(1, n_servers);
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return Self::contiguous(n_servers, k);
        }
        // Prefix sums: pre[i] = weight of servers [0, i).
        let mut pre = Vec::with_capacity(n_servers + 1);
        let mut acc = 0.0;
        pre.push(0.0);
        for w in weights {
            acc += *w;
            pre.push(acc);
        }
        let mut ranges = Vec::with_capacity(k);
        let mut lo = 0usize;
        for j in 1..k {
            let target = total * j as f64 / k as f64;
            // First boundary whose prefix reaches the share...
            let mut cut = pre.partition_point(|p| *p < target);
            // ...or the one just before it, whichever lands closer.
            if cut > 0 && cut <= n_servers && target - pre[cut - 1] < pre[cut] - target {
                cut -= 1;
            }
            // Clamp so this range and every remaining one stay non-empty.
            let hi = cut.clamp(lo + 1, n_servers - (k - j));
            ranges.push((lo, hi));
            lo = hi;
        }
        ranges.push((lo, n_servers));
        ShardPlan { ranges }
    }

    /// Max/min per-shard weight ratio under this plan — the balance
    /// metric `paper_scale_sim`/`micro_hotpath` report (1.0 = perfectly
    /// balanced; `sharded_100x_imbalance` in BENCH). A zero-weight shard
    /// under positive total weight reads as infinite imbalance; an
    /// all-zero fleet reads as 1.0 (nothing to balance).
    pub fn imbalance(&self, weights: &[f64]) -> f64 {
        let mut min_w = f64::INFINITY;
        let mut max_w = 0.0f64;
        for &(lo, hi) in &self.ranges {
            let w: f64 = weights[lo..hi].iter().sum();
            if w < min_w {
                min_w = w;
            }
            if w > max_w {
                max_w = w;
            }
        }
        if max_w <= 0.0 {
            1.0
        } else if min_w <= 0.0 {
            f64::INFINITY
        } else {
            max_w / min_w
        }
    }

    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    /// Shard owning server `i`.
    pub fn shard_of(&self, server: usize) -> usize {
        self.ranges
            .iter()
            .position(|&(lo, hi)| lo <= server && server < hi)
            // lint: allow(p1) ranges cover 0..n_servers by construction
            .expect("server inside the plan")
    }

    /// Conservative lookahead for shard `s` (seconds): the minimum
    /// inbound cross-shard latency, i.e. the smallest `LinkSpec::rtt_s`
    /// among the shard's own uplinks. A merge-barrier dispatch at time τ
    /// cannot land a compute-side event on this shard before `τ +
    /// lookahead`, which is the window the shard may burn through local
    /// physics without another head exchange (see sim/shard.rs docs).
    pub fn lookahead_s(&self, links: &[LinkSpec], s: usize) -> f64 {
        let (lo, hi) = self.ranges[s];
        links[lo..hi]
            .iter()
            .map(|l| l.rtt_s)
            // lint: allow(nan-cmp) rtt_s is a positive config constant, never NaN
            .fold(f64::INFINITY, f64::min)
    }

    /// The per-class lookahead decomposition for shard `s`: distinct
    /// inbound RTTs plus each local link's class index. The shard bounds
    /// its head by the smallest RTT among classes with a *draining*
    /// uplink instead of the unconditional floor — see
    /// [`LookaheadClasses`] and the grant-rule derivation in
    /// `sim/shard.rs`.
    pub fn lookahead_classes(&self, links: &[LinkSpec], s: usize) -> LookaheadClasses {
        let (lo, hi) = self.ranges[s];
        LookaheadClasses::of(&links[lo..hi])
    }
}

impl TopologyConfig {
    /// Lower this topology to a [`ShardPlan`]:
    ///
    /// - `Fixed(n)` — `n` balanced contiguous chunks by *server count*
    ///   (the PR-8 lowering, kept for A/B runs);
    /// - `Auto` — one shard per tier, **rebalanced** on cumulative event
    ///   weight (same shard count) when the tier partition's
    ///   [`ShardPlan::imbalance`] exceeds [`AUTO_REBALANCE_IMBALANCE`];
    /// - `Weighted(n)` — always the volume-weighted cut ([`n` shards, or
    ///   the tier count for `Weighted(0)`).
    pub fn shard_plan(&self, count: ShardCount) -> ShardPlan {
        match count {
            ShardCount::Fixed(n) => ShardPlan::contiguous(self.n_servers(), n),
            ShardCount::Weighted(n) => {
                let model = EventVolumeModel::from_topology(self);
                let k = if n == 0 {
                    self.tier_shard_plan().n_shards()
                } else {
                    n
                };
                self.weighted_plan(k, &model)
            }
            ShardCount::Auto => {
                let tiers = self.tier_shard_plan();
                let model = EventVolumeModel::from_topology(self);
                if tiers.imbalance(&model.per_server) > AUTO_REBALANCE_IMBALANCE {
                    self.weighted_plan(tiers.n_shards(), &model)
                } else {
                    tiers
                }
            }
        }
    }

    /// One shard per non-empty tier — the raw PR-8 `auto` partition,
    /// kept public so A/B runs can measure its imbalance against the
    /// volume-weighted rebalance ([`ShardPlan::imbalance`]).
    pub fn tier_shard_plan(&self) -> ShardPlan {
        let mut ranges = Vec::with_capacity(self.tiers.len());
        let mut lo = 0;
        for tier in &self.tiers {
            if tier.count > 0 {
                ranges.push((lo, lo + tier.count));
                lo += tier.count;
            }
        }
        assert!(!ranges.is_empty(), "topology has at least one tier");
        ShardPlan { ranges }
    }

    /// Tier-atomic volume-weighted plan: cut `n_shards` contiguous
    /// ranges on the model's cumulative weight, treating each tier as an
    /// unsplittable atom *unless* that tier alone exceeds a `1/k` share
    /// of total weight (then its servers become individual atoms — the
    /// only way any cut can balance). This preserves a tier's intra-range
    /// locality (and thus its homogeneous lookahead classes) whenever
    /// balance allows.
    pub fn weighted_plan(&self, n_shards: usize, model: &EventVolumeModel) -> ShardPlan {
        let n = self.n_servers();
        assert!(n > 0, "cannot shard an empty cluster");
        assert_eq!(model.per_server.len(), n, "one weight per server");
        let k = n_shards.clamp(1, n);
        let total: f64 = model.per_server.iter().sum();
        if total <= 0.0 {
            return ShardPlan::contiguous(n, k);
        }
        let share = total / k as f64;
        // Atom list: (end server index, atom weight) — whole tiers when
        // they fit a balanced share, per-server atoms when one doesn't.
        let mut atom_end: Vec<usize> = Vec::new();
        let mut atom_w: Vec<f64> = Vec::new();
        let mut lo = 0usize;
        for tier in &self.tiers {
            if tier.count == 0 {
                continue;
            }
            let hi = lo + tier.count;
            let tier_w: f64 = model.per_server[lo..hi].iter().sum();
            if tier_w > share {
                for s in lo..hi {
                    atom_end.push(s + 1);
                    atom_w.push(model.per_server[s]);
                }
            } else {
                atom_end.push(hi);
                atom_w.push(tier_w);
            }
            lo = hi;
        }
        let atoms = atom_w.len();
        let inner = ShardPlan::weighted(atoms, &atom_w, k.min(atoms));
        let ranges = inner
            .ranges
            .iter()
            .map(|&(alo, ahi)| {
                let s_lo = if alo == 0 { 0 } else { atom_end[alo - 1] };
                (s_lo, atom_end[ahi - 1])
            })
            .collect();
        ShardPlan { ranges }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::csucb::CsUcb;
    use crate::sim::engine::simulate;
    use crate::workload::generator::{generate, ArrivalProcess, WorkloadConfig};

    /// The topology path must reproduce the historical constructor bit for
    /// bit — that is what keeps every existing paper-scale result
    /// comparable.
    #[test]
    fn paper_preset_builds_exact_paper_config() {
        for model in crate::sim::server::EDGE_MODELS {
            for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
                let from_topo = TopologyConfig::paper(model, mode).build();
                let direct = ClusterConfig::paper(model, mode);
                assert_eq!(from_topo, direct, "{model} {mode:?}");
            }
        }
    }

    /// And therefore paper-topology runs are decision-identical whichever
    /// constructor produced the config.
    #[test]
    fn paper_preset_runs_are_outcome_identical() {
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(300)
                .with_arrivals(ArrivalProcess::Poisson { rate: 12.0 })
                .with_seed(9),
        );
        let direct = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Fluctuating).build();
        let mut s1 = CsUcb::with_defaults(direct.n_servers());
        let mut s2 = CsUcb::with_defaults(topo.n_servers());
        let r1 = simulate(&direct, &trace, &mut s1);
        let r2 = simulate(&topo, &trace, &mut s2);
        assert_eq!(r1.outcomes.len(), r2.outcomes.len());
        for (a, b) in r1.outcomes.iter().zip(&r2.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.server, b.server);
            assert_eq!(a.completed_at.to_bits(), b.completed_at.to_bits());
        }
        assert_eq!(r1.events_processed, r2.events_processed);
    }

    #[test]
    fn preset_shapes_and_scales() {
        let p = TopologyConfig::paper("yi-6b", BandwidthMode::Stable);
        assert_eq!(p.n_servers(), 6);
        assert!((p.capacity_scale() - 1.0).abs() < 1e-12);

        let t10 = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        assert_eq!(t10.n_servers(), 60);
        assert!(
            t10.capacity_scale() > 9.0 && t10.capacity_scale() < 12.0,
            "scale {}",
            t10.capacity_scale()
        );
        assert!((t10.scaled_rate(15.0) - 15.0 * t10.capacity_scale()).abs() < 1e-9);

        let t100 = TopologyConfig::edgeshard_100x("yi-6b", BandwidthMode::Stable);
        assert_eq!(t100.n_servers(), 600);
        assert!(
            t100.capacity_scale() > 90.0 && t100.capacity_scale() < 120.0,
            "scale {}",
            t100.capacity_scale()
        );

        for name in TOPOLOGY_PRESETS {
            assert!(TopologyConfig::by_name(name, "yi-6b", BandwidthMode::Stable).is_some());
        }
        assert!(TopologyConfig::by_name("nope", "yi-6b", BandwidthMode::Stable).is_none());
    }

    #[test]
    fn build_wires_heterogeneous_tiers() {
        let cfg = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable).build();
        assert_eq!(cfg.n_servers(), 60);
        assert_eq!(cfg.links.len(), 60);
        // Tier boundaries by name.
        assert_eq!(cfg.servers[0].name, "edge-0");
        assert_eq!(cfg.servers[47].name, "edge-47");
        assert_eq!(cfg.servers[48].name, "hub-0");
        assert_eq!(cfg.servers[58].name, "cloud-0");
        assert_eq!(cfg.servers[59].name, "cloud-1");
        // Hubs sit between the extremes on throughput; clouds are Cloud.
        assert!(cfg.servers[48].prefill_rate > cfg.servers[0].prefill_rate);
        assert!(cfg.servers[48].prefill_rate < cfg.servers[58].prefill_rate);
        assert_eq!(cfg.servers[48].kind, ServerKind::Edge);
        assert_eq!(cfg.servers[58].kind, ServerKind::Cloud);
        assert_eq!(cfg.cloud_index(), 58);
        // Heterogeneous links per tier.
        assert_eq!(cfg.links[48].name, "hub-link-0");
        assert!(cfg.links[48].bandwidth_bps > cfg.links[0].bandwidth_bps);
        assert!(cfg.links[0].fluctuation == 0.0);
        // Fluctuating mode switches every tier's amplitude on.
        let f = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating).build();
        assert!(f.links.iter().all(|l| l.fluctuation > 0.0));
    }

    /// Per-tier service-model selection lowers into the per-server specs:
    /// "token-batch" switches every tier (KV budget scaled by tier
    /// slots), "token-batch-edge" leaves cloud tiers on the PS fluid.
    #[test]
    fn service_model_selection_lowers_per_tier() {
        use crate::sim::service_model::ServiceModelKind;
        let base = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
        assert!(base
            .build()
            .servers
            .iter()
            .all(|s| s.service_model == ServiceModelKind::Ps));

        let all = base
            .clone()
            .with_service_model_by_name("token-batch")
            .unwrap()
            .build();
        for s in &all.servers {
            match s.service_model {
                ServiceModelKind::TokenBatch { kv_tokens } => {
                    assert_eq!(kv_tokens as usize, s.slots * 1536, "{}", s.name);
                }
                other => panic!("{}: expected token-batch, got {other:?}", s.name),
            }
        }

        let edge_only = base
            .clone()
            .with_service_model_by_name("token-batch-edge")
            .unwrap()
            .build();
        for s in &edge_only.servers {
            match s.kind {
                ServerKind::Edge => {
                    assert!(matches!(s.service_model, ServiceModelKind::TokenBatch { .. }))
                }
                ServerKind::Cloud => assert_eq!(s.service_model, ServiceModelKind::Ps),
            }
        }

        assert!(base.clone().with_service_model_by_name("ps").is_some());
        assert!(base.clone().with_service_model_by_name("nope").is_none());

        // The literal-kind builders (one explicit kind, e.g. a custom KV
        // budget shared by every selected tier) are the programmatic
        // siblings of the by-name arms; pin their selection behavior so
        // the two entry points cannot drift apart silently.
        let fixed = ServiceModelKind::TokenBatch { kv_tokens: 4096 };
        let all_fixed = base.clone().with_service_model(fixed).build();
        assert!(all_fixed.servers.iter().all(|s| s.service_model == fixed));
        let edge_fixed = base
            .clone()
            .with_service_model_for_kind(ServerKind::Edge, fixed)
            .build();
        for s in &edge_fixed.servers {
            match s.kind {
                ServerKind::Edge => assert_eq!(s.service_model, fixed),
                ServerKind::Cloud => assert_eq!(s.service_model, ServiceModelKind::Ps),
            }
        }
        // And the edge-only selections agree tier-for-tier on *which*
        // servers switched, whichever entry point chose them.
        let by_name_edges = base
            .with_service_model_by_name("token-batch-edge")
            .unwrap()
            .build();
        for (a, b) in edge_fixed.servers.iter().zip(&by_name_edges.servers) {
            assert_eq!(
                matches!(a.service_model, ServiceModelKind::TokenBatch { .. }),
                matches!(b.service_model, ServiceModelKind::TokenBatch { .. }),
                "{}",
                a.name
            );
        }
    }

    /// A mixed-model fleet (token-batch edge under PS cloud) runs end to
    /// end through the unchanged engine and schedulers.
    #[test]
    fn mixed_model_paper_topology_runs_end_to_end() {
        let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Stable)
            .with_service_model_by_name("token-batch-edge")
            .unwrap();
        let cfg = topo.build();
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Poisson { rate: 12.0 })
                .with_deadline_range(2.0, 6.0)
                .with_seed(17),
        );
        let mut s = CsUcb::with_defaults(cfg.n_servers());
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 400);
        assert_eq!(rep.unfinished, 0);
        assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
    }

    #[test]
    fn shard_count_parses_cli_forms() {
        assert_eq!(ShardCount::parse("auto"), Some(ShardCount::Auto));
        assert_eq!(ShardCount::parse("AUTO"), Some(ShardCount::Auto));
        assert_eq!(ShardCount::parse("1"), Some(ShardCount::Fixed(1)));
        assert_eq!(ShardCount::parse("16"), Some(ShardCount::Fixed(16)));
        assert_eq!(ShardCount::parse("weighted"), Some(ShardCount::Weighted(0)));
        assert_eq!(ShardCount::parse("WEIGHTED"), Some(ShardCount::Weighted(0)));
        assert_eq!(
            ShardCount::parse("weighted:4"),
            Some(ShardCount::Weighted(4))
        );
        assert_eq!(ShardCount::parse("weighted:0"), None);
        assert_eq!(ShardCount::parse("weighted:x"), None);
        assert_eq!(ShardCount::parse("0"), None);
        assert_eq!(ShardCount::parse("-2"), None);
        assert_eq!(ShardCount::parse("many"), None);
    }

    #[test]
    fn tier_plan_follows_tier_boundaries() {
        let t10 = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let plan = t10.tier_shard_plan();
        assert_eq!(plan.ranges, vec![(0, 48), (48, 58), (58, 60)]);
        assert_eq!(plan.n_shards(), 3);
        assert_eq!(plan.shard_of(0), 0);
        assert_eq!(plan.shard_of(47), 0);
        assert_eq!(plan.shard_of(48), 1);
        assert_eq!(plan.shard_of(59), 2);
    }

    /// `Auto` rebalances the tier partition when its event-volume
    /// imbalance exceeds the threshold. On edgeshard-10x in Stable mode
    /// weights are slot-proportional (edge 8/server, hub 12, cloud 12 →
    /// tier totals 384/120/24, imbalance 16), so the three tier shards
    /// are re-cut at cumulative-weight thirds: 22 edge servers (176),
    /// another 22 (176), and the tail 4 edge + all hubs + clouds
    /// (32 + 120 + 24 = 176).
    #[test]
    fn auto_plan_rebalances_on_volume_imbalance() {
        let t10 = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let model = EventVolumeModel::from_topology(&t10);
        let tiers = t10.tier_shard_plan();
        assert!(
            tiers.imbalance(&model.per_server) > 10.0,
            "tier imbalance {}",
            tiers.imbalance(&model.per_server)
        );
        let auto = t10.shard_plan(ShardCount::Auto);
        assert_eq!(auto.ranges, vec![(0, 22), (22, 44), (44, 60)]);
        let imb = auto.imbalance(&model.per_server);
        assert!(imb < 1.01, "rebalanced imbalance {imb}");
        // Weighted(0) (CLI "weighted") lands on the same plan here.
        assert_eq!(t10.shard_plan(ShardCount::Weighted(0)), auto);
    }

    /// The ISSUE acceptance pin: on edgeshard-100x the weighted 3-shard
    /// plan's max/min per-shard event volume is ≤ 1.25 while the raw
    /// tier plan sits ≥ 3 (it is 16: 3840/1200/240 slot-weights). The
    /// edge tier alone (3840 of 5280) exceeds a third of total weight,
    /// so it is split internally at servers 220 and 440.
    #[test]
    fn weighted_plan_balances_edgeshard_100x() {
        let t100 = TopologyConfig::edgeshard_100x("yi-6b", BandwidthMode::Stable);
        let model = EventVolumeModel::from_topology(&t100);
        let tiers = t100.tier_shard_plan();
        assert!(tiers.imbalance(&model.per_server) >= 3.0);
        let w = t100.shard_plan(ShardCount::Weighted(3));
        assert_eq!(w.ranges, vec![(0, 220), (220, 440), (440, 600)]);
        assert!(w.imbalance(&model.per_server) <= 1.25);
        // More shards than tiers still covers contiguously.
        let w8 = t100.shard_plan(ShardCount::Weighted(8));
        assert_eq!(w8.n_shards(), 8);
        assert_eq!(w8.ranges[0].0, 0);
        assert_eq!(w8.ranges.last().unwrap().1, 600);
        let mut covered = 0;
        for &(lo, hi) in &w8.ranges {
            assert_eq!(lo, covered);
            assert!(hi > lo);
            covered = hi;
        }
    }

    /// Weight ratios, not absolute rates, drive the cut: token-batch
    /// tiers weigh more per arrival, pulling the boundary toward them.
    #[test]
    fn volume_model_reflects_service_model_and_mode() {
        let stable = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let m = EventVolumeModel::from_topology(&stable);
        assert_eq!(m.per_server.len(), 60);
        // Slot-proportional in Stable mode: hub (12 slots) = 1.5x edge (8).
        assert!((m.per_server[48] / m.per_server[0] - 1.5).abs() < 1e-9);
        // Fluctuating mode adds 1/fluct_period = 2 ticks/s per server.
        let fluct = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Fluctuating);
        let mf = EventVolumeModel::from_topology(&fluct);
        assert!((mf.per_server[0] - m.per_server[0] - 2.0).abs() < 1e-9);
        // Token-batch edge triples the edge tier's arrival-event weight.
        let tb = stable
            .clone()
            .with_service_model_by_name("token-batch-edge")
            .unwrap();
        let mtb = EventVolumeModel::from_topology(&tb);
        assert!((mtb.per_server[0] / m.per_server[0] - 3.0).abs() < 1e-9);
        assert!((mtb.per_server[58] - m.per_server[58]).abs() < 1e-12);
        // Uniform background shifts every server equally.
        let bg = mtb.clone().with_background(5.0);
        for (a, b) in bg.per_server.iter().zip(&mtb.per_server) {
            assert!((a - b - 5.0).abs() < 1e-12);
        }
    }

    #[test]
    fn fixed_plans_are_balanced_contiguous_covers() {
        for (n_servers, n_shards) in [(6, 1), (6, 4), (60, 4), (60, 7), (600, 16), (3, 9)] {
            let plan = ShardPlan::contiguous(n_servers, n_shards);
            assert!(plan.n_shards() <= n_shards);
            assert_eq!(plan.ranges[0].0, 0);
            assert_eq!(plan.ranges.last().unwrap().1, n_servers);
            let mut covered = 0;
            for (i, &(lo, hi)) in plan.ranges.iter().enumerate() {
                assert_eq!(lo, covered, "gap before shard {i}");
                assert!(hi > lo, "empty shard {i}");
                covered = hi;
            }
            // Balanced to within one server.
            let sizes: Vec<usize> = plan.ranges.iter().map(|&(lo, hi)| hi - lo).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
        }
    }

    /// Degenerate lowerings clamp to valid non-empty covers instead of
    /// minting empty shards (an empty shard is a worker that can never
    /// advance the global bound).
    #[test]
    fn shard_plans_clamp_degenerate_lowerings() {
        // n_shards == 0 → one shard.
        assert_eq!(ShardPlan::contiguous(3, 0).ranges, vec![(0, 3)]);
        assert_eq!(
            ShardPlan::weighted(3, &[1.0, 2.0, 3.0], 0).ranges,
            vec![(0, 3)]
        );
        // n_shards > n_servers → one server per shard.
        assert_eq!(
            ShardPlan::contiguous(2, 9).ranges,
            vec![(0, 1), (1, 2)]
        );
        assert_eq!(
            ShardPlan::weighted(2, &[5.0, 1.0], 9).ranges,
            vec![(0, 1), (1, 2)]
        );
        // 1-server topology at any requested count.
        for k in [0, 1, 4] {
            assert_eq!(ShardPlan::contiguous(1, k).ranges, vec![(0, 1)]);
            assert_eq!(ShardPlan::weighted(1, &[7.0], k).ranges, vec![(0, 1)]);
        }
        // All weight piled at one end still yields non-empty ranges.
        let tail = ShardPlan::weighted(4, &[0.0, 0.0, 0.0, 100.0], 2);
        assert_eq!(tail.ranges, vec![(0, 3), (3, 4)]);
        let head = ShardPlan::weighted(3, &[100.0, 0.0, 0.0], 3);
        assert_eq!(head.ranges, vec![(0, 1), (1, 2), (2, 3)]);
        // Zero total weight falls back to the server-count split.
        assert_eq!(
            ShardPlan::weighted(4, &[0.0; 4], 2).ranges,
            ShardPlan::contiguous(4, 2).ranges
        );
        // A 1-tier topology through the weighted lowering clamps too.
        let single = TopologyConfig::paper("yi-6b", BandwidthMode::Stable);
        let plan = single.shard_plan(ShardCount::Weighted(64));
        assert_eq!(plan.n_shards(), 6);
        assert_eq!(plan.ranges.last().unwrap().1, 6);
    }

    /// Lookahead lowers from LinkSpec RTTs: per-tier shards read their
    /// tier's RTT (edge 5 ms, hub 20 ms, cloud 80 ms); a mixed chunk
    /// takes the min across the tiers it straddles.
    #[test]
    fn lookahead_derives_from_inbound_link_rtt() {
        let topo = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let cfg = topo.build();
        let auto = topo.tier_shard_plan();
        assert!((auto.lookahead_s(&cfg.links, 0) - 0.005).abs() < 1e-12);
        assert!((auto.lookahead_s(&cfg.links, 1) - 0.02).abs() < 1e-12);
        assert!((auto.lookahead_s(&cfg.links, 2) - 0.08).abs() < 1e-12);
        let two = topo.shard_plan(ShardCount::Fixed(2));
        // Second chunk [30, 60) straddles edge+hub+cloud → min is edge.
        assert!((two.lookahead_s(&cfg.links, 1) - 0.005).abs() < 1e-12);
    }

    /// Hand-computed class decompositions: a per-tier shard has one RTT
    /// class; a mixed chunk keeps them all, each local link mapped to
    /// its class, with the floor equal to the PR-8 scalar lookahead.
    #[test]
    fn lookahead_classes_pin_hand_computed_topologies() {
        let topo = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let cfg = topo.build();
        let tiers = topo.tier_shard_plan();
        // Homogeneous per-tier shards: exactly one class each.
        for (s, rtt) in [(0usize, 0.005), (1, 0.02), (2, 0.08)] {
            let la = tiers.lookahead_classes(&cfg.links, s);
            assert_eq!(la.rtts, vec![rtt], "shard {s}");
            assert_eq!(la.n_classes(), 1);
            assert!(la.link_class.iter().all(|&c| c == 0));
            assert!((la.floor_s() - rtt).abs() < 1e-12);
            assert!((la.floor_s() - tiers.lookahead_s(&cfg.links, s)).abs() < 1e-12);
        }
        // Fixed(2)'s second chunk [30, 60) straddles all three tiers:
        // three ascending classes, links mapped 18× edge, 10× hub,
        // 2× cloud, floor = edge.
        let two = topo.shard_plan(ShardCount::Fixed(2));
        let la = two.lookahead_classes(&cfg.links, 1);
        assert_eq!(la.rtts, vec![0.005, 0.02, 0.08]);
        assert_eq!(la.link_class.len(), 30);
        assert_eq!(la.link_class.iter().filter(|&&c| c == 0).count(), 18);
        assert_eq!(la.link_class.iter().filter(|&&c| c == 1).count(), 10);
        assert_eq!(la.link_class.iter().filter(|&&c| c == 2).count(), 2);
        assert!((la.floor_s() - 0.005).abs() < 1e-12);
        // The rebalanced Auto plan's tail shard [44, 60) mixes all
        // three tiers too (4 edge + 10 hub + 2 cloud).
        let auto = topo.shard_plan(ShardCount::Auto);
        let tail = auto.lookahead_classes(&cfg.links, 2);
        assert_eq!(tail.rtts, vec![0.005, 0.02, 0.08]);
        assert_eq!(tail.link_class.iter().filter(|&&c| c == 0).count(), 4);
    }

    /// A short streaming run on the 10x preset end to end: every layer
    /// (engine, scheduler arms sized to 60 servers, candidate pruning)
    /// accepts the large topology.
    #[test]
    fn edgeshard_10x_runs_end_to_end() {
        let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
        let cfg = topo.build();
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(500)
                .with_arrivals(ArrivalProcess::Poisson {
                    rate: topo.scaled_rate(15.0),
                })
                .with_deadline_range(2.0, 6.0)
                .with_seed(5),
        );
        let mut s = CsUcb::with_defaults(cfg.n_servers());
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 500);
        assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
        assert!(rep.peak_event_queue_len < 500);
    }
}
