//! Parameterized multi-tier cluster topologies: the generalization of the
//! paper's fixed 5-edge + 1-cloud testbed to EdgeShard-style fleets
//! (arXiv:2405.14371 evaluates multi-tier, many-instance deployments; so
//! does the cloud-edge routing study arXiv:2507.15553).
//!
//! A [`TopologyConfig`] is a list of [`TierSpec`]s — each a server
//! template, a link template, and an instance count — that [`build`]s
//! into the flat [`ClusterConfig`] every other layer already consumes
//! (DES engine, schedulers, workload scaling, the live router via
//! `Router::from_topology`). The paper testbed itself is the smallest
//! preset, and `TopologyConfig::paper(..).build()` reproduces
//! `ClusterConfig::paper(..)` field for field, so paper-scale runs are
//! decision-identical whichever constructor they start from.
//!
//! Presets: [`TopologyConfig::paper`] (6 servers),
//! [`TopologyConfig::edgeshard_10x`] (60 servers: 48 edge + 10 regional
//! hubs + 2 cloud), [`TopologyConfig::edgeshard_100x`] (600 servers).
//! "Hub" servers are mid-tier aggregation boxes — edge-kind (they sit on
//! the LAN side of the WAN boundary, and edge-only baselines like AGOD
//! may use them) with throughput, batching, and link specs between the
//! paper's two extremes.
//!
//! [`build`]: TopologyConfig::build

use super::cluster::{BandwidthMode, ClusterConfig};
use super::energy::EnergyWeights;
use super::net::LinkSpec;
use super::server::{paper_testbed, ServerKind, ServerSpec};
use super::service_model::ServiceModelKind;

/// One homogeneous tier: `count` instances stamped from the server and
/// link templates. Instance names are `{name}-{i}` (and `{name}-link-{i}`
/// for links); a single-instance tier keeps the bare template names, so
/// the paper preset reproduces the historical "cloud" / "cloud-uplink"
/// names exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct TierSpec {
    pub name: String,
    pub count: usize,
    pub server: ServerSpec,
    pub link: LinkSpec,
}

/// A multi-tier topology description that lowers to [`ClusterConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyConfig {
    pub name: String,
    pub tiers: Vec<TierSpec>,
    pub bandwidth: BandwidthMode,
    pub weights: EnergyWeights,
    pub seed: u64,
}

/// Total batch slots of the paper testbed (5×8 edge + 12 cloud) — the
/// denominator of [`TopologyConfig::capacity_scale`].
const PAPER_SLOTS: usize = 52;

impl TopologyConfig {
    /// An empty topology; add tiers with [`Self::with_tier`].
    pub fn new(name: &str, bandwidth: BandwidthMode) -> Self {
        TopologyConfig {
            name: name.to_string(),
            tiers: Vec::new(),
            bandwidth,
            weights: EnergyWeights::default(),
            seed: 0xC1A0,
        }
    }

    pub fn with_tier(mut self, tier: TierSpec) -> Self {
        self.tiers.push(tier);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run every tier's servers on `kind` (one literal kind for all
    /// tiers; use [`Self::with_service_model_by_name`] to derive per-tier
    /// KV budgets from each tier's slot count).
    pub fn with_service_model(mut self, kind: ServiceModelKind) -> Self {
        for tier in &mut self.tiers {
            tier.server.service_model = kind;
        }
        self
    }

    /// Run only tiers of the given server kind on `model` — e.g.
    /// token-batch edge tiers under PS cloud tiers, the mixed deployment
    /// the batching/quantization edge studies evaluate.
    pub fn with_service_model_for_kind(
        mut self,
        server_kind: ServerKind,
        model: ServiceModelKind,
    ) -> Self {
        for tier in &mut self.tiers {
            if tier.server.kind == server_kind {
                tier.server.service_model = model;
            }
        }
        self
    }

    /// Apply a whole-fleet service model by CLI name: "ps" (default),
    /// "token-batch" (every tier, per-tier KV budgets), or
    /// "token-batch-edge" (edge-kind tiers only; cloud stays PS).
    pub fn with_service_model_by_name(self, name: &str) -> Option<Self> {
        match name {
            "ps" => Some(self),
            "token-batch" => {
                let mut topo = self;
                for tier in &mut topo.tiers {
                    tier.server.service_model =
                        ServiceModelKind::token_batch_for(tier.server.slots);
                }
                Some(topo)
            }
            "token-batch-edge" => {
                let mut topo = self;
                for tier in &mut topo.tiers {
                    if tier.server.kind == ServerKind::Edge {
                        tier.server.service_model =
                            ServiceModelKind::token_batch_for(tier.server.slots);
                    }
                }
                Some(topo)
            }
            _ => None,
        }
    }

    /// The paper's testbed as a topology: one 5-instance edge tier + one
    /// cloud server. `build()` equals `ClusterConfig::paper(..)` exactly.
    pub fn paper(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        let servers = paper_testbed(edge_model);
        Self::new("paper", bandwidth)
            .with_tier(TierSpec {
                name: "edge".into(),
                count: 5,
                server: servers[0].clone(),
                link: LinkSpec::edge(0, false),
            })
            .with_tier(TierSpec {
                name: "cloud".into(),
                count: 1,
                server: servers[5].clone(),
                link: LinkSpec::cloud(false),
            })
    }

    /// EdgeShard-style three-tier fleet at ~10x paper scale: 48 edge
    /// devices, 10 regional hubs, 2 cloud instances (60 servers,
    /// capacity_scale ≈ 10.2).
    pub fn edgeshard_10x(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        Self::edgeshard(edge_model, bandwidth, "edgeshard-10x", 48, 10, 2)
    }

    /// EdgeShard-style three-tier fleet at ~100x paper scale: 480 edge
    /// devices, 100 regional hubs, 20 cloud instances (600 servers,
    /// capacity_scale ≈ 101.5).
    pub fn edgeshard_100x(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        Self::edgeshard(edge_model, bandwidth, "edgeshard-100x", 480, 100, 20)
    }

    fn edgeshard(
        edge_model: &str,
        bandwidth: BandwidthMode,
        name: &str,
        edges: usize,
        hubs: usize,
        clouds: usize,
    ) -> Self {
        let paper = paper_testbed(edge_model);
        let edge = paper[0].clone();
        let cloud = paper[5].clone();
        // Regional hub: LAN-side aggregation box between the paper's two
        // extremes — faster and better-batched than an edge device, far
        // cheaper per watt than the cloud GPU.
        let hub = ServerSpec {
            name: "hub".into(),
            kind: ServerKind::Edge,
            prefill_rate: edge.prefill_rate * 2.2,
            decode_rate: edge.decode_rate * 1.25,
            slots: 12,
            batch_alpha: 0.68,
            p_infer: 120.0,
            p_idle: 14.0,
            compute_capacity: 12.0,
            queue_limit: 3,
            service_model: ServiceModelKind::Ps,
        };
        let hub_link = LinkSpec {
            name: "hub-link".into(),
            bandwidth_bps: 400.0e6,
            per_flow_cap_bps: 25.0e6,
            rtt_s: 0.02,
            fluctuation: 0.0,
            fluct_period: 0.5,
            energy_j_per_mbit: 1.5,
        };
        Self::new(name, bandwidth)
            .with_tier(TierSpec {
                name: "edge".into(),
                count: edges,
                server: edge,
                link: LinkSpec::edge(0, false),
            })
            .with_tier(TierSpec {
                name: "hub".into(),
                count: hubs,
                server: hub,
                link: hub_link,
            })
            .with_tier(TierSpec {
                name: "cloud".into(),
                count: clouds,
                server: cloud,
                link: LinkSpec::cloud(false),
            })
    }

    /// Preset lookup for CLI flags: "paper" | "edgeshard-10x" |
    /// "edgeshard-100x".
    pub fn by_name(name: &str, edge_model: &str, bandwidth: BandwidthMode) -> Option<Self> {
        match name {
            "paper" => Some(Self::paper(edge_model, bandwidth)),
            "edgeshard-10x" | "10x" => Some(Self::edgeshard_10x(edge_model, bandwidth)),
            "edgeshard-100x" | "100x" => Some(Self::edgeshard_100x(edge_model, bandwidth)),
            _ => None,
        }
    }

    pub fn n_servers(&self) -> usize {
        self.tiers.iter().map(|t| t.count).sum()
    }

    pub fn total_slots(&self) -> usize {
        self.tiers.iter().map(|t| t.count * t.server.slots).sum()
    }

    /// Serving capacity relative to the paper testbed, by batch slots —
    /// the factor per-tier arrival rates should scale by to keep offered
    /// load comparable across topologies.
    pub fn capacity_scale(&self) -> f64 {
        self.total_slots() as f64 / PAPER_SLOTS as f64
    }

    /// A paper-calibrated arrival rate (req/s) scaled to this topology's
    /// capacity.
    pub fn scaled_rate(&self, paper_rate: f64) -> f64 {
        paper_rate * self.capacity_scale()
    }

    /// Lower to the flat per-server [`ClusterConfig`] every simulation
    /// layer consumes. The bandwidth mode is applied to each link template
    /// here (Fluctuating grants a template's own amplitude when it has
    /// one, else the paper's ±20 %), mirroring what
    /// `ClusterConfig::paper` does with `LinkSpec::edge`/`cloud`.
    pub fn build(&self) -> ClusterConfig {
        assert!(!self.tiers.is_empty(), "topology has at least one tier");
        let mut servers = Vec::with_capacity(self.n_servers());
        let mut links = Vec::with_capacity(self.n_servers());
        for tier in &self.tiers {
            for i in 0..tier.count {
                let mut server = tier.server.clone();
                let mut link = tier.link.clone();
                if tier.count == 1 {
                    server.name = tier.name.clone();
                } else {
                    server.name = format!("{}-{i}", tier.name);
                    link.name = format!("{}-link-{i}", tier.name);
                }
                link.fluctuation = match self.bandwidth {
                    BandwidthMode::Stable => 0.0,
                    BandwidthMode::Fluctuating => {
                        if tier.link.fluctuation > 0.0 {
                            tier.link.fluctuation
                        } else {
                            0.2
                        }
                    }
                };
                servers.push(server);
                links.push(link);
            }
        }
        ClusterConfig {
            servers,
            links,
            bandwidth: self.bandwidth,
            weights: self.weights,
            outages: Vec::new(),
            seed: self.seed,
            churn_guard: true,
        }
    }
}

pub const TOPOLOGY_PRESETS: [&str; 3] = ["paper", "edgeshard-10x", "edgeshard-100x"];

/// Shard-count selection for the sharded DES engine (`--shards N|auto`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardCount {
    /// One shard per tier — the natural EdgeShard decomposition: tier
    /// boundaries are exactly where cross-shard traffic pays a
    /// `LinkSpec` latency, so per-tier shards maximize the conservative
    /// lookahead window.
    Auto,
    /// Exactly `N` shards (contiguous, server-count-balanced chunks).
    Fixed(usize),
}

impl ShardCount {
    /// Parse a `--shards` flag value: "auto" or a positive integer.
    pub fn parse(s: &str) -> Option<ShardCount> {
        if s.eq_ignore_ascii_case("auto") {
            return Some(ShardCount::Auto);
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Some(ShardCount::Fixed(n)),
            _ => None,
        }
    }
}

/// Tier→shard lowering: which contiguous server ranges each engine shard
/// owns, plus the conservative lookahead each shard derives from its
/// inbound links.
///
/// Ranges are always contiguous and cover `0..n_servers` exactly — the
/// engine's bit-identity holds for *any* contiguous partition (the merge
/// barrier serializes every scheduler interaction), so the partition
/// choice is purely a load-balance / lookahead question, never a
/// correctness one. That is pinned by `rust/tests/sharded_identity.rs`
/// across shard counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Half-open server ranges `[lo, hi)`, one per shard, ascending and
    /// adjoining. Never empty; every range is non-empty.
    pub ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// `n_shards` contiguous chunks over `n_servers` servers, balanced to
    /// within one server. Shard counts above the server count are clamped
    /// (an empty shard has no events and only adds barrier latency).
    pub fn contiguous(n_servers: usize, n_shards: usize) -> ShardPlan {
        assert!(n_servers > 0, "cannot shard an empty cluster");
        let k = n_shards.clamp(1, n_servers);
        let ranges = (0..k)
            .map(|i| (i * n_servers / k, (i + 1) * n_servers / k))
            .collect();
        ShardPlan { ranges }
    }

    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    /// Shard owning server `i`.
    pub fn shard_of(&self, server: usize) -> usize {
        self.ranges
            .iter()
            .position(|&(lo, hi)| lo <= server && server < hi)
            // lint: allow(p1) ranges cover 0..n_servers by construction
            .expect("server inside the plan")
    }

    /// Conservative lookahead for shard `s` (seconds): the minimum
    /// inbound cross-shard latency, i.e. the smallest `LinkSpec::rtt_s`
    /// among the shard's own uplinks. A merge-barrier dispatch at time τ
    /// cannot land a compute-side event on this shard before `τ +
    /// lookahead`, which is the window the shard may burn through local
    /// physics without another head exchange (see sim/shard.rs docs).
    pub fn lookahead_s(&self, links: &[LinkSpec], s: usize) -> f64 {
        let (lo, hi) = self.ranges[s];
        links[lo..hi]
            .iter()
            .map(|l| l.rtt_s)
            // lint: allow(nan-cmp) rtt_s is a positive config constant, never NaN
            .fold(f64::INFINITY, f64::min)
    }
}

impl TopologyConfig {
    /// Lower this topology to a [`ShardPlan`]: `Auto` gives one shard
    /// per tier (shard boundaries = tier boundaries), `Fixed(n)` gives
    /// `n` balanced contiguous chunks.
    pub fn shard_plan(&self, count: ShardCount) -> ShardPlan {
        match count {
            ShardCount::Fixed(n) => ShardPlan::contiguous(self.n_servers(), n),
            ShardCount::Auto => {
                let mut ranges = Vec::with_capacity(self.tiers.len());
                let mut lo = 0;
                for tier in &self.tiers {
                    if tier.count > 0 {
                        ranges.push((lo, lo + tier.count));
                        lo += tier.count;
                    }
                }
                assert!(!ranges.is_empty(), "topology has at least one tier");
                ShardPlan { ranges }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::csucb::CsUcb;
    use crate::sim::engine::simulate;
    use crate::workload::generator::{generate, ArrivalProcess, WorkloadConfig};

    /// The topology path must reproduce the historical constructor bit for
    /// bit — that is what keeps every existing paper-scale result
    /// comparable.
    #[test]
    fn paper_preset_builds_exact_paper_config() {
        for model in crate::sim::server::EDGE_MODELS {
            for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
                let from_topo = TopologyConfig::paper(model, mode).build();
                let direct = ClusterConfig::paper(model, mode);
                assert_eq!(from_topo, direct, "{model} {mode:?}");
            }
        }
    }

    /// And therefore paper-topology runs are decision-identical whichever
    /// constructor produced the config.
    #[test]
    fn paper_preset_runs_are_outcome_identical() {
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(300)
                .with_arrivals(ArrivalProcess::Poisson { rate: 12.0 })
                .with_seed(9),
        );
        let direct = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Fluctuating).build();
        let mut s1 = CsUcb::with_defaults(direct.n_servers());
        let mut s2 = CsUcb::with_defaults(topo.n_servers());
        let r1 = simulate(&direct, &trace, &mut s1);
        let r2 = simulate(&topo, &trace, &mut s2);
        assert_eq!(r1.outcomes.len(), r2.outcomes.len());
        for (a, b) in r1.outcomes.iter().zip(&r2.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.server, b.server);
            assert_eq!(a.completed_at.to_bits(), b.completed_at.to_bits());
        }
        assert_eq!(r1.events_processed, r2.events_processed);
    }

    #[test]
    fn preset_shapes_and_scales() {
        let p = TopologyConfig::paper("yi-6b", BandwidthMode::Stable);
        assert_eq!(p.n_servers(), 6);
        assert!((p.capacity_scale() - 1.0).abs() < 1e-12);

        let t10 = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        assert_eq!(t10.n_servers(), 60);
        assert!(
            t10.capacity_scale() > 9.0 && t10.capacity_scale() < 12.0,
            "scale {}",
            t10.capacity_scale()
        );
        assert!((t10.scaled_rate(15.0) - 15.0 * t10.capacity_scale()).abs() < 1e-9);

        let t100 = TopologyConfig::edgeshard_100x("yi-6b", BandwidthMode::Stable);
        assert_eq!(t100.n_servers(), 600);
        assert!(
            t100.capacity_scale() > 90.0 && t100.capacity_scale() < 120.0,
            "scale {}",
            t100.capacity_scale()
        );

        for name in TOPOLOGY_PRESETS {
            assert!(TopologyConfig::by_name(name, "yi-6b", BandwidthMode::Stable).is_some());
        }
        assert!(TopologyConfig::by_name("nope", "yi-6b", BandwidthMode::Stable).is_none());
    }

    #[test]
    fn build_wires_heterogeneous_tiers() {
        let cfg = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable).build();
        assert_eq!(cfg.n_servers(), 60);
        assert_eq!(cfg.links.len(), 60);
        // Tier boundaries by name.
        assert_eq!(cfg.servers[0].name, "edge-0");
        assert_eq!(cfg.servers[47].name, "edge-47");
        assert_eq!(cfg.servers[48].name, "hub-0");
        assert_eq!(cfg.servers[58].name, "cloud-0");
        assert_eq!(cfg.servers[59].name, "cloud-1");
        // Hubs sit between the extremes on throughput; clouds are Cloud.
        assert!(cfg.servers[48].prefill_rate > cfg.servers[0].prefill_rate);
        assert!(cfg.servers[48].prefill_rate < cfg.servers[58].prefill_rate);
        assert_eq!(cfg.servers[48].kind, ServerKind::Edge);
        assert_eq!(cfg.servers[58].kind, ServerKind::Cloud);
        assert_eq!(cfg.cloud_index(), 58);
        // Heterogeneous links per tier.
        assert_eq!(cfg.links[48].name, "hub-link-0");
        assert!(cfg.links[48].bandwidth_bps > cfg.links[0].bandwidth_bps);
        assert!(cfg.links[0].fluctuation == 0.0);
        // Fluctuating mode switches every tier's amplitude on.
        let f = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating).build();
        assert!(f.links.iter().all(|l| l.fluctuation > 0.0));
    }

    /// Per-tier service-model selection lowers into the per-server specs:
    /// "token-batch" switches every tier (KV budget scaled by tier
    /// slots), "token-batch-edge" leaves cloud tiers on the PS fluid.
    #[test]
    fn service_model_selection_lowers_per_tier() {
        use crate::sim::service_model::ServiceModelKind;
        let base = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
        assert!(base
            .build()
            .servers
            .iter()
            .all(|s| s.service_model == ServiceModelKind::Ps));

        let all = base
            .clone()
            .with_service_model_by_name("token-batch")
            .unwrap()
            .build();
        for s in &all.servers {
            match s.service_model {
                ServiceModelKind::TokenBatch { kv_tokens } => {
                    assert_eq!(kv_tokens as usize, s.slots * 1536, "{}", s.name);
                }
                other => panic!("{}: expected token-batch, got {other:?}", s.name),
            }
        }

        let edge_only = base
            .clone()
            .with_service_model_by_name("token-batch-edge")
            .unwrap()
            .build();
        for s in &edge_only.servers {
            match s.kind {
                ServerKind::Edge => {
                    assert!(matches!(s.service_model, ServiceModelKind::TokenBatch { .. }))
                }
                ServerKind::Cloud => assert_eq!(s.service_model, ServiceModelKind::Ps),
            }
        }

        assert!(base.clone().with_service_model_by_name("ps").is_some());
        assert!(base.clone().with_service_model_by_name("nope").is_none());

        // The literal-kind builders (one explicit kind, e.g. a custom KV
        // budget shared by every selected tier) are the programmatic
        // siblings of the by-name arms; pin their selection behavior so
        // the two entry points cannot drift apart silently.
        let fixed = ServiceModelKind::TokenBatch { kv_tokens: 4096 };
        let all_fixed = base.clone().with_service_model(fixed).build();
        assert!(all_fixed.servers.iter().all(|s| s.service_model == fixed));
        let edge_fixed = base
            .clone()
            .with_service_model_for_kind(ServerKind::Edge, fixed)
            .build();
        for s in &edge_fixed.servers {
            match s.kind {
                ServerKind::Edge => assert_eq!(s.service_model, fixed),
                ServerKind::Cloud => assert_eq!(s.service_model, ServiceModelKind::Ps),
            }
        }
        // And the edge-only selections agree tier-for-tier on *which*
        // servers switched, whichever entry point chose them.
        let by_name_edges = base
            .with_service_model_by_name("token-batch-edge")
            .unwrap()
            .build();
        for (a, b) in edge_fixed.servers.iter().zip(&by_name_edges.servers) {
            assert_eq!(
                matches!(a.service_model, ServiceModelKind::TokenBatch { .. }),
                matches!(b.service_model, ServiceModelKind::TokenBatch { .. }),
                "{}",
                a.name
            );
        }
    }

    /// A mixed-model fleet (token-batch edge under PS cloud) runs end to
    /// end through the unchanged engine and schedulers.
    #[test]
    fn mixed_model_paper_topology_runs_end_to_end() {
        let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Stable)
            .with_service_model_by_name("token-batch-edge")
            .unwrap();
        let cfg = topo.build();
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Poisson { rate: 12.0 })
                .with_deadline_range(2.0, 6.0)
                .with_seed(17),
        );
        let mut s = CsUcb::with_defaults(cfg.n_servers());
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 400);
        assert_eq!(rep.unfinished, 0);
        assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
    }

    #[test]
    fn shard_count_parses_cli_forms() {
        assert_eq!(ShardCount::parse("auto"), Some(ShardCount::Auto));
        assert_eq!(ShardCount::parse("AUTO"), Some(ShardCount::Auto));
        assert_eq!(ShardCount::parse("1"), Some(ShardCount::Fixed(1)));
        assert_eq!(ShardCount::parse("16"), Some(ShardCount::Fixed(16)));
        assert_eq!(ShardCount::parse("0"), None);
        assert_eq!(ShardCount::parse("-2"), None);
        assert_eq!(ShardCount::parse("many"), None);
    }

    #[test]
    fn auto_plan_follows_tier_boundaries() {
        let t10 = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let plan = t10.shard_plan(ShardCount::Auto);
        assert_eq!(plan.ranges, vec![(0, 48), (48, 58), (58, 60)]);
        assert_eq!(plan.n_shards(), 3);
        assert_eq!(plan.shard_of(0), 0);
        assert_eq!(plan.shard_of(47), 0);
        assert_eq!(plan.shard_of(48), 1);
        assert_eq!(plan.shard_of(59), 2);
    }

    #[test]
    fn fixed_plans_are_balanced_contiguous_covers() {
        for (n_servers, n_shards) in [(6, 1), (6, 4), (60, 4), (60, 7), (600, 16), (3, 9)] {
            let plan = ShardPlan::contiguous(n_servers, n_shards);
            assert!(plan.n_shards() <= n_shards);
            assert_eq!(plan.ranges[0].0, 0);
            assert_eq!(plan.ranges.last().unwrap().1, n_servers);
            let mut covered = 0;
            for (i, &(lo, hi)) in plan.ranges.iter().enumerate() {
                assert_eq!(lo, covered, "gap before shard {i}");
                assert!(hi > lo, "empty shard {i}");
                covered = hi;
            }
            // Balanced to within one server.
            let sizes: Vec<usize> = plan.ranges.iter().map(|&(lo, hi)| hi - lo).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
        }
    }

    /// Lookahead lowers from LinkSpec RTTs: per-tier shards read their
    /// tier's RTT (edge 5 ms, hub 20 ms, cloud 80 ms); a mixed chunk
    /// takes the min across the tiers it straddles.
    #[test]
    fn lookahead_derives_from_inbound_link_rtt() {
        let topo = TopologyConfig::edgeshard_10x("yi-6b", BandwidthMode::Stable);
        let cfg = topo.build();
        let auto = topo.shard_plan(ShardCount::Auto);
        assert!((auto.lookahead_s(&cfg.links, 0) - 0.005).abs() < 1e-12);
        assert!((auto.lookahead_s(&cfg.links, 1) - 0.02).abs() < 1e-12);
        assert!((auto.lookahead_s(&cfg.links, 2) - 0.08).abs() < 1e-12);
        let two = topo.shard_plan(ShardCount::Fixed(2));
        // Second chunk [30, 60) straddles edge+hub+cloud → min is edge.
        assert!((two.lookahead_s(&cfg.links, 1) - 0.005).abs() < 1e-12);
    }

    /// A short streaming run on the 10x preset end to end: every layer
    /// (engine, scheduler arms sized to 60 servers, candidate pruning)
    /// accepts the large topology.
    #[test]
    fn edgeshard_10x_runs_end_to_end() {
        let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
        let cfg = topo.build();
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(500)
                .with_arrivals(ArrivalProcess::Poisson {
                    rate: topo.scaled_rate(15.0),
                })
                .with_deadline_range(2.0, 6.0)
                .with_seed(5),
        );
        let mut s = CsUcb::with_defaults(cfg.n_servers());
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 500);
        assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
        assert!(rep.peak_event_queue_len < 500);
    }
}
