//! Edge-cloud cluster simulation substrate: discrete-event engine,
//! processor-sharing queues, network links (with the shared-cloud-uplink
//! congestion mechanism), server batching model, and Eq.-2 energy
//! accounting. This replaces the paper's physical testbed (DESIGN.md §2).

pub mod cluster;
pub mod energy;
pub mod engine;
pub mod faults;
pub mod net;
pub mod prefix;
pub mod ps;
pub mod server;
pub mod service_model;
pub(crate) mod shard;
pub mod time;
pub mod token_batch;
pub mod topology;

pub use cluster::{BandwidthMode, ClusterConfig, ClusterSim, Outage};
pub use energy::{EnergyBreakdown, EnergyWeights};
pub use engine::{
    simulate, simulate_faulted, simulate_faulted_sharded, simulate_sharded, simulate_stream,
    simulate_stream_faulted, simulate_stream_faulted_sharded, simulate_stream_sharded,
    AvailabilityReport, Engine, RunReport, ShardPerf, ShardPerfReport,
};
pub use faults::{
    CrashPolicy, FaultEvent, FaultKind, FaultPlan, GenerativeFaults, HealthConfig, HealthMonitor,
};
pub use prefix::{CacheCounters, PrefixCache, KV_CACHE_TOKENS_PER_SLOT};
pub use server::{ServerKind, ServerSpec, EDGE_MODELS};
pub use service_model::{PsServiceModel, ServiceModel, ServiceModelKind, ServicePrediction};
pub use token_batch::TokenBatchModel;
pub use topology::{
    EventVolumeModel, LookaheadClasses, ShardCount, ShardPlan, TierSpec, TopologyConfig,
    TOPOLOGY_PRESETS,
};
