//! Per-server KV-prefix cache (PR 10): the DES-side residency model
//! behind session affinity.
//!
//! A server that recently served a conversation still holds that
//! session's KV tokens; a follow-up turn landing there skips the cached
//! prefix's prefill entirely (`ServerSim::admit` shrinks the effective
//! prompt). Landing anywhere else pays full prefill — unless the engine
//! judged a KV transfer over the `LinkSpec` economical and stamped
//! `SessionRef::xfer_tokens` at dispatch.
//!
//! Capacity is counted in KV tokens and evicted LRU by whole sessions —
//! a partial prefix is still useful (reuse is `min(prefix, resident)`),
//! but real serving stacks drop whole conversations, and whole-session
//! eviction keeps the accounting exact. The recency list is a `BTreeMap`
//! keyed by a monotone sequence number (deterministic iteration order;
//! the `HashMap` alongside it is point-lookup only — D2-clean).

use std::collections::{BTreeMap, HashMap};

/// KV-cache tokens provisioned per batch slot: the prefix cache of a
/// server with `slots` slots holds `slots * KV_CACHE_TOKENS_PER_SLOT`
/// tokens. Sized so a paper-testbed edge server (8 slots) retains on the
/// order of twenty ~1.5k-token conversations — enough for affinity to
/// pay, small enough that a chat-heavy fleet sees real eviction
/// pressure.
pub const KV_CACHE_TOKENS_PER_SLOT: u64 = 4096;

/// Per-session residency entry.
#[derive(Debug, Clone, Copy)]
struct Entry {
    /// Recency key in `lru` (monotone; larger = more recent).
    seq: u64,
    /// KV tokens this session occupies.
    tokens: u64,
}

/// LRU cache of per-session KV-token residency for one server.
#[derive(Debug, Clone, Default)]
pub struct PrefixCache {
    /// Token capacity (0 = caching disabled; every lookup misses).
    capacity: u64,
    used: u64,
    seq: u64,
    /// session_id -> residency (point lookups only).
    entries: HashMap<u64, Entry>,
    /// recency seq -> session_id; first key is the LRU victim.
    lru: BTreeMap<u64, u64>,
    /// Sessions evicted under pressure (observability).
    pub evictions: u64,
}

impl PrefixCache {
    pub fn new(capacity_tokens: u64) -> PrefixCache {
        PrefixCache {
            capacity: capacity_tokens,
            ..PrefixCache::default()
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// KV tokens currently resident.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Occupancy in [0, 1] — the eviction-risk signal surfaced to
    /// schedulers as `ServerView::prefix_pressure`.
    pub fn occupancy(&self) -> f64 {
        if self.capacity == 0 {
            1.0
        } else {
            self.used as f64 / self.capacity as f64
        }
    }

    /// KV tokens resident for `session_id` (0 when absent). Read-only:
    /// prediction and view pricing must not disturb recency.
    pub fn resident_for(&self, session_id: u64) -> u64 {
        self.entries.get(&session_id).map_or(0, |e| e.tokens)
    }

    /// Record that this server just served a turn of `session_id` whose
    /// conversation now spans `tokens_after` KV tokens: the session
    /// becomes (or stays) resident at that footprint and most-recent,
    /// evicting least-recently-used sessions if needed. A footprint
    /// larger than the whole cache caps at capacity (the tail of the
    /// conversation is what stays hot).
    pub fn admit_turn(&mut self, session_id: u64, tokens_after: u64) {
        if self.capacity == 0 {
            return;
        }
        let tokens = tokens_after.min(self.capacity);
        if let Some(e) = self.entries.remove(&session_id) {
            self.lru.remove(&e.seq);
            self.used -= e.tokens;
        }
        while self.used + tokens > self.capacity {
            // lint: allow(P1) tokens <= capacity, so the loop guard implies used > 0 and lru is non-empty
            let (&seq, &victim) = self.lru.iter().next().expect("used > 0 implies entries");
            self.lru.remove(&seq);
            // lint: allow(P1) entries and lru are inserted/removed in lockstep (check_invariants pins it)
            let v = self.entries.remove(&victim).expect("lru entry backed");
            self.used -= v.tokens;
            self.evictions += 1;
        }
        self.seq += 1;
        let seq = self.seq;
        self.entries.insert(session_id, Entry { seq, tokens });
        self.lru.insert(seq, session_id);
        self.used += tokens;
    }

    /// Drop everything (hard-crash restart: KV memory does not survive).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.lru.clear();
        self.used = 0;
    }

    #[cfg(test)]
    fn check_invariants(&self) {
        assert_eq!(self.entries.len(), self.lru.len());
        let sum: u64 = self.lru.values().map(|sid| self.entries[sid].tokens).sum();
        assert_eq!(sum, self.used);
        assert!(self.used <= self.capacity || self.capacity == 0);
    }
}

/// Per-class cache observability counters for one server, folded into
/// `RunReport::cache` (identity-excluded, like all perf counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Session-turn admissions per class (lookup opportunities).
    pub lookups: [u64; 4],
    /// Lookups that reused a non-empty prefix, per class.
    pub hits: [u64; 4],
    /// Prefill tokens skipped thanks to reuse.
    pub prefill_tokens_saved: u64,
    /// KV bytes shipped over links to make remote turns warm.
    pub kv_transfer_bytes: u64,
    /// Whole-session LRU evictions under capacity pressure.
    pub evictions: u64,
}

impl CacheCounters {
    pub fn absorb(&mut self, other: &CacheCounters) {
        for c in 0..4 {
            self.lookups[c] += other.lookups[c];
            self.hits[c] += other.hits[c];
        }
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.kv_transfer_bytes += other.kv_transfer_bytes;
        self.evictions += other.evictions;
    }

    pub fn total_lookups(&self) -> u64 {
        self.lookups.iter().sum()
    }

    pub fn total_hits(&self) -> u64 {
        self.hits.iter().sum()
    }

    /// Overall hit rate (None when no session turn was ever admitted —
    /// the sessions-off case).
    pub fn hit_rate(&self) -> Option<f64> {
        let n = self.total_lookups();
        if n == 0 {
            None
        } else {
            Some(self.total_hits() as f64 / n as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_after_admit_turn_and_grows() {
        let mut c = PrefixCache::new(10_000);
        assert_eq!(c.resident_for(1), 0);
        c.admit_turn(1, 300);
        assert_eq!(c.resident_for(1), 300);
        c.admit_turn(1, 900);
        assert_eq!(c.resident_for(1), 900);
        assert_eq!(c.used(), 900, "re-admission replaces, never double-counts");
        c.check_invariants();
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut c = PrefixCache::new(1000);
        c.admit_turn(1, 400);
        c.admit_turn(2, 400);
        // Touch 1 so 2 becomes LRU.
        c.admit_turn(1, 400);
        c.admit_turn(3, 400); // needs 400, evicts session 2
        assert_eq!(c.resident_for(2), 0, "LRU victim");
        assert_eq!(c.resident_for(1), 400);
        assert_eq!(c.resident_for(3), 400);
        assert_eq!(c.evictions, 1);
        c.check_invariants();
    }

    #[test]
    fn oversized_session_caps_at_capacity() {
        let mut c = PrefixCache::new(500);
        c.admit_turn(1, 200);
        c.admit_turn(2, 10_000);
        assert_eq!(c.resident_for(2), 500);
        assert_eq!(c.resident_for(1), 0, "everything else evicted");
        c.check_invariants();
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = PrefixCache::new(0);
        c.admit_turn(1, 100);
        assert_eq!(c.resident_for(1), 0);
        assert_eq!(c.used(), 0);
        assert_eq!(c.occupancy(), 1.0, "no room is full, never attractive");
    }

    #[test]
    fn clear_drops_residency_but_keeps_eviction_count() {
        let mut c = PrefixCache::new(600);
        c.admit_turn(1, 400);
        c.admit_turn(2, 400); // evicts 1
        assert_eq!(c.evictions, 1);
        c.clear();
        assert_eq!(c.resident_for(2), 0);
        assert_eq!(c.used(), 0);
        assert_eq!(c.evictions, 1, "counters survive a crash");
        c.check_invariants();
    }

    #[test]
    fn eviction_under_pressure_is_deterministic() {
        // Two identical interleavings produce identical residency.
        let run = || {
            let mut c = PrefixCache::new(2_000);
            for i in 0..50u64 {
                c.admit_turn(i % 7, 100 + (i * 37) % 400);
                c.admit_turn((i + 3) % 11, 80 + (i * 13) % 300);
            }
            let snapshot: Vec<(u64, u64)> =
                (0..12u64).map(|sid| (sid, c.resident_for(sid))).collect();
            (snapshot, c.used(), c.evictions)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn counters_absorb_and_rate() {
        let mut a = CacheCounters::default();
        a.lookups[0] = 10;
        a.hits[0] = 4;
        a.prefill_tokens_saved = 800;
        let mut b = CacheCounters::default();
        b.lookups[0] = 2;
        b.hits[0] = 2;
        b.kv_transfer_bytes = 4096;
        b.evictions = 3;
        a.absorb(&b);
        assert_eq!(a.total_lookups(), 12);
        assert_eq!(a.total_hits(), 6);
        assert_eq!(a.hit_rate(), Some(0.5));
        assert_eq!(a.kv_transfer_bytes, 4096);
        assert_eq!(a.evictions, 3);
        assert_eq!(CacheCounters::default().hit_rate(), None);
    }
}
