//! Server layer: static server descriptions ([`ServerSpec`], including
//! which [`ServiceModelKind`] the server runs) and the per-server DES
//! state ([`ServerSim`]) — energy/busy integrators and the outage
//! multiplier around a pluggable [`ServiceModel`].
//!
//! Calibration (DESIGN.md §6) follows the paper's Figure-2 measurements:
//! the cloud A100 is ~6-10x faster per token and batches well; the edge
//! Xeon is slower but draws ~8x less power. A request's *solo work* is
//! `prompt/prefill_rate + output/decode_rate` seconds; how concurrent
//! requests share the server is the service model's business — the PS
//! fluid splits rate `eff(n)/n` per job, the token-batch model serves
//! discrete iterations (see `sim/service_model.rs`).
//!
//! # Migration note (PR 4)
//!
//! `ServerSim` no longer exposes a public `queue: PsQueue` — the
//! PS-specific internals moved behind the [`ServiceModel`] trait so
//! batching-sensitive models can plug in without forking the engine.
//! Old call sites translate mechanically:
//!
//! | pre-trait                                   | now                          |
//! |---------------------------------------------|------------------------------|
//! | `srv.queue.push(id, spec.solo_work(&r), t)` | `srv.admit(id, &r, t)`       |
//! | `srv.queue.reap_into(t, srv.per_job_rate(), &mut buf)` | `srv.reap_into(t, &mut buf)` |
//! | `srv.queue.peek_finish_work()` + rate guard | `srv.completion_key()`       |
//! | `srv.queue.next_completion_in(rate)`        | `srv.next_completion_in()`   |
//! | `srv.queue.n_active()` / `n_waiting()`      | `srv.n_active()` / `srv.n_waiting()` |
//! | `srv.predict_service_time(&r)`              | unchanged (plus `srv.predict(..)` for TTFT) |
//!
//! The PS default is bit-identical pre/post refactor — pinned by the
//! executable-spec run-identity test in
//! `rust/tests/service_model_identity.rs`.

use super::prefix::{CacheCounters, PrefixCache, KV_CACHE_TOKENS_PER_SLOT};
use super::service_model::{build_model, ServiceModel, ServiceModelKind, ServicePrediction};
use super::ps::PsJob;
use super::time::{Generation, SimTime};
use crate::workload::service::{ServiceRequest, SessionRef};

/// Server tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerKind {
    Edge,
    Cloud,
}

/// Static description of one server (one arm dimension of the bandit).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerSpec {
    pub name: String,
    pub kind: ServerKind,
    /// Prefill throughput, tokens/s (solo).
    pub prefill_rate: f64,
    /// Decode throughput, tokens/s (solo, single stream).
    pub decode_rate: f64,
    /// Max concurrent batch slots.
    pub slots: usize,
    /// Batching-efficiency exponent (see `batch_efficiency`).
    pub batch_alpha: f64,
    /// Power draw while any request is in service, watts.
    pub p_infer: f64,
    /// Idle power draw, watts.
    pub p_idle: f64,
    /// Abstract compute capacity units (paper C2's C_max).
    pub compute_capacity: f64,
    /// Bounded waiting queue: arrivals beyond `slots + queue_limit` are
    /// dropped (admission failure). Real serving stacks shed load rather
    /// than queue unboundedly; this is also what makes sustained-overload
    /// success rates meaningful (DESIGN.md §6).
    pub queue_limit: usize,
    /// Which token-level service model this server runs (PS fluid by
    /// default; topologies may select per tier).
    pub service_model: ServiceModelKind,
}

impl ServerSpec {
    /// Solo service work (seconds) for a request on this server.
    pub fn solo_work(&self, req: &ServiceRequest) -> f64 {
        req.prompt_tokens as f64 / self.prefill_rate
            + req.output_tokens as f64 / self.decode_rate
    }

    /// Compute-units demand of one request (paper C_i): normalized token
    /// work so capacity checks are server-independent.
    pub fn compute_demand(req: &ServiceRequest) -> f64 {
        (req.prompt_tokens as f64 + 4.0 * req.output_tokens as f64) / 1000.0
    }

    /// This spec with a different service model (topology per-tier
    /// selection / CLI overrides).
    pub fn with_service_model(mut self, model: ServiceModelKind) -> Self {
        self.service_model = model;
        self
    }
}

/// Dynamic server state inside the DES: energy/busy integrators and the
/// outage multiplier around the spec's [`ServiceModel`].
#[derive(Debug)]
pub struct ServerSim {
    pub spec: ServerSpec,
    /// The pluggable token-level service model. Public so the
    /// executable-spec identity tests can swap in reference
    /// implementations; production code goes through the delegating
    /// methods below.
    pub model: Box<dyn ServiceModel>,
    pub gen: Generation,
    /// Rate multiplier (1.0 normally, 0.0 during an injected outage).
    pub rate_mult: f64,
    last_update: SimTime,
    /// Integrated energy, joules.
    pub energy_infer_j: f64,
    pub energy_idle_j: f64,
    /// Integrated busy time (any slot occupied).
    pub busy_s: f64,
    /// Tokens fully served (throughput accounting).
    pub tokens_served: u64,
    /// KV-prefix residency for session follow-up turns (PR 10). Only
    /// session requests ever touch it — the single-shot path is
    /// instruction-identical to the pre-session engine.
    pub prefix: PrefixCache,
    /// Prefix-cache observability counters (identity-excluded).
    pub cache: CacheCounters,
}

impl ServerSim {
    pub fn new(spec: ServerSpec) -> Self {
        ServerSim {
            model: build_model(&spec),
            prefix: PrefixCache::new(spec.slots as u64 * KV_CACHE_TOKENS_PER_SLOT),
            spec,
            gen: Generation::new(),
            rate_mult: 1.0,
            last_update: 0.0,
            energy_infer_j: 0.0,
            energy_idle_j: 0.0,
            busy_s: 0.0,
            tokens_served: 0,
            cache: CacheCounters::default(),
        }
    }

    /// Advance integrators and job progress to `now`. Call before any state
    /// change and before scheduling the next completion. For the PS model
    /// this is O(1) (virtual-work-time counter bump + two scalar
    /// integrals); the token-batch model is O(batch) only when iterations
    /// actually complete.
    pub fn advance_to(&mut self, now: SimTime) {
        // lint: no-alloc runs on every event that touches this server
        let dt = now - self.last_update;
        if dt <= 0.0 {
            return;
        }
        let n = self.model.n_active();
        let busy = n > 0;
        let e_per_job = self.marginal_energy(dt, n);
        self.model.advance(dt, self.rate_mult, e_per_job);
        if busy {
            self.energy_infer_j += self.spec.p_infer * dt;
            self.busy_s += dt;
        } else {
            self.energy_idle_j += self.spec.p_idle * dt;
        }
        self.last_update = now;
        // lint: end-no-alloc
    }

    /// Marginal inference energy attributed to one job over `dt` seconds
    /// when `n` jobs share the server (per-service energy accounting).
    pub fn marginal_energy(&self, dt: f64, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        (self.spec.p_infer - self.spec.p_idle) * dt / n as f64
    }

    /// Prefill tokens a request would reuse if admitted here right now:
    /// 0 for single-shot requests; for a session turn, the usable part
    /// of its prefix given this server's KV residency (plus anything the
    /// engine shipped). Read-only — prediction and view pricing share it.
    #[inline]
    pub fn prefix_reuse(&self, req: &ServiceRequest) -> u32 {
        match req.session {
            Some(s) => s.usable_prefix(self.prefix.resident_for(s.session_id)),
            None => 0,
        }
    }

    /// Admit `req` as job `id` at `now` (the caller checked
    /// [`Self::would_drop`]).
    ///
    /// Session turns are where KV-prefix reuse physically happens: the
    /// reusable prefix is subtracted from the prompt the service model
    /// sees (its prefill was skipped), and the session's residency is
    /// refreshed to the conversation's new footprint. Single-shot
    /// requests take the verbatim pre-session path.
    pub fn admit(&mut self, id: u64, req: &ServiceRequest, now: SimTime) {
        match req.session {
            Some(s) => self.admit_session(id, req, s, now),
            None => self.model.admit(id, req, now),
        }
    }

    fn admit_session(&mut self, id: u64, req: &ServiceRequest, s: SessionRef, now: SimTime) {
        let reuse = s.usable_prefix(self.prefix.resident_for(s.session_id));
        let class = req.class.index();
        self.cache.lookups[class] += 1;
        if s.xfer_tokens > 0 {
            self.cache.kv_transfer_bytes += SessionRef::kv_bytes(s.xfer_tokens);
        }
        if reuse > 0 {
            self.cache.hits[class] += 1;
            self.cache.prefill_tokens_saved += reuse as u64;
            // ServiceRequest is all-inline data: the clone is a stack
            // copy, no allocation on this hot path.
            let mut eff = req.clone();
            eff.prompt_tokens = req.prompt_tokens.saturating_sub(reuse);
            self.model.admit(id, &eff, now);
        } else {
            self.model.admit(id, req, now);
        }
        let before = self.prefix.evictions;
        self.prefix
            .admit_turn(s.session_id, req.prompt_tokens as u64 + req.output_tokens as u64);
        self.cache.evictions += self.prefix.evictions - before;
    }

    /// Move finished jobs into `out` (cleared first) and promote waiters.
    pub fn reap_into(&mut self, now: SimTime, out: &mut Vec<PsJob>) {
        self.model.reap_into(now, self.rate_mult, out);
    }

    /// Seconds until the earliest completion at the current rate.
    pub fn next_completion_in(&self) -> Option<SimTime> {
        self.model.next_completion_in(self.rate_mult)
    }

    /// Reschedule-guard key (see `sim/service_model.rs` module docs).
    pub fn completion_key(&self) -> Option<(f64, f64)> {
        self.model.completion_key(self.rate_mult)
    }

    /// Jobs currently in service / waiting (view occupancy).
    pub fn n_active(&self) -> usize {
        self.model.n_active()
    }

    pub fn n_waiting(&self) -> usize {
        self.model.n_waiting()
    }

    /// Full TTFT + completion prediction for a request arriving now.
    /// Session turns are predicted at their *effective* prompt (reusable
    /// prefix subtracted), mirroring what [`Self::admit`] will do — the
    /// predictor and the physics must price reuse identically.
    pub fn predict(&self, req: &ServiceRequest, extra_n: usize, extra_work: f64) -> ServicePrediction {
        self.predict_with_rate(req, extra_n, extra_work, self.rate_mult)
    }

    /// Prediction at an explicit rate multiplier instead of ground truth
    /// — how a lagged health view prices this server: the cluster
    /// substitutes the monitor's *observed* health for `rate_mult`, so a
    /// just-crashed server still looks fast until the probe pipeline
    /// catches up.
    pub fn predict_with_rate(
        &self,
        req: &ServiceRequest,
        extra_n: usize,
        extra_work: f64,
        rate: f64,
    ) -> ServicePrediction {
        if req.session.is_some() {
            let reuse = self.prefix_reuse(req);
            if reuse > 0 {
                let mut eff = req.clone();
                eff.prompt_tokens = req.prompt_tokens.saturating_sub(reuse);
                return self.model.predict(&eff, extra_n, extra_work, rate);
            }
        }
        self.model.predict(req, extra_n, extra_work, rate)
    }

    /// Hard-crash restart: discard all in-service/queued jobs by
    /// rebuilding the service model cold, and invalidate any scheduled
    /// completion event. Energy/busy/token integrators survive — the
    /// server existed and drew power; its work just died. The caller owns
    /// failing/requeueing the jobs that were on board.
    pub fn crash_reset(&mut self, now: SimTime) {
        self.advance_to(now);
        self.model = build_model(&self.spec);
        // KV memory dies with the process: every resident prefix is gone.
        self.prefix.clear();
        self.gen.invalidate();
    }

    /// Predicted *additional* time for a request arriving now: queue wait
    /// estimate + stretched service time at the post-admission batch size.
    /// Shared by every scheduler (CS-UCB and baselines see the same
    /// predictor — differences come from their decision logic, not their
    /// information).
    pub fn predict_service_time(&self, req: &ServiceRequest) -> f64 {
        self.predict_service_time_with(req, 0, 0.0)
    }

    /// Prediction including `extra_n` requests (with `extra_work` total
    /// solo-work) already dispatched toward this server but still in
    /// flight on the network — the coordinator knows what it has sent.
    pub fn predict_service_time_with(
        &self,
        req: &ServiceRequest,
        extra_n: usize,
        extra_work: f64,
    ) -> f64 {
        self.predict(req, extra_n, extra_work).total_s
    }

    /// Paper C2: remaining compute capacity. Occupancy counts both batch
    /// slots and the bounded waiting queue, so a full server (which would
    /// drop the request) reports zero headroom and fails the C2 filter.
    pub fn compute_headroom(&self) -> f64 {
        self.compute_headroom_with(0)
    }

    /// Headroom counting `extra_n` in-flight dispatches toward this server.
    pub fn compute_headroom_with(&self, extra_n: usize) -> f64 {
        let cap = (self.model.slot_capacity() + self.model.queue_capacity()) as f64;
        let used = (self.model.n_active() + self.model.n_waiting() + extra_n) as f64;
        self.spec.compute_capacity * (1.0 - used / cap).max(0.0)
    }

    /// Would an arrival right now be shed? (bounded queue at its limit)
    pub fn would_drop(&self) -> bool {
        self.model.would_drop()
    }
}

/// Build the paper's testbed: five edge servers + one cloud server, with
/// the edge model deployment named by `edge_model` (Table 1 rows).
pub fn paper_testbed(edge_model: &str) -> Vec<ServerSpec> {
    // Decode rates per edge deployment, calibrated so the 6B model is
    // fastest and the 9B slowest (paper Table 1 trends). Absolute rates are
    // scaled so the tier capacity ratios match the paper's success rates
    // (DESIGN.md §6): edge tier ≈ 0.7x offered load, cloud path ≈ 0.6x,
    // combined ≈ 1.3x.
    let (prefill, decode) = match edge_model {
        "yi-6b" => (1700.0, 54.0),
        "llama2-7b" => (1550.0, 51.0),
        "llama3-8b" => (1400.0, 48.0),
        "yi-9b" => (1250.0, 45.0),
        // lint: allow(panic) config-time validation of a CLI preset name; a test pins the message
        other => panic!("unknown edge model {other}"),
    };
    let mut servers: Vec<ServerSpec> = (0..5)
        .map(|i| ServerSpec {
            name: format!("edge-{i}"),
            kind: ServerKind::Edge,
            prefill_rate: prefill,
            decode_rate: decode,
            slots: 8,
            batch_alpha: 0.58,
            p_infer: 45.0,
            p_idle: 6.0,
            compute_capacity: 8.0,
            queue_limit: 2,
            service_model: ServiceModelKind::Ps,
        })
        .collect();
    servers.push(ServerSpec {
        name: "cloud".into(),
        kind: ServerKind::Cloud,
        prefill_rate: 8000.0,
        decode_rate: 70.0,
        slots: 12,
        batch_alpha: 0.8,
        p_infer: 520.0,
        p_idle: 65.0,
        compute_capacity: 12.0,
        queue_limit: 4,
        service_model: ServiceModelKind::Ps,
    });
    servers
}

pub const EDGE_MODELS: [&str; 4] = ["yi-6b", "llama2-7b", "llama3-8b", "yi-9b"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::service::ServiceClass;

    fn req(prompt: u32, output: u32) -> ServiceRequest {
        ServiceRequest {
            id: 1,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: prompt,
            output_tokens: output,
            slo: crate::workload::service::SloSpec::completion_only(4.0),
            payload_bytes: 10_000,
            session: None,
        }
    }

    fn session_req(sid: u64, turn: u32, prefix: u32, prompt: u32, output: u32) -> ServiceRequest {
        let mut r = req(prompt, output);
        r.session = Some(SessionRef {
            session_id: sid,
            turn,
            prefix_tokens: prefix,
            xfer_tokens: 0,
        });
        r
    }

    fn edge_spec() -> ServerSpec {
        paper_testbed("llama2-7b")[0].clone()
    }

    fn cloud_spec() -> ServerSpec {
        paper_testbed("llama2-7b")[5].clone()
    }

    #[test]
    fn solo_work_cloud_faster() {
        let r = req(100, 50);
        assert!(cloud_spec().solo_work(&r) < edge_spec().solo_work(&r));
    }

    #[test]
    fn energy_integration_busy_vs_idle() {
        let mut s = ServerSim::new(edge_spec());
        s.advance_to(10.0); // idle 10 s
        assert!((s.energy_idle_j - 60.0).abs() < 1e-9); // 6 W * 10 s
        s.admit(1, &req(100, 40), 10.0);
        s.advance_to(11.0); // busy 1 s
        assert!((s.energy_infer_j - 45.0).abs() < 1e-9);
        assert!((s.busy_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_job_completes_at_solo_work() {
        let spec = edge_spec();
        let r = req(130, 10);
        let work = spec.solo_work(&r);
        let mut s = ServerSim::new(spec);
        s.admit(1, &r, 0.0);
        let eta = s.next_completion_in().unwrap();
        assert!((eta - work).abs() < 1e-9);
    }

    #[test]
    fn batching_stretches_per_job_but_raises_total() {
        // One job alone finishes its solo work in solo time; four equal
        // jobs each take longer (per-job stretch) but the batch completes
        // sooner than serial service (total throughput rises).
        let spec = cloud_spec();
        let r = req(800, 80);
        let solo = spec.solo_work(&r);
        let mut s1 = ServerSim::new(spec.clone());
        s1.admit(1, &r, 0.0);
        let t1 = s1.next_completion_in().unwrap();
        assert!((t1 - solo).abs() < 1e-9);
        let mut s4 = ServerSim::new(spec);
        for i in 0..4 {
            s4.admit(i, &r, 0.0);
        }
        let t4 = s4.next_completion_in().unwrap();
        assert!(t4 > t1, "per-job time must stretch with batch size");
        assert!(t4 < 4.0 * t1, "total throughput must rise");
    }

    #[test]
    fn predict_increases_with_load() {
        let mut s = ServerSim::new(edge_spec());
        let r = req(100, 40);
        let empty = s.predict_service_time(&r);
        for i in 0..8 {
            s.admit(i, &req(60, 100), 0.0);
        }
        let loaded = s.predict_service_time(&r);
        assert!(loaded > empty, "{loaded} vs {empty}");
    }

    #[test]
    fn prediction_exposes_ttft_below_total() {
        for spec in [edge_spec(), cloud_spec()] {
            let s = ServerSim::new(spec);
            let p = s.predict(&req(400, 100), 0, 0.0);
            assert!(p.ttft_s > 0.0);
            assert!(p.ttft_s < p.total_s, "{} !< {}", p.ttft_s, p.total_s);
        }
    }

    #[test]
    fn outage_gives_zero_rate() {
        let mut s = ServerSim::new(edge_spec());
        s.admit(1, &req(100, 40), 0.0);
        s.rate_mult = 0.0;
        assert!(s.next_completion_in().is_none());
        assert!(s.completion_key().is_none());
    }

    #[test]
    fn testbed_shape() {
        for m in EDGE_MODELS {
            let tb = paper_testbed(m);
            assert_eq!(tb.len(), 6);
            assert_eq!(tb.iter().filter(|s| s.kind == ServerKind::Edge).count(), 5);
            assert_eq!(tb[5].kind, ServerKind::Cloud);
            // Cloud is faster but hungrier.
            assert!(tb[5].decode_rate > tb[0].decode_rate);
            assert!(tb[5].p_infer > 5.0 * tb[0].p_infer);
            // PS fluid remains the default model everywhere.
            assert!(tb.iter().all(|s| s.service_model == ServiceModelKind::Ps));
        }
    }

    #[test]
    fn with_service_model_swaps_kind() {
        let spec = edge_spec().with_service_model(ServiceModelKind::token_batch_for(8));
        assert_ne!(spec.service_model, ServiceModelKind::Ps);
        let s = ServerSim::new(spec);
        assert_eq!(s.n_active(), 0);
        assert_eq!(s.model.slot_capacity(), 8);
    }

    #[test]
    #[should_panic]
    fn unknown_model_panics() {
        paper_testbed("gpt-5");
    }

    /// A follow-up turn on the server that served turn 1 skips its
    /// prefix's prefill: the completion ETA shrinks by exactly
    /// `prefix / prefill_rate` vs a cold server, and the hit counters
    /// record the reuse.
    #[test]
    fn warm_follow_up_skips_prefix_prefill() {
        let spec = edge_spec();
        let prefill = spec.prefill_rate;
        let mut warm = ServerSim::new(spec.clone());
        warm.admit(1, &session_req(7, 1, 0, 100, 40), 0.0);
        let mut drain = Vec::new();
        warm.advance_to(100.0);
        warm.reap_into(100.0, &mut drain);
        assert_eq!(drain.len(), 1, "turn 1 completed");
        assert_eq!(warm.prefix.resident_for(7), 140, "conversation resident");

        // Turn 2: prefix 140 of a 200-token prompt.
        let t2 = session_req(7, 2, 140, 200, 40);
        let mut cold = ServerSim::new(spec);
        let eta_warm = warm.predict(&t2, 0, 0.0).total_s;
        let eta_cold = cold.predict(&t2, 0, 0.0).total_s;
        let saved = eta_cold - eta_warm;
        assert!(
            (saved - 140.0 / prefill).abs() < 1e-9,
            "saved {saved} != prefix prefill {}",
            140.0 / prefill
        );
        // Physics matches the prediction: admit and check the ETA.
        warm.admit(2, &t2, 100.0);
        cold.admit(2, &t2, 100.0);
        let warm_eta = warm.next_completion_in().unwrap();
        let cold_eta = cold.next_completion_in().unwrap();
        assert!((cold_eta - warm_eta - 140.0 / prefill).abs() < 1e-9);
        assert_eq!(warm.cache.hits[0], 1);
        assert_eq!(warm.cache.prefill_tokens_saved, 140);
        assert_eq!(cold.cache.hits[0], 0, "cold server missed");
        assert_eq!(cold.cache.lookups[0], 1);
    }

    /// Shipped KV tokens (`xfer_tokens`) count as residency on arrival
    /// and are billed as transfer bytes.
    #[test]
    fn shipped_prefix_counts_as_warm() {
        let mut s = ServerSim::new(edge_spec());
        let mut t2 = session_req(9, 2, 100, 160, 40);
        t2.session.as_mut().unwrap().xfer_tokens = 100;
        let cold_eta = {
            let c = ServerSim::new(edge_spec());
            c.predict(&session_req(9, 2, 100, 160, 40), 0, 0.0).total_s
        };
        assert!(s.predict(&t2, 0, 0.0).total_s < cold_eta);
        s.admit(1, &t2, 0.0);
        assert_eq!(s.cache.hits[0], 1);
        assert_eq!(
            s.cache.kv_transfer_bytes,
            crate::workload::service::SessionRef::kv_bytes(100)
        );
        assert_eq!(s.cache.prefill_tokens_saved, 100);
    }

    /// Crash restarts dump KV memory: the session must re-prefill.
    #[test]
    fn crash_reset_clears_prefix_residency() {
        let mut s = ServerSim::new(edge_spec());
        s.admit(1, &session_req(3, 1, 0, 80, 20), 0.0);
        assert_eq!(s.prefix.resident_for(3), 100);
        s.crash_reset(1.0);
        assert_eq!(s.prefix.resident_for(3), 0);
        assert_eq!(s.prefix_reuse(&session_req(3, 2, 100, 150, 20)), 0);
    }

    /// Single-shot requests never touch the prefix machinery.
    #[test]
    fn single_shot_requests_bypass_the_cache() {
        let mut s = ServerSim::new(edge_spec());
        s.admit(1, &req(100, 40), 0.0);
        assert_eq!(s.cache.lookups, [0; 4]);
        assert_eq!(s.prefix.used(), 0);
        assert_eq!(s.prefix_reuse(&req(100, 40)), 0);
    }
}
