//! Discrete-event engine core: a time-ordered event queue with stable
//! FIFO tie-breaking and generation-stamped cancellation.
//!
//! The cluster simulation (sim/engine.rs) uses processor-sharing queues for
//! both the shared cloud uplink and server batch slots; those recompute
//! completion times whenever occupancy changes, which is expressed here by
//! bumping a generation counter and letting stale events fall through.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated seconds.
pub type SimTime = f64;

#[derive(Debug, Clone)]
struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. NaN times are
        // rejected at push, so partial_cmp is total here.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with a monotone clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
    stale: u64,
    peak_len: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
            stale: 0,
            peak_len: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far (perf metric: DES events/s).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Record that a popped event was generation-invalidated and dropped.
    /// Stale events still cost a heap pop, so tracking them keeps events/s
    /// honest: a high stale ratio means the queue is churning on cancelled
    /// completions rather than real work.
    pub fn note_stale(&mut self) {
        self.stale += 1;
    }

    /// Number of popped events that were stale (generation-invalidated).
    pub fn stale(&self) -> u64 {
        self.stale
    }

    /// Fraction of popped events that were stale, in [0, 1].
    pub fn stale_ratio(&self) -> f64 {
        self.stale as f64 / self.processed.max(1) as f64
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Largest number of events ever simultaneously pending. With a
    /// streaming [`crate::workload::ArrivalSource`] (one prefetched
    /// arrival) this stays bounded by in-flight concurrency, not trace
    /// length — the memory guarantee the 1M-request run relies on.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at` (clamped to now; NaN rejected).
    pub fn push_at(&mut self, at: SimTime, event: E) {
        assert!(!at.is_nan(), "NaN event time");
        let t = if at < self.now { self.now } else { at };
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
        self.peak_len = self.peak_len.max(self.heap.len());
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn push_in(&mut self, delay: SimTime, event: E) {
        assert!(delay >= 0.0 && !delay.is_nan(), "bad delay {delay}");
        self.push_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now, "time went backwards");
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.event))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }
}

/// Generation counter for cancellable completion events: schedule events
/// stamped with `current()`, bump with `invalidate()` whenever the
/// underlying computation changes, and drop popped events whose stamp is
/// stale.
#[derive(Debug, Default, Clone, Copy)]
pub struct Generation(u64);

impl Generation {
    pub fn new() -> Self {
        Generation(0)
    }

    pub fn current(&self) -> u64 {
        self.0
    }

    pub fn invalidate(&mut self) -> u64 {
        self.0 += 1;
        self.0
    }

    pub fn is_current(&self, stamp: u64) -> bool {
        self.0 == stamp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push_at(5.0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(1.0, ());
        q.push_at(4.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        // Past-dated push is clamped to now, not allowed to rewind the clock.
        q.push_at(0.5, ());
        let (t, _) = q.pop().unwrap();
        assert!(t >= 1.0);
        q.pop();
        assert_eq!(q.now(), 4.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn push_in_relative() {
        let mut q = EventQueue::new();
        q.push_at(2.0, "first");
        q.pop();
        q.push_in(1.5, "second");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "second");
        assert!((t - 3.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn nan_time_rejected() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, ());
    }

    #[test]
    fn stale_accounting() {
        let mut q = EventQueue::new();
        q.push_at(1.0, "live");
        q.push_at(2.0, "stale");
        q.pop();
        q.pop();
        q.note_stale();
        assert_eq!(q.stale(), 1);
        assert_eq!(q.processed(), 2);
        assert!((q.stale_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stale_ratio_zero_when_empty() {
        let q: EventQueue<()> = EventQueue::new();
        assert_eq!(q.stale(), 0);
        assert_eq!(q.stale_ratio(), 0.0);
    }

    #[test]
    fn peak_len_tracks_high_water_mark() {
        let mut q = EventQueue::new();
        assert_eq!(q.peak_len(), 0);
        q.push_at(1.0, ());
        q.push_at(2.0, ());
        q.push_at(3.0, ());
        assert_eq!(q.peak_len(), 3);
        q.pop();
        q.pop();
        q.push_at(4.0, ());
        // Draining doesn't lower the high-water mark.
        assert_eq!(q.peak_len(), 3);
    }

    #[test]
    fn generation_invalidates() {
        let mut g = Generation::new();
        let stamp = g.current();
        assert!(g.is_current(stamp));
        g.invalidate();
        assert!(!g.is_current(stamp));
        assert!(g.is_current(g.current()));
    }
}
