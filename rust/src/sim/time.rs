//! Discrete-event engine core: a time-ordered event queue with stable
//! FIFO tie-breaking and generation-stamped cancellation.
//!
//! The cluster simulation (sim/engine.rs) uses processor-sharing queues for
//! both the shared cloud uplink and server batch slots; those recompute
//! completion times whenever occupancy changes, which is expressed here by
//! bumping a generation counter and letting stale events fall through.
//!
//! # Calendar queue
//!
//! [`EventQueue`] is a **calendar queue** (Brown, CACM'88): events hash
//! into time-width buckets and pop walks the current "day" bucket, so
//! push/pop are O(1) amortized instead of the binary heap's O(log n) —
//! the difference shows up at 10-100x cluster scale where hundreds of
//! servers keep hundreds of completion events in flight. The width and
//! bucket count resize automatically as occupancy changes. Ordering is
//! *exactly* the heap's — earliest time first, FIFO (push order) on ties
//! — and the previous heap implementation is retained as
//! [`HeapEventQueue`], an executable specification the differential
//! proptest (`rust/tests/calendar_queue_equivalence.rs`) checks the
//! calendar queue against, pop for pop.
//!
//! Ordering is drift-free by construction: each event carries its
//! *virtual bucket number* (`floor(time / width)`, an integer), pop
//! drains virtual buckets in integer order, and within a bucket entries
//! are kept sorted by `(time, seq)`. Since the bucket number is monotone
//! in time, integer bucket order + in-bucket order is total `(time,
//! seq)` order — no float accumulation is ever compared against.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated seconds.
pub type SimTime = f64;

/// Smallest / largest bucket counts the calendar resizes between.
const MIN_BUCKETS: usize = 8;
const MAX_BUCKETS: usize = 1 << 20;

/// Entries sampled from the head region when recomputing the bucket width
/// at resize time (Brown's calendar queues sample the head so one
/// far-future outlier — e.g. an outage-end event — cannot blow the width
/// up to the whole horizon).
const WIDTH_SAMPLE: usize = 32;

#[derive(Debug, Clone)]
struct CalEntry<E> {
    time: SimTime,
    seq: u64,
    /// Virtual bucket number `floor(time / width)` at the current width:
    /// the integer pop order that makes bucket draining drift-free.
    vb: u64,
    event: E,
}

/// Earliest-first event queue with a monotone clock (calendar-queue
/// implementation; same observable behavior as [`HeapEventQueue`]).
#[derive(Debug)]
pub struct EventQueue<E> {
    /// `buckets[vb % nbuckets]`, each sorted by `(time, seq)` DESCENDING
    /// so the earliest entry is at the end (O(1) pop via `Vec::pop`).
    buckets: Vec<Vec<CalEntry<E>>>,
    /// Power of two, so `vb % nbuckets` stays cheap and stable.
    nbuckets: usize,
    /// Seconds per bucket.
    width: f64,
    /// The virtual bucket pop is currently draining.
    cur_vb: u64,
    len: usize,
    now: SimTime,
    seq: u64,
    processed: u64,
    stale: u64,
    peak_len: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            nbuckets: MIN_BUCKETS,
            width: 1.0,
            cur_vb: 0,
            len: 0,
            now: 0.0,
            seq: 0,
            processed: 0,
            stale: 0,
            peak_len: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far (perf metric: DES events/s).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Record that a popped event was generation-invalidated and dropped.
    /// Stale events still cost a pop, so tracking them keeps events/s
    /// honest: a high stale ratio means the queue is churning on cancelled
    /// completions rather than real work.
    pub fn note_stale(&mut self) {
        self.stale += 1;
    }

    /// Number of popped events that were stale (generation-invalidated).
    pub fn stale(&self) -> u64 {
        self.stale
    }

    /// Fraction of popped events that were stale, in [0, 1].
    pub fn stale_ratio(&self) -> f64 {
        self.stale as f64 / self.processed.max(1) as f64
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Largest number of events ever simultaneously pending. With a
    /// streaming [`crate::workload::ArrivalSource`] (one prefetched
    /// arrival) this stays bounded by in-flight concurrency, not trace
    /// length — the memory guarantee the 1M-request run relies on.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Virtual bucket of `t` at the current width. The float division is
    /// only a *hash*: ordering never compares accumulated floats, it
    /// compares these integers (monotone in `t`) and then `(time, seq)`.
    #[inline]
    fn vbucket_of(&self, t: SimTime) -> u64 {
        // `as` saturates at u64::MAX for huge quotients, which keeps
        // far-future events (outage horizons) ordered: they share the top
        // bucket number and fall back to exact (time, seq) order there.
        (t / self.width) as u64
    }

    /// Schedule `event` at absolute time `at` (clamped to now; must be
    /// finite — the calendar hash has no bucket for NaN/inf).
    pub fn push_at(&mut self, at: SimTime, event: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let t = if at < self.now { self.now } else { at };
        let seq = self.seq;
        self.seq += 1;
        let vb = self.vbucket_of(t);
        let entry = CalEntry {
            time: t,
            seq,
            vb,
            event,
        };
        let bucket = &mut self.buckets[(vb % self.nbuckets as u64) as usize];
        // Descending (time, seq): find the insertion point from the sorted
        // prefix of strictly-greater entries. Buckets hold ~1-2 entries at
        // the steady-state width, so this is effectively O(1).
        let pos = bucket.partition_point(|e| (e.time, e.seq) > (t, seq));
        bucket.insert(pos, entry);
        self.len += 1;
        self.peak_len = self.peak_len.max(self.len);
        if self.len > 2 * self.nbuckets && self.nbuckets < MAX_BUCKETS {
            self.rebuild();
        }
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn push_in(&mut self, delay: SimTime, event: E) {
        assert!(delay >= 0.0 && !delay.is_nan(), "bad delay {delay}");
        self.push_at(self.now + delay, event);
    }

    /// Schedule `event` at `at` under a caller-issued ordering stamp
    /// instead of the queue's own `seq` counter.
    ///
    /// This is the cross-queue tie-order primitive of the sharded engine
    /// (sim/shard.rs): the orchestrator issues globally comparable stamps
    /// so that `(time, stamp)` across *several* shard-local queues
    /// reproduces the single sequential queue's `(time, seq)` total
    /// order. The internal counter is advanced past the stamp so a later
    /// plain `push_at` can never collide with or pre-empt a stamped
    /// entry. Stamps must be unique per queue (the sharded stamp clock
    /// guarantees this by construction).
    pub fn push_at_stamped(&mut self, at: SimTime, stamp: u64, event: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let t = if at < self.now { self.now } else { at };
        self.seq = self.seq.max(stamp.saturating_add(1));
        let vb = self.vbucket_of(t);
        let entry = CalEntry {
            time: t,
            seq: stamp,
            vb,
            event,
        };
        let bucket = &mut self.buckets[(vb % self.nbuckets as u64) as usize];
        let pos = bucket.partition_point(|e| (e.time, e.seq) > (t, stamp));
        bucket.insert(pos, entry);
        self.len += 1;
        self.peak_len = self.peak_len.max(self.len);
        if self.len > 2 * self.nbuckets && self.nbuckets < MAX_BUCKETS {
            self.rebuild();
        }
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if self.len == 0 {
            return None;
        }
        // Walk the calendar from the current virtual bucket. Entries of
        // virtual bucket `vb` live only in ring slot `vb % nbuckets`, and
        // the in-bucket minimum is at the end, so one `last()` check per
        // step suffices. A full fruitless lap (sparse queue: next event
        // more than a "year" away) falls back to a direct min search.
        for _ in 0..self.nbuckets {
            let slot = (self.cur_vb % self.nbuckets as u64) as usize;
            if self.buckets[slot].last().is_some_and(|tail| tail.vb == self.cur_vb) {
                if let Some(e) = self.buckets[slot].pop() {
                    return Some(self.finish_pop(e));
                }
            }
            // Saturating: a u64::MAX virtual bucket (astronomically far
            // future) must not overflow the scan; the direct-search
            // fallback below handles whatever the lap cannot reach.
            self.cur_vb = self.cur_vb.saturating_add(1);
        }
        // Direct search: the global minimum is the smallest bucket tail.
        let slot = (0..self.nbuckets)
            .filter(|&i| !self.buckets[i].is_empty())
            .min_by(|&a, &b| {
                let ea = self.buckets[a].last().expect("non-empty"); // lint: allow(p1) filter keeps only non-empty buckets
                let eb = self.buckets[b].last().expect("non-empty"); // lint: allow(p1) filter keeps only non-empty buckets
                (ea.time, ea.seq)
                    .partial_cmp(&(eb.time, eb.seq))
                    // lint: allow(p1, n1) event times are asserted finite at push
                    .expect("finite times")
            })
            // lint: allow(p1) len > 0 was checked on entry, so a bucket is non-empty
            .expect("len > 0");
        // lint: allow(p1) slot was selected among non-empty buckets
        let e = self.buckets[slot].pop().expect("non-empty");
        self.cur_vb = e.vb;
        Some(self.finish_pop(e))
    }

    fn finish_pop(&mut self, e: CalEntry<E>) -> (SimTime, E) {
        debug_assert!(e.time >= self.now, "time went backwards");
        self.len -= 1;
        self.now = e.time;
        self.processed += 1;
        if self.len < self.nbuckets / 4 && self.nbuckets > MIN_BUCKETS {
            self.rebuild();
        }
        (e.time, e.event)
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.peek().map(|(t, _, _)| t)
    }

    /// Peek at the head `(time, stamp, event)` without popping or
    /// advancing the clock.
    ///
    /// The sharded grant protocol classifies the head (local physics vs
    /// scheduler-coupled boundary) *before* committing to process it: a
    /// pop would advance `now` and clamp any earlier event a concurrent
    /// merge-barrier dispatch lands afterwards, so classification must be
    /// possible by reference.
    pub fn peek(&self) -> Option<(SimTime, u64, &E)> {
        self.buckets
            .iter()
            .filter_map(|b| b.last())
            .min_by(|a, b| {
                (a.time, a.seq)
                    .partial_cmp(&(b.time, b.seq))
                    // lint: allow(p1, n1) event times are asserted finite at push
                    .expect("finite times")
            })
            .map(|e| (e.time, e.seq, &e.event))
    }

    /// Re-hash every entry into a bucket array sized for the current
    /// occupancy, with the width re-estimated from inter-event gaps near
    /// the head. O(len log len); triggered O(log) times per doubling, so
    /// amortized cost per operation stays constant.
    fn rebuild(&mut self) {
        let mut all: Vec<CalEntry<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.append(b);
        }
        all.sort_by(|a, b| {
            (a.time, a.seq)
                .partial_cmp(&(b.time, b.seq))
                // lint: allow(p1, n1) event times are asserted finite at push
                .expect("finite times")
        });

        // Width: a few times the mean gap over the head region keeps
        // ~one event per bucket without letting a far-future outlier
        // stretch the calendar to the horizon.
        let sample = &all[..all.len().min(WIDTH_SAMPLE)];
        let mut gaps = 0.0;
        let mut n_gaps = 0u32;
        for w in sample.windows(2) {
            let g = w[1].time - w[0].time;
            if g > 0.0 {
                gaps += g;
                n_gaps += 1;
            }
        }
        if n_gaps > 0 {
            self.width = (4.0 * gaps / n_gaps as f64).clamp(1e-9, 1e9);
        }

        self.nbuckets = all
            .len()
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        self.buckets = (0..self.nbuckets).map(|_| Vec::new()).collect();
        self.cur_vb = self.vbucket_of(self.now);
        // Insert in descending global order so every bucket ends up
        // descending-sorted with plain appends.
        for mut e in all.into_iter().rev() {
            e.vb = self.vbucket_of(e.time);
            self.buckets[(e.vb % self.nbuckets as u64) as usize].push(e);
        }
    }
}

#[derive(Debug, Clone)]
struct HeapEntry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for HeapEntry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for HeapEntry<E> {}

impl<E> PartialOrd for HeapEntry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for HeapEntry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. NaN times
        // are rejected at push, so partial_cmp is total here.
        other
            .time
            .partial_cmp(&self.time)
            // lint: allow(p1, n1) NaN times are rejected at push, so the ordering is total
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The original binary-heap event queue, retained as the **executable
/// specification** for [`EventQueue`]: same API, same observable
/// behavior, O(log n) operations. The differential proptest
/// (`rust/tests/calendar_queue_equivalence.rs`) replays randomized
/// push/pop sequences against both and demands pop-for-pop equality,
/// FIFO tie-breaks included.
#[derive(Debug)]
pub struct HeapEventQueue<E> {
    heap: BinaryHeap<HeapEntry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
    stale: u64,
    peak_len: usize,
}

impl<E> Default for HeapEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapEventQueue<E> {
    pub fn new() -> Self {
        HeapEventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
            stale: 0,
            peak_len: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn note_stale(&mut self) {
        self.stale += 1;
    }

    pub fn stale(&self) -> u64 {
        self.stale
    }

    pub fn stale_ratio(&self) -> f64 {
        self.stale as f64 / self.processed.max(1) as f64
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at` (clamped to now; must be
    /// finite, matching the calendar implementation).
    pub fn push_at(&mut self, at: SimTime, event: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let t = if at < self.now { self.now } else { at };
        self.heap.push(HeapEntry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
        self.peak_len = self.peak_len.max(self.heap.len());
    }

    pub fn push_in(&mut self, delay: SimTime, event: E) {
        assert!(delay >= 0.0 && !delay.is_nan(), "bad delay {delay}");
        self.push_at(self.now + delay, event);
    }

    /// Stamped push — spec twin of [`EventQueue::push_at_stamped`].
    pub fn push_at_stamped(&mut self, at: SimTime, stamp: u64, event: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let t = if at < self.now { self.now } else { at };
        self.seq = self.seq.max(stamp.saturating_add(1));
        self.heap.push(HeapEntry {
            time: t,
            seq: stamp,
            event,
        });
        self.peak_len = self.peak_len.max(self.heap.len());
    }

    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now, "time went backwards");
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.event))
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    /// Peek `(time, stamp, event)` — spec twin of [`EventQueue::peek`].
    pub fn peek(&self) -> Option<(SimTime, u64, &E)> {
        self.heap.peek().map(|e| (e.time, e.seq, &e.event))
    }
}

/// Generation counter for cancellable completion events: schedule events
/// stamped with `current()`, bump with `invalidate()` whenever the
/// underlying computation changes, and drop popped events whose stamp is
/// stale.
#[derive(Debug, Default, Clone, Copy)]
pub struct Generation(u64);

impl Generation {
    pub fn new() -> Self {
        Generation(0)
    }

    pub fn current(&self) -> u64 {
        self.0
    }

    pub fn invalidate(&mut self) -> u64 {
        self.0 += 1;
        self.0
    }

    pub fn is_current(&self, stamp: u64) -> bool {
        self.0 == stamp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push_at(5.0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(1.0, ());
        q.push_at(4.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        // Past-dated push is clamped to now, not allowed to rewind the clock.
        q.push_at(0.5, ());
        let (t, _) = q.pop().unwrap();
        assert!(t >= 1.0);
        q.pop();
        assert_eq!(q.now(), 4.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn push_in_relative() {
        let mut q = EventQueue::new();
        q.push_at(2.0, "first");
        q.pop();
        q.push_in(1.5, "second");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "second");
        assert!((t - 3.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn nan_time_rejected() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, ());
    }

    #[test]
    #[should_panic]
    fn infinite_time_rejected() {
        let mut q = EventQueue::new();
        q.push_at(f64::INFINITY, ());
    }

    #[test]
    fn stale_accounting() {
        let mut q = EventQueue::new();
        q.push_at(1.0, "live");
        q.push_at(2.0, "stale");
        q.pop();
        q.pop();
        q.note_stale();
        assert_eq!(q.stale(), 1);
        assert_eq!(q.processed(), 2);
        assert!((q.stale_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stale_ratio_zero_when_empty() {
        let q: EventQueue<()> = EventQueue::new();
        assert_eq!(q.stale(), 0);
        assert_eq!(q.stale_ratio(), 0.0);
    }

    #[test]
    fn peak_len_tracks_high_water_mark() {
        let mut q = EventQueue::new();
        assert_eq!(q.peak_len(), 0);
        q.push_at(1.0, ());
        q.push_at(2.0, ());
        q.push_at(3.0, ());
        assert_eq!(q.peak_len(), 3);
        q.pop();
        q.pop();
        q.push_at(4.0, ());
        // Draining doesn't lower the high-water mark.
        assert_eq!(q.peak_len(), 3);
    }

    /// Enough pushes to force several calendar resizes (grow past the
    /// initial 8 buckets, then shrink while draining), with sub-width
    /// spacing so many events share a virtual bucket.
    #[test]
    fn survives_resizes_in_order() {
        let mut q = EventQueue::new();
        for i in 0..500u64 {
            // Deterministic scatter into [0, 5) with repeats.
            q.push_at((i * 7919 % 500) as f64 / 100.0, i);
        }
        let mut popped = Vec::new();
        while let Some((t, e)) = q.pop() {
            popped.push((t, e));
        }
        assert_eq!(popped.len(), 500);
        for w in popped.windows(2) {
            assert!(
                w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[0].1 < w[1].1),
                "order violated: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    /// A far-future event (an outage horizon) among dense near-term
    /// events exercises the direct-search fallback and must not disturb
    /// ordering or the width estimate.
    #[test]
    fn far_future_outlier_pops_last() {
        let mut q = EventQueue::new();
        q.push_at(1.0e9, "horizon");
        for i in 0..100u64 {
            q.push_at(i as f64 * 1e-3, "dense");
        }
        for _ in 0..100 {
            let (t, e) = q.pop().unwrap();
            assert_eq!(e, "dense");
            assert!(t < 1.0);
        }
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1.0e9, "horizon"));
        assert!(q.is_empty());
    }

    /// Interleaved push/pop with a monotone clock — the DES access
    /// pattern — across a resize boundary.
    #[test]
    fn interleaved_pop_push_stays_sorted() {
        let mut q = EventQueue::new();
        for i in 0..40u64 {
            q.push_at(i as f64 * 0.1, i);
        }
        let mut last = -1.0f64;
        let mut n = 0u64;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
            if n % 3 == 0 && n < 60 {
                q.push_in(0.05, 1000 + n);
            }
        }
        assert!(n > 40);
    }

    #[test]
    fn heap_spec_same_basic_behavior() {
        let mut q = HeapEventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(1.0, "a2");
        q.push_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "a2", "b", "c"]);
        assert_eq!(q.processed(), 4);
        assert_eq!(q.peak_len(), 4);
    }

    #[test]
    fn peek_matches_next_pop() {
        let mut q = EventQueue::new();
        q.push_at(2.0, "b");
        q.push_at(1.0, "a");
        let (t, _, e) = q.peek().expect("head");
        assert_eq!((t, *e), (1.0, "a"));
        // Peeking is pure: clock and counters untouched.
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.len(), 2);
        let (t2, e2) = q.pop().unwrap();
        assert_eq!((t2, e2), (1.0, "a"));
        assert_eq!(q.peek().map(|(t, _, e)| (t, *e)), Some((2.0, "b")));
    }

    #[test]
    fn stamped_pushes_order_across_plain_pushes() {
        // Stamps are the ordering key on ties: a stamped entry slots in
        // exactly where a plain push with that seq would have.
        let mut q = EventQueue::new();
        q.push_at(5.0, "seq0");
        q.push_at_stamped(5.0, 10, "stamp10");
        q.push_at_stamped(5.0, 3, "stamp3");
        // Plain push after a stamp of 10 must get seq >= 11.
        q.push_at(5.0, "seq11");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["seq0", "stamp3", "stamp10", "seq11"]);
    }

    #[test]
    fn stamped_agrees_with_heap_spec() {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        let script: &[(f64, u64)] = &[
            (1.0, 7),
            (1.0, 2),
            (0.5, 40),
            (2.5, 1),
            (1.0, 9),
            (0.5, 41),
        ];
        for &(t, s) in script {
            cal.push_at_stamped(t, s, s);
            heap.push_at_stamped(t, s, s);
        }
        loop {
            assert_eq!(
                cal.peek().map(|(t, s, e)| (t, s, *e)),
                heap.peek().map(|(t, s, e)| (t, s, *e))
            );
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn generation_invalidates() {
        let mut g = Generation::new();
        let stamp = g.current();
        assert!(g.is_current(stamp));
        g.invalidate();
        assert!(!g.is_current(stamp));
        assert!(g.is_current(g.current()));
    }
}
