//! Per-tier engine shard for the sharded parallel DES core.
//!
//! The sharded engine (`sim::engine`) splits the cluster into contiguous
//! server ranges — one shard per topology tier by default (see
//! [`crate::sim::topology::ShardPlan`]) — and gives each shard its own
//! sub-[`ClusterSim`], its own calendar [`EventQueue`], and its own flow
//! table. Because link *i* is server *i*'s co-located uplink, every event
//! a shard schedules lands back on the same shard: there are **no**
//! shard-to-shard event sends. All cross-shard interaction (scheduling
//! decisions, outcome feedback, fault accounting, health probes) flows
//! through the orchestrator at *merge barriers*.
//!
//! # Event taxonomy
//!
//! A shard's queue holds only physics events:
//!
//! | event          | classification                                        |
//! |----------------|-------------------------------------------------------|
//! | `FluctTick`    | always local                                           |
//! | `LinkDone`     | always local (stale drop, or reap → `ComputeArrive`)   |
//! | `ComputeArrive`| **boundary** iff the landing fails (crashed / departed |
//! |                | / bounded-queue drop) — the orchestrator must resolve  |
//! |                | the request; otherwise local (plain admit)             |
//! | `ServerDone`   | **boundary** iff generation-current (completions feed  |
//! |                | the scheduler); stale ones are local drops             |
//!
//! Local events execute inside `Grant` windows without synchronizing;
//! boundary events stop the shard and are executed one at a time by the
//! orchestrator's merge barrier (`ExecuteBoundary`), which re-creates the
//! sequential engine's advance + snapshot + feedback sequence exactly.
//!
//! # Conservative grant rule (active-feed lookahead sync)
//!
//! The orchestrator may let a shard process local events strictly below a
//! `limit` key only if no *other* shard (and no global event) can reveal a
//! barrier below that limit. Each shard therefore reports a conservative
//! lower bound on where its next barrier could appear:
//!
//! ```text
//! bound = min( earliest queued ComputeArrive key,   -- may classify as a drop
//!              earliest queued ServerDone key,       -- may be a completion
//!              head.time + min draining RTT )        -- only while some uplink
//!                                                    -- is draining an upload;
//!                                                    -- omitted entirely when
//!                                                    -- every local queue is dry
//! ```
//!
//! The third term covers `ComputeArrive`s that do not exist yet: a reap of
//! link *l* at time `t >= head.time` mints a CA at `t + rtt(l)`. Reaps can
//! only happen on links whose upload queue is non-empty, and uploads start
//! exclusively at merge barriers (`Dispatch` never interleaves a grant), so
//! the *draining set can only shrink inside a grant window*. That makes
//! `head.time + min RTT over currently-draining links` a sound bound — and
//! when **no** local uplink is draining, no future CA (and hence no future
//! current `ServerDone`, which requires admitting a CA) can appear at all,
//! so the term vanishes and the shard reports only its queued CA/SD minima
//! (possibly no bound). PR 8 instead applied the unconditional floor
//! `head.time + min RTT over all local links`
//! ([`crate::sim::topology::ShardPlan::lookahead_s`]); the per-class
//! refinement ([`crate::sim::topology::LookaheadClasses`], PR 9) widens
//! grant windows exactly when a shard's fastest links are idle — the
//! common case on mixed chunks whose 5 ms edge links are dry while a 20–80
//! ms hub/cloud upload drains. Flap-to-zero links stay counted as draining
//! (no reap can fire, so the bound is merely conservative, never unsafe).
//!
//! New `ServerDone`s can only appear by admitting a queued or covered
//! `ComputeArrive`, so they are always later than the `ComputeArrive`
//! minimum already in the bound. A shard's grant limit is the minimum over
//! the *other* shards' bounds (its own pending events never gate itself —
//! this self-exclusion keeps the globally-earliest shard runnable and the
//! protocol deadlock-free), the global queue head, and the horizon.
//! Processing below such a limit can never create a barrier inside a
//! window another shard was granted, which is the bit-identity argument:
//! every advance/feedback interleaving the sequential engine performs at
//! barriers is replayed at the same simulated instants in the same order.
//!
//! # Deterministic stamps
//!
//! Events carry explicit tie-break stamps (`EventQueue::push_at_stamped`)
//! of the form `(epoch << 32) | counter`. The orchestrator bumps `epoch`
//! at the start of every barrier; within an epoch the orchestrator's
//! pushes use counters `< 2^20` and shard `s` uses `((s + 1) << 24) | c`,
//! so same-float-time ties order as: construction pushes first (epoch 0),
//! then earlier-epoch pushes, then barrier-ordered orchestrator pushes,
//! then shard-local pushes in shard order — mirroring the sequential
//! engine's monotone push counter on every cross-queue comparison that can
//! affect merged state. The residual (same-float-time *local* events on
//! different shards) acts on disjoint shard state and commutes;
//! `tests/sharded_identity.rs` pins the end-to-end identity at every
//! shard count.
//!
//! # Fluctuation side-values
//!
//! Shards own no RNG. The orchestrator replays the sequential engine's
//! single fluctuation stream (drawn in sequential tick-pop order from the
//! same raw-seeded generator) and ships each tick's multiplier ahead of
//! its grant; a shard consumes them per link in FIFO order, which is
//! unambiguous because one link's ticks are strictly time-increasing.

use std::collections::BinaryHeap;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, SyncSender};

use super::cluster::{fill_server_view, ClusterConfig, ClusterSim};
use super::faults::FaultAction;
use super::ps::PsJob;
use super::time::{EventQueue, SimTime};
use super::topology::LookaheadClasses;
use crate::scheduler::ServerView;
use crate::workload::service::ServiceRequest;

/// Orchestrator per-epoch stamp counters stay below this; shard counters
/// start at `(shard + 1) << 24`, so barrier-ordered pushes win same-time
/// ties within an epoch.
pub(crate) const ORCH_STAMP_LIMIT: u64 = 1 << 20;
const SHARD_STAMP_SHIFT: u64 = 24;
const EPOCH_SHIFT: u64 = 32;

/// Compose an orchestrator-side stamp: `(epoch << 32) | k`, `k < 2^20`.
pub(crate) fn orch_stamp(epoch: u64, k: u64) -> u64 {
    debug_assert!(k < ORCH_STAMP_LIMIT, "orchestrator stamp counter overflow");
    (epoch << EPOCH_SHIFT) | k
}

fn shard_stamp(epoch: u64, shard: usize, c: u64) -> u64 {
    debug_assert!(c < 1 << SHARD_STAMP_SHIFT, "shard stamp counter overflow");
    debug_assert!(shard < 255, "stamp scheme supports at most 254 shards");
    (epoch << EPOCH_SHIFT) | ((shard as u64 + 1) << SHARD_STAMP_SHIFT) | c
}

/// Total event-order key: `(time, stamp)` with the same ordering the
/// event queues use internally. Times are finite (the queues assert on
/// push), so `total_cmp` agrees with numeric order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct Key(pub SimTime, pub u64);

impl Eq for Key {}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Shard-local physics events. All indices are shard-local
/// (`global - range.start`).
#[derive(Debug, Clone, Copy)]
enum LocalEv {
    /// Earliest upload completion on link (generation-stamped).
    LinkDone { link: usize, gen: u64 },
    /// Upload finished + RTT elapsed: flow `slot` reaches the server.
    ComputeArrive { slot: usize, server: usize },
    /// Earliest batch completion on server (generation-stamped).
    ServerDone { server: usize, gen: u64 },
    /// Apply a pre-drawn bandwidth fluctuation multiplier.
    FluctTick { link: usize },
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum FlowPhase {
    Uploading,
    Computing,
}

/// One dispatched request resident on this shard. Slots are recycled via
/// a free list; the slot index doubles as the PS-queue job id (both
/// service models order completions by admission, never by id, so local
/// ids are safe).
#[derive(Debug, Clone)]
struct FlowSlot {
    live: bool,
    /// Global dense service index (the orchestrator's request table).
    svc: u64,
    /// Local server the flow was dispatched toward.
    server: usize,
    req: ServiceRequest,
    phase: FlowPhase,
    dispatched_at: SimTime,
    upload_done_at: SimTime,
    compute_started_at: SimTime,
    first_token_at: SimTime,
    tx_energy_j: f64,
}

/// Reschedule guard state, one per local link / server — a field-for-field
/// copy of the sequential engine's private cache (`sim::engine` keeps its
/// own so the sequential path stays untouched).
#[derive(Debug, Clone, Copy, Default)]
struct SchedCache {
    live: bool,
    fw: f64,
    rate: f64,
    at: SimTime,
}

/// Per-server fault depth, mirroring the sequential engine's bookkeeping.
#[derive(Debug, Clone, Copy)]
struct ServerFault {
    down: u32,
    crash: u32,
    degrade: u32,
    degrade_factor: f64,
}

impl Default for ServerFault {
    fn default() -> Self {
        ServerFault {
            down: 0,
            crash: 0,
            degrade: 0,
            degrade_factor: 1.0,
        }
    }
}

/// Everything the orchestrator needs to finish a completed request — the
/// inputs of the sequential engine's `complete()` outcome literal.
#[derive(Debug, Clone, Copy)]
pub(crate) struct CompletionRec {
    pub svc: u64,
    pub dispatched_at: SimTime,
    pub upload_done_at: SimTime,
    pub compute_started_at: SimTime,
    pub first_token_at: SimTime,
    pub tx_energy_j: f64,
    pub infer_energy_j: f64,
}

/// Everything the orchestrator needs to fail (or requeue) a request whose
/// upload was already paid for — the inputs of the sequential `fail()`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct FailRec {
    pub svc: u64,
    pub dispatched_at: SimTime,
    pub upload_done_at: SimTime,
    pub tx_energy_j: f64,
}

/// Why a boundary `ComputeArrive` could not be admitted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum LandKind {
    /// Hard-crashed server: the crash policy decides fail vs requeue.
    Crashed,
    /// Departed (not accepting) server: counted as failed-in-flight.
    Departed,
    /// Bounded queue full: a plain admission-shed failure.
    Dropped,
}

/// Result of executing one boundary event at the merge barrier.
#[derive(Debug)]
pub(crate) enum BoundaryOut {
    /// The event resolved locally after all (stale pop, or a fault window
    /// cleared between classification and execution): nothing to merge.
    None,
    /// A `ServerDone` reap: completions in reap order on local `server`.
    Completions { server: usize, recs: Vec<CompletionRec> },
    /// A failed `ComputeArrive` landing on local `server`.
    Landed {
        server: usize,
        kind: LandKind,
        rec: FailRec,
    },
}

/// Crash/recovery side-channel from `ApplyFault`.
#[derive(Debug, Default)]
pub(crate) struct FaultOut {
    /// The action put the server under its first covering down window.
    pub newly_down: bool,
    /// The action lifted the server's last covering down window.
    pub recovered: bool,
    /// Hard-crash casualties in ascending global-svc order (the
    /// sequential victim-scan order). The flows are already torn down
    /// locally; the orchestrator applies the crash policy.
    pub victims: Vec<FailRec>,
}

/// Queue/boundary status a shard reports after every queue-changing
/// command; the orchestrator's settle loop runs on these.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ShardStatus {
    /// Head event key + boundary classification (`None`: queue empty).
    pub head: Option<(Key, bool)>,
    /// Conservative lower bound on this shard's next *revealable* barrier
    /// (`None` = never). See the module docs' grant rule.
    pub bound: Option<Key>,
    /// Local queue clock (time of the last local pop) — feeds the run-end
    /// clock when every queue drains.
    pub now: SimTime,
    /// Local event-queue accounting for the merged report.
    pub processed: u64,
    pub stale: u64,
    pub peak: usize,
}

/// Per-server / per-link accounting returned once at `Finish`, in local
/// index order, so the orchestrator can fold energy in global server
/// order (float-sum order is part of the bit-identity contract).
#[derive(Debug)]
pub(crate) struct ShardFinish {
    pub infer_j: Vec<f64>,
    pub idle_j: Vec<f64>,
    pub bytes_moved: Vec<f64>,
    /// Tokens fully served on this shard (integer, order-free sum).
    pub tokens: u64,
    /// Flows still resident at run end: `(svc, first_token_at,
    /// tx_energy_j)` — feeds the horizon-stranded outcome pass.
    pub live_flows: Vec<(u64, SimTime, f64)>,
    /// Per-server prefix-cache counters (PR 10), local index order, so
    /// the orchestrator folds them in global server order — the same
    /// fold the sequential report tail performs.
    pub cache: Vec<crate::sim::prefix::CacheCounters>,
}

/// Orchestrator → shard commands. Index arguments are shard-local; `now`
/// is the barrier instant; `epoch` the barrier epoch for stamping.
#[derive(Debug)]
pub(crate) enum Cmd {
    /// Process local events with key strictly below `limit`, stopping at
    /// boundaries. `fluct` ships newly pre-drawn `(local link,
    /// multiplier)` values, appended to per-link FIFOs before processing.
    Grant {
        limit: Key,
        epoch: u64,
        fluct: Vec<(u32, f64)>,
    },
    /// Pop and execute the boundary event at the queue head.
    ExecuteBoundary { now: SimTime, epoch: u64 },
    /// Mirror of the sequential `ClusterSim::advance_all` call sites.
    AdvanceTo { now: SimTime },
    /// Fill per-server scheduler views + admissibility flags for the
    /// global snapshot (buffers are recycled round-trip).
    FillView {
        req: ServiceRequest,
        views: Vec<ServerView>,
        admissible: Vec<bool>,
    },
    /// Start an upload: the scheduler assigned `svc` to local `server`.
    Dispatch {
        svc: u64,
        req: ServiceRequest,
        server: usize,
        now: SimTime,
        epoch: u64,
    },
    /// Replay one fault-plan action (indices already localized).
    ApplyFault {
        action: FaultAction,
        now: SimTime,
        epoch: u64,
    },
    /// Snapshot ground-truth health (`accepting ? rate_mult : 0`) into
    /// `buf` in local server order.
    ProbeHealth { buf: Vec<f64> },
    /// Install the lagged monitor's published values for this shard's
    /// servers (local order); no-op without a monitor.
    PublishObserved { observed: Vec<f64> },
    /// Final accounting; the worker replies and exits.
    Finish { now: SimTime },
}

/// Shard → orchestrator replies, 1:1 with [`Cmd`].
#[derive(Debug)]
pub(crate) enum Reply {
    Granted {
        status: ShardStatus,
        fluct: Vec<(u32, f64)>,
    },
    Boundary {
        out: BoundaryOut,
        status: ShardStatus,
    },
    Advanced,
    View {
        views: Vec<ServerView>,
        admissible: Vec<bool>,
        n_admissible: usize,
    },
    Dispatched {
        status: ShardStatus,
    },
    Fault {
        out: FaultOut,
        status: ShardStatus,
    },
    Health {
        buf: Vec<f64>,
    },
    Published {
        observed: Vec<f64>,
    },
    Finished(Box<ShardFinish>),
}

/// One engine shard: a sub-cluster serving a contiguous global server
/// range, its calendar queue, and its resident flows.
pub(crate) struct ShardSim {
    shard: usize,
    cluster: ClusterSim,
    events: EventQueue<LocalEv>,
    flows: Vec<FlowSlot>,
    free: Vec<usize>,
    link_sched: Vec<SchedCache>,
    server_sched: Vec<SchedCache>,
    fault: Vec<ServerFault>,
    link_flap: Vec<u32>,
    /// Pre-drawn fluctuation multipliers per local link, FIFO.
    fluct_pending: Vec<VecDeque<f64>>,
    /// Lagged health values for local servers (`Some` iff a monitor is
    /// configured; initialized to 1.0 like `HealthMonitor`).
    observed: Option<Vec<f64>>,
    reap_buf: Vec<PsJob>,
    /// Keys of queued `ComputeArrive` events (min-heap): every one is a
    /// potential boundary until classified at the head.
    pending_ca: BinaryHeap<std::cmp::Reverse<Key>>,
    /// Keys of queued `ServerDone` events, stale or not (conservative).
    pending_sd: BinaryHeap<std::cmp::Reverse<Key>>,
    /// Inbound-RTT class decomposition: the shard's lookahead table.
    la: LookaheadClasses,
    /// Per RTT class, how many local links currently drain an upload.
    /// Indexed by `la` class (ascending RTT); maintained at dispatch and
    /// reap so `status()` can bound by the smallest *active* feed.
    draining: Vec<u32>,
    /// Jobs resident in each local link's upload queue.
    link_jobs: Vec<u32>,
    churn_guard: bool,
    epoch: u64,
    stamp_c: u64,
}

impl ShardSim {
    /// Build a shard over `sub` (the global config sliced to this shard's
    /// server range, outages stripped — outage and fault events replay
    /// through the orchestrator's global queue). `init_ticks` seeds
    /// construction-epoch fluctuation ticks as `(time, stamp, local
    /// link)` stamped in global construction order.
    pub(crate) fn new(
        sub: &ClusterConfig,
        shard: usize,
        la: LookaheadClasses,
        init_ticks: &[(SimTime, u64, usize)],
        monitored: bool,
    ) -> Self {
        let n = sub.servers.len();
        let n_links = sub.links.len();
        debug_assert_eq!(la.link_class.len(), n_links, "one RTT class per local link");
        let n_classes = la.n_classes();
        let mut events = EventQueue::new();
        for &(at, stamp, link) in init_ticks {
            events.push_at_stamped(at, stamp, LocalEv::FluctTick { link });
        }
        ShardSim {
            shard,
            cluster: ClusterSim::new(sub),
            events,
            flows: Vec::new(),
            free: Vec::new(),
            link_sched: vec![SchedCache::default(); n],
            server_sched: vec![SchedCache::default(); n],
            fault: vec![ServerFault::default(); n],
            link_flap: vec![0; n_links],
            fluct_pending: vec![VecDeque::new(); n_links],
            observed: monitored.then(|| vec![1.0; n]),
            reap_buf: Vec::new(),
            pending_ca: BinaryHeap::new(),
            pending_sd: BinaryHeap::new(),
            la,
            draining: vec![0; n_classes],
            link_jobs: vec![0; n_links],
            churn_guard: sub.churn_guard,
            epoch: 0,
            stamp_c: 0,
        }
    }

    fn set_epoch(&mut self, epoch: u64) {
        if epoch != self.epoch {
            debug_assert!(epoch > self.epoch, "barrier epochs are monotone");
            self.epoch = epoch;
            self.stamp_c = 0;
        }
    }

    /// Would executing `ev` require the merge barrier? (See the module
    /// docs' classification table.)
    fn is_boundary(&self, ev: LocalEv) -> bool {
        match ev {
            LocalEv::LinkDone { .. } | LocalEv::FluctTick { .. } => false,
            LocalEv::ServerDone { server, gen } => self.cluster.servers[server].gen.is_current(gen),
            LocalEv::ComputeArrive { slot: _, server } => {
                self.fault[server].crash > 0
                    || !self.cluster.accepting[server]
                    || self.cluster.servers[server].would_drop()
            }
        }
    }

    pub(crate) fn status(&self) -> ShardStatus {
        let head = self
            .events
            .peek()
            .map(|(t, stamp, &ev)| (Key(t, stamp), self.is_boundary(ev)));
        let mut bound = match (self.pending_ca.peek(), self.pending_sd.peek()) {
            (Some(a), Some(b)) => Some(a.0.min(b.0)),
            (Some(a), None) => Some(a.0),
            (None, Some(b)) => Some(b.0),
            (None, None) => None,
        };
        if let Some((hk, boundary)) = head {
            if !boundary {
                // Only reaps of *currently draining* uplinks can mint new
                // ComputeArrives during a grant (uploads start at barriers
                // only, so the draining set cannot grow mid-window): bound
                // by the smallest draining RTT class, or not at all when
                // every local upload queue is dry — see the module docs'
                // grant-rule derivation.
                if let Some(c) = self.draining.iter().position(|&n| n > 0) {
                    let ahead = Key(hk.0 + self.la.rtts[c], 0);
                    bound = Some(match bound {
                        Some(b) if b < ahead => b,
                        _ => ahead,
                    });
                }
            }
        }
        ShardStatus {
            head,
            bound,
            now: self.events.now(),
            processed: self.events.processed(),
            stale: self.events.stale(),
            peak: self.events.peak_len(),
        }
    }

    /// Drop a popped CA/SD key from the conservative-bound heaps. Pops
    /// happen in key order, so the popped key is always the heap minimum.
    fn note_popped(&mut self, ev: LocalEv, key: Key) {
        match ev {
            LocalEv::ComputeArrive { .. } => {
                let top = self.pending_ca.pop();
                debug_assert_eq!(top, Some(std::cmp::Reverse(key)));
            }
            LocalEv::ServerDone { .. } => {
                let top = self.pending_sd.pop();
                debug_assert_eq!(top, Some(std::cmp::Reverse(key)));
            }
            _ => {}
        }
    }

    /// Process local events with key strictly below `limit`, stopping at
    /// the first boundary.
    fn run_granted(&mut self, limit: Key, epoch: u64, fluct: &mut Vec<(u32, f64)>) -> ShardStatus {
        self.set_epoch(epoch);
        for (li, v) in fluct.drain(..) {
            self.fluct_pending[li as usize].push_back(v);
        }
        // lint: no-alloc per-shard hot loop: grant windows execute O(events) against recycled buffers
        loop {
            let Some((t, stamp, &ev)) = self.events.peek() else {
                break;
            };
            let key = Key(t, stamp);
            if !(key < limit) || self.is_boundary(ev) {
                break;
            }
            let popped = self.events.pop();
            debug_assert!(popped.is_some());
            self.note_popped(ev, key);
            self.cluster.now = t;
            self.exec_local(t, ev);
        }
        // lint: end-no-alloc
        self.status()
    }

    /// Execute one *local* event — a transcription of the sequential
    /// engine's `LinkDone` / `FluctTick` arms (plus the stale half of
    /// `ServerDone` and the admit path of `ComputeArrive`), against
    /// shard-local state.
    fn exec_local(&mut self, now: SimTime, ev: LocalEv) {
        match ev {
            LocalEv::LinkDone { link, gen } => {
                if !self.cluster.links[link].gen.is_current(gen) {
                    self.events.note_stale();
                    return;
                }
                self.link_sched[link].live = false;
                self.cluster.links[link].advance_to(now);
                let rate = self.cluster.links[link].per_flow_rate();
                let mut done = std::mem::take(&mut self.reap_buf);
                self.cluster.links[link].queue.reap_into(now, rate, &mut done);
                if !done.is_empty() {
                    self.link_jobs[link] -= done.len() as u32;
                    if self.link_jobs[link] == 0 {
                        self.draining[self.la.link_class[link]] -= 1;
                    }
                }
                let rtt = self.cluster.links[link].spec.rtt_s;
                for job in &done {
                    let slot = job.id as usize;
                    self.flows[slot].upload_done_at = now + rtt;
                    let stamp = shard_stamp(self.epoch, self.shard, self.stamp_c);
                    self.stamp_c += 1;
                    self.pending_ca.push(std::cmp::Reverse(Key(now + rtt, stamp)));
                    self.events
                        .push_at_stamped(now + rtt, stamp, LocalEv::ComputeArrive { slot, server: link });
                }
                self.reap_buf = done;
                self.reschedule_link(now, link);
            }
            LocalEv::ComputeArrive { slot, server } => {
                // Classified local: the landing admits (not crashed, not
                // departed, queue has room).
                self.cluster.land_in_flight(server, &self.flows[slot].req);
                let srv = &mut self.cluster.servers[server];
                srv.advance_to(now);
                let ttft_s = srv.predict(&self.flows[slot].req, 0, 0.0).ttft_s;
                self.flows[slot].first_token_at = now + ttft_s;
                self.cluster.servers[server].admit(slot as u64, &self.flows[slot].req, now);
                self.cluster.refresh_admissibility(server);
                self.flows[slot].phase = FlowPhase::Computing;
                self.flows[slot].compute_started_at = now;
                self.reschedule_server(now, server);
            }
            LocalEv::ServerDone { server, gen } => {
                // Only stale `ServerDone`s classify local; current ones
                // are boundaries.
                debug_assert!(!self.cluster.servers[server].gen.is_current(gen));
                let _ = (server, gen);
                self.events.note_stale();
            }
            LocalEv::FluctTick { link } => {
                let l = &mut self.cluster.links[link];
                l.advance_to(now);
                // Pre-drawn by the orchestrator in sequential stream
                // order; flap windows still consume the value.
                debug_assert!(
                    !self.fluct_pending[link].is_empty(),
                    "fluct value underflow on link {link}: grant outran the drawn stream"
                );
                let m = self.fluct_pending[link].pop_front().unwrap_or(1.0);
                let l = &mut self.cluster.links[link];
                if self.link_flap[link] == 0 {
                    l.mult = m;
                }
                let period = l.spec.fluct_period;
                self.reschedule_link(now, link);
                let stamp = shard_stamp(self.epoch, self.shard, self.stamp_c);
                self.stamp_c += 1;
                self.events
                    .push_at_stamped(now + period, stamp, LocalEv::FluctTick { link });
            }
        }
    }

    /// Pop and execute the boundary event at the head.
    fn execute_boundary(&mut self, now: SimTime, epoch: u64) -> BoundaryOut {
        self.set_epoch(epoch);
        let Some((t, stamp, &ev)) = self.events.peek() else {
            debug_assert!(false, "ExecuteBoundary on an empty shard queue");
            return BoundaryOut::None;
        };
        debug_assert_eq!(t, now, "boundary executes at its own key time");
        let key = Key(t, stamp);
        let popped = self.events.pop();
        debug_assert!(popped.is_some());
        self.note_popped(ev, key);
        self.cluster.now = now;
        match ev {
            LocalEv::ServerDone { server, gen } => {
                if !self.cluster.servers[server].gen.is_current(gen) {
                    self.events.note_stale();
                    return BoundaryOut::None;
                }
                self.server_sched[server].live = false;
                self.cluster.servers[server].advance_to(now);
                let mut done = std::mem::take(&mut self.reap_buf);
                self.cluster.servers[server].reap_into(now, &mut done);
                self.cluster.refresh_admissibility(server);
                let mut recs = Vec::with_capacity(done.len());
                for job in &done {
                    recs.push(self.complete_rec(job.id as usize, server, job.energy_j));
                }
                self.reap_buf = done;
                self.reschedule_server(now, server);
                BoundaryOut::Completions { server, recs }
            }
            LocalEv::ComputeArrive { slot, server } => {
                self.cluster.land_in_flight(server, &self.flows[slot].req);
                if self.fault[server].crash > 0 || !self.cluster.accepting[server] {
                    self.cluster.servers[server].advance_to(now);
                    let kind = if self.fault[server].crash > 0 {
                        LandKind::Crashed
                    } else {
                        LandKind::Departed
                    };
                    let rec = self.fail_rec(slot);
                    return BoundaryOut::Landed { server, kind, rec };
                }
                let srv = &mut self.cluster.servers[server];
                srv.advance_to(now);
                if srv.would_drop() {
                    let rec = self.fail_rec(slot);
                    return BoundaryOut::Landed {
                        server,
                        kind: LandKind::Dropped,
                        rec,
                    };
                }
                // Classified boundary at peek but admitting now: cannot
                // happen without an interleaved state change (the
                // orchestrator re-reads status after every one), kept as a
                // defensive local admit.
                let ttft_s = srv.predict(&self.flows[slot].req, 0, 0.0).ttft_s;
                self.flows[slot].first_token_at = now + ttft_s;
                self.cluster.servers[server].admit(slot as u64, &self.flows[slot].req, now);
                self.cluster.refresh_admissibility(server);
                self.flows[slot].phase = FlowPhase::Computing;
                self.flows[slot].compute_started_at = now;
                self.reschedule_server(now, server);
                BoundaryOut::None
            }
            LocalEv::LinkDone { .. } | LocalEv::FluctTick { .. } => {
                debug_assert!(false, "local event executed as boundary");
                self.exec_local(now, ev);
                BoundaryOut::None
            }
        }
    }

    /// Start an upload — the sequential `dispatch()` against a fresh
    /// flow slot.
    fn dispatch(&mut self, now: SimTime, epoch: u64, svc: u64, req: ServiceRequest, server: usize) {
        self.set_epoch(epoch);
        self.cluster.now = now;
        let slot = self.alloc_flow(svc, server, req);
        self.cluster.dispatch_in_flight(server, &self.flows[slot].req);
        // Same payload rule as the sequential `dispatch()`: a stamped KV
        // transfer (the orchestrator decided before sending `Dispatch`)
        // rides the upload and costs tx energy.
        let payload = self.flows[slot].req.payload_bytes
            + match self.flows[slot].req.session {
                Some(s) => crate::workload::service::SessionRef::kv_bytes(s.xfer_tokens),
                None => 0,
            };
        let link = &mut self.cluster.links[server];
        link.advance_to(now);
        link.queue.push(slot as u64, payload as f64, now);
        let tx_energy_j = link.spec.tx_energy(payload);
        if self.link_jobs[server] == 0 {
            self.draining[self.la.link_class[server]] += 1;
        }
        self.link_jobs[server] += 1;
        let fl = &mut self.flows[slot];
        fl.dispatched_at = now;
        fl.tx_energy_j = tx_energy_j;
        self.reschedule_link(now, server);
    }

    /// Replay one localized fault action — the sequential `apply_fault`
    /// arms minus the orchestrator-side incident/fleet accounting, which
    /// is reconstructed from the returned [`FaultOut`].
    fn apply_fault(&mut self, now: SimTime, epoch: u64, action: FaultAction) -> FaultOut {
        self.set_epoch(epoch);
        self.cluster.now = now;
        let mut out = FaultOut::default();
        match action {
            FaultAction::Down { server, crash } => {
                self.fault_down(now, server, crash, &mut out);
            }
            FaultAction::Up { server, crash } => {
                self.fault_up(now, server, crash, &mut out);
            }
            FaultAction::DegradeStart { server, factor } => {
                self.cluster.servers[server].advance_to(now);
                let f = &mut self.fault[server];
                f.degrade += 1;
                f.degrade_factor *= factor;
                self.apply_rate(server);
                self.reschedule_server(now, server);
            }
            FaultAction::DegradeEnd { server, factor } => {
                self.cluster.servers[server].advance_to(now);
                let f = &mut self.fault[server];
                f.degrade -= 1;
                if f.degrade == 0 {
                    // Snap back to exactly 1.0 (no float residue).
                    f.degrade_factor = 1.0;
                } else {
                    f.degrade_factor /= factor;
                }
                self.apply_rate(server);
                self.reschedule_server(now, server);
            }
            FaultAction::FlapStart { link, factor } => {
                self.link_flap[link] += 1;
                let l = &mut self.cluster.links[link];
                l.advance_to(now);
                l.mult = factor;
                self.reschedule_link(now, link);
            }
            FaultAction::FlapEnd { link } => {
                self.link_flap[link] -= 1;
                if self.link_flap[link] == 0 {
                    let l = &mut self.cluster.links[link];
                    l.advance_to(now);
                    l.mult = 1.0;
                    self.reschedule_link(now, link);
                }
            }
            FaultAction::Leave { server } => {
                self.cluster.accepting[server] = false;
                self.cluster.refresh_admissibility(server);
            }
            FaultAction::Join { server } => {
                self.cluster.accepting[server] = true;
                self.cluster.refresh_admissibility(server);
            }
        }
        out
    }

    fn apply_rate(&mut self, server: usize) {
        let f = self.fault[server];
        self.cluster.servers[server].rate_mult = if f.down > 0 { 0.0 } else { f.degrade_factor };
    }

    fn fault_down(&mut self, now: SimTime, server: usize, crash: bool, out: &mut FaultOut) {
        self.cluster.servers[server].advance_to(now);
        self.fault[server].down += 1;
        if crash {
            self.fault[server].crash += 1;
        }
        self.apply_rate(server);
        self.reschedule_server(now, server);
        if crash {
            self.crash_in_flight(now, server, out);
        }
        if self.fault[server].down == 1 {
            out.newly_down = true;
        }
    }

    fn fault_up(&mut self, now: SimTime, server: usize, crash: bool, out: &mut FaultOut) {
        self.cluster.servers[server].advance_to(now);
        let f = &mut self.fault[server];
        debug_assert!(f.down > 0, "Up without covering Down on local server {server}");
        f.down = f.down.saturating_sub(1);
        if crash {
            f.crash = f.crash.saturating_sub(1);
        }
        self.apply_rate(server);
        self.reschedule_server(now, server);
        if self.fault[server].down == 0 {
            out.recovered = true;
        }
    }

    /// Tear down every flow computing on a hard-crashed server, in
    /// ascending global-svc order (the sequential victim-scan order: svc
    /// indices are assigned in arrival order).
    fn crash_in_flight(&mut self, now: SimTime, server: usize, out: &mut FaultOut) {
        let mut victims: Vec<usize> = (0..self.flows.len())
            .filter(|&s| {
                self.flows[s].live
                    && self.flows[s].phase == FlowPhase::Computing
                    && self.flows[s].server == server
            })
            .collect();
        victims.sort_unstable_by_key(|&s| self.flows[s].svc);
        self.cluster.servers[server].crash_reset(now);
        self.reschedule_server(now, server);
        self.cluster.refresh_admissibility(server);
        for slot in victims {
            let rec = self.fail_rec(slot);
            out.victims.push(rec);
        }
    }

    /// Fill scheduler views + admissibility flags for the global
    /// snapshot; returns the shard's admissible-server count.
    fn fill_view(&self, req: &ServiceRequest, views: &mut Vec<ServerView>, adm: &mut Vec<bool>) -> usize {
        views.clear();
        adm.clear();
        for i in 0..self.cluster.servers.len() {
            let observed = self.observed.as_ref().map(|o| o[i]);
            views.push(fill_server_view(
                &self.cluster.servers[i],
                &self.cluster.links[i],
                &self.cluster.in_flight[i],
                observed,
                req,
            ));
        }
        adm.extend_from_slice(self.cluster.admissible_flags());
        self.cluster.n_admissible()
    }

    /// Ground-truth health snapshot in local server order (the sequential
    /// `health_probe` scrape).
    fn probe_health(&self, buf: &mut Vec<f64>) {
        buf.clear();
        for (i, srv) in self.cluster.servers.iter().enumerate() {
            buf.push(if self.cluster.accepting[i] { srv.rate_mult } else { 0.0 });
        }
    }

    fn publish_observed(&mut self, observed: &[f64]) {
        if let Some(o) = self.observed.as_mut() {
            o.copy_from_slice(observed);
        }
    }

    fn finish(&mut self, now: SimTime) -> ShardFinish {
        self.cluster.advance_all(now);
        let mut fin = ShardFinish {
            infer_j: Vec::with_capacity(self.cluster.servers.len()),
            idle_j: Vec::with_capacity(self.cluster.servers.len()),
            bytes_moved: Vec::with_capacity(self.cluster.links.len()),
            tokens: self.cluster.tokens_served(),
            live_flows: Vec::new(),
            cache: Vec::with_capacity(self.cluster.servers.len()),
        };
        for s in &self.cluster.servers {
            fin.infer_j.push(s.energy_infer_j);
            fin.idle_j.push(s.energy_idle_j);
            fin.cache.push(s.cache);
        }
        for l in &self.cluster.links {
            fin.bytes_moved.push(l.bytes_moved);
        }
        for fl in &self.flows {
            if fl.live {
                fin.live_flows.push((fl.svc, fl.first_token_at, fl.tx_energy_j));
            }
        }
        fin
    }

    fn alloc_flow(&mut self, svc: u64, server: usize, req: ServiceRequest) -> usize {
        let fl = FlowSlot {
            live: true,
            svc,
            server,
            req,
            phase: FlowPhase::Uploading,
            dispatched_at: 0.0,
            upload_done_at: 0.0,
            compute_started_at: 0.0,
            first_token_at: f64::INFINITY,
            tx_energy_j: 0.0,
        };
        match self.free.pop() {
            Some(slot) => {
                self.flows[slot] = fl;
                slot
            }
            None => {
                self.flows.push(fl);
                self.flows.len() - 1
            }
        }
    }

    /// Resolve a flow into its fail/requeue record and recycle the slot.
    fn fail_rec(&mut self, slot: usize) -> FailRec {
        let fl = &mut self.flows[slot];
        fl.live = false;
        let rec = FailRec {
            svc: fl.svc,
            dispatched_at: fl.dispatched_at,
            upload_done_at: fl.upload_done_at,
            tx_energy_j: fl.tx_energy_j,
        };
        self.free.push(slot);
        rec
    }

    fn complete_rec(&mut self, slot: usize, server: usize, infer_energy_j: f64) -> CompletionRec {
        let fl = &mut self.flows[slot];
        fl.live = false;
        let tokens = fl.req.total_tokens();
        let rec = CompletionRec {
            svc: fl.svc,
            dispatched_at: fl.dispatched_at,
            upload_done_at: fl.upload_done_at,
            compute_started_at: fl.compute_started_at,
            first_token_at: fl.first_token_at,
            tx_energy_j: fl.tx_energy_j,
            infer_energy_j,
        };
        self.cluster.servers[server].tokens_served += tokens;
        self.free.push(slot);
        rec
    }

    /// Transcription of the sequential `reschedule_link`, with the
    /// barrier clock passed explicitly (the local queue clock lags
    /// barrier-driven touches).
    fn reschedule_link(&mut self, now: SimTime, li: usize) {
        let link = &mut self.cluster.links[li];
        let rate = link.per_flow_rate();
        let cache = &mut self.link_sched[li];
        match link.queue.peek_finish_work() {
            Some(fw) if rate > 0.0 => {
                if cache.live && cache.fw == fw && cache.rate == rate {
                    if self.churn_guard {
                        return;
                    }
                    let gen = link.gen.invalidate();
                    let stamp = shard_stamp(self.epoch, self.shard, self.stamp_c);
                    self.stamp_c += 1;
                    self.events
                        .push_at_stamped(cache.at, stamp, LocalEv::LinkDone { link: li, gen });
                    return;
                }
                let gen = link.gen.invalidate();
                let dt = (fw - link.queue.attained()).max(0.0) / rate;
                let at = now + dt;
                let stamp = shard_stamp(self.epoch, self.shard, self.stamp_c);
                self.stamp_c += 1;
                self.events
                    .push_at_stamped(at, stamp, LocalEv::LinkDone { link: li, gen });
                *cache = SchedCache {
                    live: true,
                    fw,
                    rate,
                    at,
                };
            }
            _ => {
                link.gen.invalidate();
                cache.live = false;
            }
        }
    }

    /// Transcription of the sequential `reschedule_server` (same explicit
    /// clock); every completion it schedules is tracked as a potential
    /// boundary in `pending_sd`.
    fn reschedule_server(&mut self, now: SimTime, si: usize) {
        let srv = &mut self.cluster.servers[si];
        let cache = &mut self.server_sched[si];
        match srv.completion_key() {
            Some((fw, rate)) => {
                if cache.live && cache.fw == fw && cache.rate == rate {
                    if self.churn_guard {
                        return;
                    }
                    let gen = srv.gen.invalidate();
                    let stamp = shard_stamp(self.epoch, self.shard, self.stamp_c);
                    self.stamp_c += 1;
                    self.pending_sd.push(std::cmp::Reverse(Key(cache.at, stamp)));
                    self.events
                        .push_at_stamped(cache.at, stamp, LocalEv::ServerDone { server: si, gen });
                    return;
                }
                let gen = srv.gen.invalidate();
                let Some(dt) = srv.next_completion_in() else {
                    log::error!("local server {si}: completion key without completion estimate");
                    cache.live = false;
                    return;
                };
                let at = now + dt;
                let stamp = shard_stamp(self.epoch, self.shard, self.stamp_c);
                self.stamp_c += 1;
                self.pending_sd.push(std::cmp::Reverse(Key(at, stamp)));
                self.events
                    .push_at_stamped(at, stamp, LocalEv::ServerDone { server: si, gen });
                *cache = SchedCache {
                    live: true,
                    fw,
                    rate,
                    at,
                };
            }
            None => {
                srv.gen.invalidate();
                cache.live = false;
            }
        }
    }
}

/// Shard worker: strict request/reply over bounded channels until
/// `Finish` (or channel teardown on an orchestrator panic).
pub(crate) fn worker(mut shard: ShardSim, rx: Receiver<Cmd>, tx: SyncSender<Reply>) {
    while let Ok(cmd) = rx.recv() {
        let reply = match cmd {
            Cmd::Grant {
                limit,
                epoch,
                mut fluct,
            } => {
                let status = shard.run_granted(limit, epoch, &mut fluct);
                Reply::Granted { status, fluct }
            }
            Cmd::ExecuteBoundary { now, epoch } => {
                let out = shard.execute_boundary(now, epoch);
                Reply::Boundary {
                    out,
                    status: shard.status(),
                }
            }
            Cmd::AdvanceTo { now } => {
                shard.cluster.advance_all(now);
                Reply::Advanced
            }
            Cmd::FillView {
                req,
                mut views,
                mut admissible,
            } => {
                let n_admissible = shard.fill_view(&req, &mut views, &mut admissible);
                Reply::View {
                    views,
                    admissible,
                    n_admissible,
                }
            }
            Cmd::Dispatch {
                svc,
                req,
                server,
                now,
                epoch,
            } => {
                shard.dispatch(now, epoch, svc, req, server);
                Reply::Dispatched {
                    status: shard.status(),
                }
            }
            Cmd::ApplyFault { action, now, epoch } => {
                let out = shard.apply_fault(now, epoch, action);
                Reply::Fault {
                    out,
                    status: shard.status(),
                }
            }
            Cmd::ProbeHealth { mut buf } => {
                shard.probe_health(&mut buf);
                Reply::Health { buf }
            }
            Cmd::PublishObserved { observed } => {
                shard.publish_observed(&observed);
                Reply::Published { observed }
            }
            Cmd::Finish { now } => {
                let fin = shard.finish(now);
                let _ = tx.send(Reply::Finished(Box::new(fin)));
                return;
            }
        };
        if tx.send(reply).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cluster::BandwidthMode;
    use crate::workload::service::{ServiceClass, SloSpec};

    fn sub_cfg() -> ClusterConfig {
        ClusterConfig::paper("llama2-7b", BandwidthMode::Stable)
    }

    fn req(id: u64) -> ServiceRequest {
        ServiceRequest {
            id,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: 100,
            output_tokens: 40,
            slo: SloSpec::completion_only(4.0),
            payload_bytes: 200_000,
            session: None,
        }
    }

    const NO_LIMIT: Key = Key(f64::INFINITY, u64::MAX);

    #[test]
    fn key_ordering_is_time_then_stamp() {
        assert!(Key(1.0, 5) < Key(1.0, 6));
        assert!(Key(1.0, 99) < Key(1.5, 0));
        assert!(Key(0.0, 0) < Key(0.0, 1));
        let mut h = BinaryHeap::new();
        h.push(std::cmp::Reverse(Key(2.0, 1)));
        h.push(std::cmp::Reverse(Key(1.0, 7)));
        h.push(std::cmp::Reverse(Key(1.0, 3)));
        assert_eq!(h.pop(), Some(std::cmp::Reverse(Key(1.0, 3))));
    }

    #[test]
    fn stamps_order_construction_then_barrier_then_shards() {
        // Within one epoch: orchestrator stamps < shard 0 < shard 1.
        let o = orch_stamp(3, 17);
        let s0 = shard_stamp(3, 0, 0);
        let s1 = shard_stamp(3, 1, 0);
        assert!(o < s0 && s0 < s1);
        // Any earlier-epoch stamp beats any later-epoch stamp.
        assert!(shard_stamp(3, 200, (1 << 24) - 1) < orch_stamp(4, 0));
        // Construction (epoch 0) beats everything at runtime.
        assert!(orch_stamp(0, 5) < shard_stamp(1, 0, 0));
    }

    #[test]
    fn dispatch_then_grant_reaches_boundary_completion() {
        let cfg = sub_cfg();
        let mut s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[], false);
        s.dispatch(0.0, 1, 7, req(7), 0);
        // Upload + landing are local; the completion is the boundary.
        let mut fl = Vec::new();
        let status = s.run_granted(NO_LIMIT, 1, &mut fl);
        let (key, boundary) = status.head.expect("a ServerDone must be scheduled");
        assert!(boundary, "a current-generation ServerDone is a boundary");
        assert!(key.0 > 0.0);
        match s.execute_boundary(key.0, 2) {
            BoundaryOut::Completions { server, recs } => {
                assert_eq!(server, 0);
                assert_eq!(recs.len(), 1);
                assert_eq!(recs[0].svc, 7);
                assert!(recs[0].tx_energy_j > 0.0);
                assert!(recs[0].first_token_at.is_finite());
            }
            other => panic!("expected a completion, got {other:?}"),
        }
        // Slot recycled, tokens accounted on the shard's server.
        assert_eq!(s.free.len(), 1);
        assert_eq!(s.cluster.tokens_served(), req(7).total_tokens());
    }

    #[test]
    fn bound_never_exceeds_pending_compute_arrive() {
        let cfg = sub_cfg();
        let mut s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[], false);
        s.dispatch(0.0, 1, 0, req(0), 0);
        // Run the upload until the ComputeArrive is queued.
        let mut fl = Vec::new();
        let mut status = s.run_granted(Key(0.0, u64::MAX), 1, &mut fl);
        while s.pending_ca.is_empty() {
            let (key, boundary) = status.head.expect("upload events pending");
            assert!(!boundary);
            status = s.run_granted(Key(key.0 + 1e-9, u64::MAX), 1, &mut fl);
        }
        let ca_min = s.pending_ca.peek().expect("just checked").0;
        let bound = status.bound.expect("pending CA implies a bound");
        assert!(bound <= ca_min, "bound {bound:?} must cover queued CA {ca_min:?}");
    }

    #[test]
    fn crashed_landing_classifies_as_boundary_and_fails() {
        let cfg = sub_cfg();
        let mut s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[], false);
        s.dispatch(0.0, 1, 3, req(3), 1);
        // Crash server 1 mid-upload (barrier-driven), then drain.
        let out = s.apply_fault(
            0.01,
            2,
            FaultAction::Down {
                server: 1,
                crash: true,
            },
        );
        assert!(out.newly_down);
        assert!(out.victims.is_empty(), "nothing was computing yet");
        let mut fl = Vec::new();
        let mut status = s.run_granted(NO_LIMIT, 2, &mut fl);
        let key = loop {
            match status.head {
                Some((k, true)) => break k,
                Some(_) | None => {
                    status = s.run_granted(NO_LIMIT, 2, &mut fl);
                }
            }
        };
        match s.execute_boundary(key.0, 3) {
            BoundaryOut::Landed { server, kind, rec } => {
                assert_eq!(server, 1);
                assert_eq!(kind, LandKind::Crashed);
                assert_eq!(rec.svc, 3);
                assert!(rec.tx_energy_j > 0.0);
            }
            other => panic!("expected a crashed landing, got {other:?}"),
        }
    }

    #[test]
    fn crash_tears_down_only_the_crashed_servers_flows() {
        let cfg = sub_cfg();
        let mut s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[], false);
        s.dispatch(0.0, 1, 0, req(0), 0);
        s.dispatch(0.0, 1, 1, req(1), 1);
        // Drain both uploads until both flows are computing (the next
        // head is then a boundary ServerDone).
        let mut fl = Vec::new();
        let mut guard = 0;
        loop {
            let status = s.run_granted(NO_LIMIT, 1, &mut fl);
            match status.head {
                Some((_, true)) => break,
                Some(_) => {}
                None => panic!("completions must be pending"),
            }
            guard += 1;
            assert!(guard < 100, "flows never reached the servers");
        }
        assert_eq!(
            s.flows.iter().filter(|f| f.live && f.phase == FlowPhase::Computing).count(),
            2
        );
        let out = s.apply_fault(
            1.0,
            2,
            FaultAction::Down {
                server: 0,
                crash: true,
            },
        );
        // Only svc 0 (computing on server 0) is a casualty.
        assert_eq!(out.victims.len(), 1);
        assert_eq!(out.victims[0].svc, 0);
        assert!(s.flows.iter().any(|f| f.live && f.svc == 1));
    }

    /// Active-feed lookahead: with every local upload queue dry and no
    /// queued CA/SD, a non-boundary head (a FluctTick) yields *no* bound
    /// at all — nothing this shard does can reveal a barrier.
    #[test]
    fn idle_shard_reports_no_bound() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let period = cfg.links[0].fluct_period;
        let s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[(period, 0, 0)], false);
        let status = s.status();
        let (_, boundary) = status.head.expect("the seeded tick is queued");
        assert!(!boundary, "FluctTick is always local");
        assert!(
            status.bound.is_none(),
            "no draining uplink and no pending CA/SD: bound must be None, got {:?}",
            status.bound
        );
    }

    /// The head+lookahead term reads the smallest *draining* RTT class,
    /// not the unconditional floor: a paper shard (5 ms edge links + 80 ms
    /// cloud link) with only the cloud uplink busy bounds at head + 80 ms.
    #[test]
    fn bound_uses_smallest_draining_rtt_class() {
        let cfg = sub_cfg();
        let la = LookaheadClasses::of(&cfg.links);
        assert_eq!(la.rtts, vec![0.005, 0.08]);
        let mut s = ShardSim::new(&cfg, 0, la, &[], false);
        // Cloud-only dispatch: the 5 ms edge class is dry.
        s.dispatch(0.0, 1, 0, req(0), 5);
        assert_eq!(s.draining, vec![0, 1]);
        let status = s.status();
        let (hk, boundary) = status.head.expect("the upload's LinkDone is queued");
        assert!(!boundary);
        let bound = status.bound.expect("a draining uplink implies a bound");
        assert!(
            (bound.0 - (hk.0 + 0.08)).abs() < 1e-12,
            "cloud-only drain must bound at head + 80 ms, got {} vs head {}",
            bound.0,
            hk.0
        );
        // An edge dispatch activates the 5 ms class and tightens it.
        s.dispatch(0.0, 1, 1, req(1), 0);
        assert_eq!(s.draining, vec![1, 1]);
        let status = s.status();
        let (hk, _) = status.head.expect("uploads queued");
        let bound = status.bound.expect("draining uplinks imply a bound");
        assert!((bound.0 - (hk.0 + 0.005)).abs() < 1e-12);
    }

    /// Reaps retire draining state: once both uploads reap and land, the
    /// queues are dry again and the counters return to zero.
    #[test]
    fn reaps_retire_draining_counters() {
        let cfg = sub_cfg();
        let mut s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[], false);
        s.dispatch(0.0, 1, 0, req(0), 0);
        s.dispatch(0.0, 1, 1, req(1), 5);
        assert_eq!(s.link_jobs[0], 1);
        assert_eq!(s.link_jobs[5], 1);
        let mut fl = Vec::new();
        let mut guard = 0;
        loop {
            let status = s.run_granted(NO_LIMIT, 1, &mut fl);
            match status.head {
                Some((_, true)) => break,
                Some(_) => {}
                None => panic!("completions must be pending"),
            }
            guard += 1;
            assert!(guard < 100, "flows never reached the servers");
        }
        assert!(s.draining.iter().all(|&n| n == 0), "{:?}", s.draining);
        assert!(s.link_jobs.iter().all(|&n| n == 0));
    }

    #[test]
    fn fluct_values_apply_in_fifo_order() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let period = cfg.links[0].fluct_period;
        let mut s = ShardSim::new(&cfg, 0, LookaheadClasses::of(&cfg.links), &[(period, 0, 0)], false);
        let mut fl = vec![(0u32, 0.9), (0u32, 1.1)];
        let status = s.run_granted(Key(period + period / 2.0, u64::MAX), 1, &mut fl);
        assert!(fl.is_empty(), "the grant drains the shipped values");
        assert_eq!(s.cluster.links[0].mult, 0.9, "first tick applies the first value");
        assert_eq!(s.fluct_pending[0].len(), 1, "second value waits for the next tick");
        // The tick re-armed itself.
        assert!(status.head.is_some());
    }
}
