//! Fault injection and lagged health observation: the chaos layer that
//! turns the static testbed into a dynamic fleet (ROADMAP item 4).
//!
//! A [`FaultPlan`] is a declarative description of everything that goes
//! wrong during a run: scripted [`FaultEvent`]s (crash at t = 120 s,
//! degrade to 40 % for a minute, flap a link, drain a server out of the
//! fleet) plus an optional generative MTTF/MTTR process that draws
//! per-server failure windows from a salted side-stream RNG. The engine
//! lowers the plan to a timeline of [`FaultAction`]s at construction time
//! ([`FaultPlan::materialize`]) and replays them as ordinary DES events,
//! so fault handling shares the clock, FIFO ordering, and determinism
//! guarantees of every other event — and never consumes a draw from the
//! engine's own RNG stream.
//!
//! The [`HealthMonitor`] sits between ground truth and the scheduler:
//! periodic probes snapshot each server's true service rate, but the
//! snapshot only becomes the *observed* health after a configurable lag.
//! `ServerView::observed_health` (and, when a monitor is installed, the
//! view's service-time predictions) are driven by the lagged signal, so a
//! scheduler can route to a just-crashed server and pay for it — exactly
//! the probe-staleness window a production registry/health/balancer stack
//! exhibits.
//!
//! Identity discipline: an empty plan materializes to nothing and installs
//! no monitor, leaving the engine bit-identical to the pre-fault code
//! path; [`FaultPlan::from_outages`] lowers the legacy scripted
//! `ClusterConfig::outages` list to the same per-outage adjacent
//! start/end push order the dedicated outage events used, so event
//! sequence numbers — and therefore every outcome bit — match
//! (`tests/faults_identity.rs` pins both).

use std::collections::VecDeque;

use super::cluster::Outage;
use super::time::SimTime;
use crate::util::rng::Rng;

/// Salt folded into the generative-fault RNG seed so fault schedules are
/// a side stream: changing the plan never perturbs arrival, fluctuation,
/// or SLO draws, and vice versa (same pattern as the workload generator's
/// `SLO_STREAM_SALT`).
pub const FAULT_STREAM_SALT: u64 = 0xFA_017_5EED;

/// What happens to requests already computing on a server when it
/// crashes (soft outages never kill work; only `Crash` and generative
/// `kill: true` windows do).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CrashPolicy {
    /// Fail them on the spot: counted as dropped, infinite processing
    /// time, recorded under `failed_in_flight` incident accounting.
    #[default]
    Fail,
    /// Bounce them back through the scheduler as if they had just
    /// arrived (upload is not repeated; the decision is). Recorded under
    /// `requeued_in_flight`.
    Requeue,
}

/// One scripted fault. All times are absolute simulation seconds —
/// absolute (not durations) so lowering involves no float arithmetic and
/// legacy outage replays stay bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Hard crash: service rate drops to zero, in-flight computing
    /// requests are failed or requeued per [`CrashPolicy`], and the
    /// server restarts cold (service-model state rebuilt) at `recover`.
    /// `recover: None` means the server never comes back.
    Crash {
        server: usize,
        recover: Option<SimTime>,
    },
    /// Partial degradation: service rate multiplied by `rate_factor`
    /// (e.g. 0.4 = thermal throttling to 40 %) until `until`. Nested
    /// degradations compose multiplicatively.
    Degrade {
        server: usize,
        rate_factor: f64,
        until: SimTime,
    },
    /// Pin one uplink's bandwidth multiplier to `rate_factor` until
    /// `until`, overriding (but not desynchronizing) the fluctuation
    /// process.
    LinkFlap {
        link: usize,
        rate_factor: f64,
        until: SimTime,
    },
    /// Graceful drain: the server stops accepting new work but finishes
    /// what it has (fleet membership change, not a failure).
    Leave { server: usize },
    /// Rejoin the fleet and accept work again. Schedulers see a
    /// [`crate::scheduler::FleetEvent::Joined`] and may reset stale arm
    /// statistics.
    Join { server: usize },
    /// Legacy soft outage: rate to zero until `until`, in-flight work
    /// stalls rather than dying — exactly what
    /// `ClusterConfig::outages` always did.
    Outage { server: usize, until: SimTime },
}

/// A scripted fault at an absolute time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: SimTime,
    pub kind: FaultKind,
}

/// Generative failure process: independent alternating-renewal up/down
/// cycles per server with exponential time-to-failure (mean `mttf_s`)
/// and time-to-repair (mean `mttr_s`), drawn from a per-server salted
/// side-stream RNG. Windows never overlap on one server by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct GenerativeFaults {
    pub mttf_s: f64,
    pub mttr_s: f64,
    /// Stop generating failures past this horizon (repairs may land
    /// after it so no window is left open).
    pub horizon_s: f64,
    /// Servers subject to the process; empty = every server.
    pub targets: Vec<usize>,
    /// `true` → windows are hard crashes (in-flight killed per policy);
    /// `false` → soft outages.
    pub kill: bool,
}

/// Health-probe configuration: probe every `period_s`, publish each
/// probe's snapshot to the observed view `lag_s` later. Publication
/// happens on probe ticks, so the effective lag is quantized up to the
/// next probe boundary (lag 5.0 with period 1.0 → observed health is
/// 5–6 s stale).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthConfig {
    pub period_s: f64,
    pub lag_s: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            period_s: 1.0,
            lag_s: 5.0,
        }
    }
}

/// The full chaos description for one run. `FaultPlan::default()` is the
/// empty plan: no scripted events, no generative process, no health
/// monitor — and the engine is bit-identical to a plan-less run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub scripted: Vec<FaultEvent>,
    pub generative: Option<GenerativeFaults>,
    /// Install a lagged health monitor; `None` keeps views on ground
    /// truth (`observed_health` pinned at 1.0).
    pub health: Option<HealthConfig>,
    pub crash_policy: CrashPolicy,
}

/// Lowered, engine-facing fault action. Scripted events and generative
/// windows both reduce to this vocabulary; the engine replays them as
/// `Ev::Fault` events.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Server goes down (`crash` distinguishes hard crashes from soft
    /// outages). Nested windows stack: a server is up again only when
    /// every covering window has ended.
    Down { server: usize, crash: bool },
    Up { server: usize, crash: bool },
    DegradeStart { server: usize, factor: f64 },
    DegradeEnd { server: usize, factor: f64 },
    FlapStart { link: usize, factor: f64 },
    FlapEnd { link: usize },
    Leave { server: usize },
    Join { server: usize },
}

impl FaultAction {
    /// The server this action targets, when it targets one.
    pub fn server(&self) -> Option<usize> {
        match *self {
            FaultAction::Down { server, .. }
            | FaultAction::Up { server, .. }
            | FaultAction::DegradeStart { server, .. }
            | FaultAction::DegradeEnd { server, .. }
            | FaultAction::Leave { server }
            | FaultAction::Join { server } => Some(server),
            FaultAction::FlapStart { .. } | FaultAction::FlapEnd { .. } => None,
        }
    }

    /// The link this action targets, when it targets one (link flaps).
    pub fn link(&self) -> Option<usize> {
        match *self {
            FaultAction::FlapStart { link, .. } | FaultAction::FlapEnd { link } => Some(link),
            _ => None,
        }
    }

    /// The server (or same-index link) whose shard must apply this
    /// action's *physics* — links share their server's index, so one
    /// accessor routes both families.
    pub fn target_index(&self) -> usize {
        match self.server() {
            Some(s) => s,
            // lint: allow(p1) the two families are exhaustive: no server target implies a link target
            None => self.link().expect("fault action targets a server or a link"),
        }
    }
}

/// Partition a materialized fault timeline across the shards of a
/// [`crate::sim::topology::ShardPlan`]: `out[s]` receives the indices
/// (into `timeline`) of the actions whose *physics* land on shard `s`,
/// preserving timeline order within each shard.
///
/// The sharded engine still executes every fault action at a global
/// merge barrier (fault actions feed `FleetEvent`s to the scheduler and
/// may crash-requeue work, both scheduler interactions); what this
/// partition answers is *which shard's local state* — server rate
/// multipliers, link flap factors, crash victims — each action touches,
/// so the orchestrator routes exactly one shard command per action.
pub fn partition_timeline_by_shard(
    timeline: &[(SimTime, FaultAction)],
    plan: &crate::sim::topology::ShardPlan,
) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = (0..plan.n_shards()).map(|_| Vec::new()).collect();
    for (i, (_, action)) in timeline.iter().enumerate() {
        out[plan.shard_of(action.target_index())].push(i);
    }
    out
}

impl FaultPlan {
    /// True when the plan changes nothing about a run.
    pub fn is_empty(&self) -> bool {
        self.scripted.is_empty() && self.generative.is_none() && self.health.is_none()
    }

    /// Lower the legacy scripted outage list into a plan that replays
    /// through the fault layer bit-identically (same adjacent
    /// start/end push order per outage, same absolute times).
    pub fn from_outages(outages: &[Outage]) -> Self {
        FaultPlan {
            scripted: outages
                .iter()
                .map(|o| FaultEvent {
                    at: o.start,
                    kind: FaultKind::Outage {
                        server: o.server,
                        until: o.end,
                    },
                })
                .collect(),
            ..FaultPlan::default()
        }
    }

    pub fn with_event(mut self, at: SimTime, kind: FaultKind) -> Self {
        self.scripted.push(FaultEvent { at, kind });
        self
    }

    pub fn with_generative(mut self, g: GenerativeFaults) -> Self {
        self.generative = Some(g);
        self
    }

    pub fn with_health(mut self, h: HealthConfig) -> Self {
        self.health = Some(h);
        self
    }

    pub fn with_crash_policy(mut self, p: CrashPolicy) -> Self {
        self.crash_policy = p;
        self
    }

    /// Lower the plan to a `(time, action)` timeline. The list is NOT
    /// sorted: scripted events emit their start/end action pairs
    /// adjacently in scripted order (matching the legacy outage push
    /// order so replays keep identical event sequence numbers — the
    /// calendar queue orders by `(time, seq)` and handles out-of-order
    /// pushes), with generative windows appended after. Panics on
    /// out-of-range indices or nonsensical parameters: a fault plan is
    /// experiment configuration, and a typo should fail loudly at
    /// construction, not corrupt a long run.
    pub fn materialize(
        &self,
        n_servers: usize,
        n_links: usize,
        seed: u64,
    ) -> Vec<(SimTime, FaultAction)> {
        let mut out = Vec::new();
        for ev in &self.scripted {
            assert!(ev.at >= 0.0, "fault time must be nonnegative");
            match ev.kind {
                FaultKind::Crash { server, recover } => {
                    assert!(server < n_servers, "crash target {server} out of range");
                    out.push((ev.at, FaultAction::Down { server, crash: true }));
                    if let Some(r) = recover {
                        assert!(r >= ev.at, "crash recovery precedes the crash");
                        out.push((r, FaultAction::Up { server, crash: true }));
                    }
                }
                FaultKind::Degrade {
                    server,
                    rate_factor,
                    until,
                } => {
                    assert!(server < n_servers, "degrade target {server} out of range");
                    assert!(
                        rate_factor > 0.0 && rate_factor.is_finite(),
                        "degrade factor must be positive and finite (use Crash for zero-rate)"
                    );
                    assert!(until >= ev.at, "degrade ends before it starts");
                    out.push((
                        ev.at,
                        FaultAction::DegradeStart {
                            server,
                            factor: rate_factor,
                        },
                    ));
                    out.push((
                        until,
                        FaultAction::DegradeEnd {
                            server,
                            factor: rate_factor,
                        },
                    ));
                }
                FaultKind::LinkFlap {
                    link,
                    rate_factor,
                    until,
                } => {
                    assert!(link < n_links, "flap target link {link} out of range");
                    assert!(
                        rate_factor > 0.0 && rate_factor.is_finite(),
                        "flap factor must be positive and finite"
                    );
                    assert!(until >= ev.at, "flap ends before it starts");
                    out.push((
                        ev.at,
                        FaultAction::FlapStart {
                            link,
                            factor: rate_factor,
                        },
                    ));
                    out.push((until, FaultAction::FlapEnd { link }));
                }
                FaultKind::Leave { server } => {
                    assert!(server < n_servers, "leave target {server} out of range");
                    out.push((ev.at, FaultAction::Leave { server }));
                }
                FaultKind::Join { server } => {
                    assert!(server < n_servers, "join target {server} out of range");
                    out.push((ev.at, FaultAction::Join { server }));
                }
                FaultKind::Outage { server, until } => {
                    assert!(server < n_servers, "outage target {server} out of range");
                    assert!(until >= ev.at, "outage ends before it starts");
                    out.push((
                        ev.at,
                        FaultAction::Down {
                            server,
                            crash: false,
                        },
                    ));
                    out.push((
                        until,
                        FaultAction::Up {
                            server,
                            crash: false,
                        },
                    ));
                }
            }
        }
        if let Some(g) = &self.generative {
            assert!(g.mttf_s > 0.0 && g.mttr_s > 0.0, "MTTF/MTTR must be positive");
            assert!(g.horizon_s >= 0.0, "generative horizon must be nonnegative");
            let all: Vec<usize>;
            let targets: &[usize] = if g.targets.is_empty() {
                all = (0..n_servers).collect();
                &all
            } else {
                &g.targets
            };
            for &s in targets {
                assert!(s < n_servers, "generative target {s} out of range");
                // One independent stream per (seed, server): schedules
                // are reproducible and adding a server never reshuffles
                // another server's windows.
                let mut rng = Rng::new(
                    seed ^ FAULT_STREAM_SALT
                        ^ (s as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                let mut t = rng.exp(1.0 / g.mttf_s);
                while t < g.horizon_s {
                    let d = rng.exp(1.0 / g.mttr_s);
                    out.push((
                        t,
                        FaultAction::Down {
                            server: s,
                            crash: g.kill,
                        },
                    ));
                    out.push((
                        t + d,
                        FaultAction::Up {
                            server: s,
                            crash: g.kill,
                        },
                    ));
                    // Repair completes before the next failure draw:
                    // windows on one server can never overlap.
                    t += d + rng.exp(1.0 / g.mttf_s);
                }
            }
        }
        out
    }
}

/// Lagged health observation: the scheduler-facing view of fleet health,
/// deliberately out of date. The engine probes ground truth every
/// `period_s`; each snapshot becomes the published observation once
/// `lag_s` has elapsed (checked at probe ticks, see [`HealthConfig`]).
/// Until a crash propagates through the pipeline, schedulers keep seeing
/// — and routing to — a healthy server.
pub struct HealthMonitor {
    cfg: HealthConfig,
    /// Published (lagged) per-server health: the server's effective
    /// service-rate multiplier as of `lag_s` ago. 1.0 = healthy,
    /// 0.0 = down/left.
    observed: Vec<f64>,
    /// Probes waiting out their lag, oldest first.
    pending: VecDeque<(SimTime, Vec<f64>)>,
    /// Recycled snapshot buffers (probes run every period for the whole
    /// run; no steady-state allocation).
    spare: Vec<Vec<f64>>,
}

impl HealthMonitor {
    pub fn new(cfg: HealthConfig, n_servers: usize) -> Self {
        assert!(cfg.period_s > 0.0, "probe period must be positive");
        assert!(cfg.lag_s >= 0.0, "observation lag must be nonnegative");
        HealthMonitor {
            cfg,
            observed: vec![1.0; n_servers],
            pending: VecDeque::new(),
            spare: Vec::new(),
        }
    }

    pub fn cfg(&self) -> HealthConfig {
        self.cfg
    }

    /// The lagged health signal for one server.
    #[inline]
    pub fn observed(&self, server: usize) -> f64 {
        self.observed[server]
    }

    /// Record a probe of ground truth at `now`, then publish every
    /// pending snapshot whose lag has elapsed (lag 0 publishes the new
    /// probe immediately).
    pub fn probe(&mut self, now: SimTime, truth: &[f64]) {
        debug_assert_eq!(truth.len(), self.observed.len());
        let mut snap = self.spare.pop().unwrap_or_default();
        snap.clear();
        snap.extend_from_slice(truth);
        self.pending.push_back((now, snap));
        while self.pending.front().is_some_and(|(t, _)| *t + self.cfg.lag_s <= now) {
            if let Some((_, v)) = self.pending.pop_front() {
                self.observed.copy_from_slice(&v);
                self.spare.push(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_materializes_to_nothing() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        assert!(plan.materialize(6, 6, 42).is_empty());
    }

    /// Every action names exactly one physics target (server or link),
    /// and the shard partition routes each action to its owner in
    /// timeline order.
    #[test]
    fn timeline_partitions_to_owning_shards() {
        use crate::sim::topology::ShardPlan;
        let timeline: Vec<(SimTime, FaultAction)> = vec![
            (1.0, FaultAction::Down { server: 0, crash: true }),
            (2.0, FaultAction::FlapStart { link: 5, factor: 0.5 }),
            (3.0, FaultAction::Leave { server: 4 }),
            (4.0, FaultAction::Up { server: 0, crash: true }),
            (5.0, FaultAction::FlapEnd { link: 5 }),
            (6.0, FaultAction::DegradeStart { server: 2, factor: 0.7 }),
        ];
        for (_, a) in &timeline {
            assert!(a.server().is_some() != a.link().is_some(), "{a:?}");
            assert_eq!(
                a.target_index(),
                a.server().or(a.link()).unwrap(),
                "{a:?}"
            );
        }
        // 6 servers in 2 shards of 3: servers/links 0-2 → shard 0,
        // 3-5 → shard 1.
        let plan = ShardPlan::contiguous(6, 2);
        let parts = partition_timeline_by_shard(&timeline, &plan);
        assert_eq!(parts, vec![vec![0, 3, 5], vec![1, 2, 4]]);
    }

    /// `from_outages` must reproduce the legacy engine's push pattern:
    /// per outage, the start action immediately followed by the end
    /// action, in outage-list order, at the exact scripted times.
    #[test]
    fn from_outages_preserves_legacy_push_order_and_times() {
        let outages = vec![
            Outage {
                server: 2,
                start: 5.0,
                end: 9.0,
            },
            Outage {
                server: 0,
                start: 1.5,
                end: 2.5,
            },
        ];
        let plan = FaultPlan::from_outages(&outages);
        assert!(!plan.is_empty());
        let tl = plan.materialize(6, 6, 7);
        assert_eq!(
            tl,
            vec![
                (
                    5.0,
                    FaultAction::Down {
                        server: 2,
                        crash: false
                    }
                ),
                (
                    9.0,
                    FaultAction::Up {
                        server: 2,
                        crash: false
                    }
                ),
                (
                    1.5,
                    FaultAction::Down {
                        server: 0,
                        crash: false
                    }
                ),
                (
                    2.5,
                    FaultAction::Up {
                        server: 0,
                        crash: false
                    }
                ),
            ]
        );
    }

    #[test]
    fn scripted_kinds_lower_to_expected_actions() {
        let plan = FaultPlan::default()
            .with_event(
                10.0,
                FaultKind::Crash {
                    server: 1,
                    recover: Some(40.0),
                },
            )
            .with_event(
                20.0,
                FaultKind::Degrade {
                    server: 3,
                    rate_factor: 0.4,
                    until: 50.0,
                },
            )
            .with_event(
                30.0,
                FaultKind::LinkFlap {
                    link: 5,
                    rate_factor: 0.1,
                    until: 35.0,
                },
            )
            .with_event(60.0, FaultKind::Leave { server: 4 })
            .with_event(90.0, FaultKind::Join { server: 4 });
        let tl = plan.materialize(6, 6, 0);
        assert_eq!(
            tl,
            vec![
                (
                    10.0,
                    FaultAction::Down {
                        server: 1,
                        crash: true
                    }
                ),
                (
                    40.0,
                    FaultAction::Up {
                        server: 1,
                        crash: true
                    }
                ),
                (
                    20.0,
                    FaultAction::DegradeStart {
                        server: 3,
                        factor: 0.4
                    }
                ),
                (
                    50.0,
                    FaultAction::DegradeEnd {
                        server: 3,
                        factor: 0.4
                    }
                ),
                (
                    30.0,
                    FaultAction::FlapStart {
                        link: 5,
                        factor: 0.1
                    }
                ),
                (35.0, FaultAction::FlapEnd { link: 5 }),
                (60.0, FaultAction::Leave { server: 4 }),
                (90.0, FaultAction::Join { server: 4 }),
            ]
        );
    }

    #[test]
    fn permanent_crash_emits_no_recovery() {
        let plan = FaultPlan::default().with_event(
            1.0,
            FaultKind::Crash {
                server: 0,
                recover: None,
            },
        );
        let tl = plan.materialize(2, 2, 0);
        assert_eq!(
            tl,
            vec![(
                1.0,
                FaultAction::Down {
                    server: 0,
                    crash: true
                }
            )]
        );
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn materialize_rejects_out_of_range_server() {
        FaultPlan::default()
            .with_event(
                0.0,
                FaultKind::Crash {
                    server: 6,
                    recover: None,
                },
            )
            .materialize(6, 6, 0);
    }

    fn windows_of(tl: &[(SimTime, FaultAction)], server: usize) -> Vec<(SimTime, SimTime)> {
        let mut out = Vec::new();
        let mut open: Option<SimTime> = None;
        for (t, a) in tl {
            match a {
                FaultAction::Down { server: s, .. } if *s == server => {
                    assert!(open.is_none(), "nested generative window");
                    open = Some(*t);
                }
                FaultAction::Up { server: s, .. } if *s == server => {
                    out.push((open.take().expect("up without down"), *t));
                }
                _ => {}
            }
        }
        assert!(open.is_none(), "window left open");
        out
    }

    #[test]
    fn generative_schedules_are_seed_deterministic() {
        let plan = FaultPlan::default().with_generative(GenerativeFaults {
            mttf_s: 100.0,
            mttr_s: 20.0,
            horizon_s: 2000.0,
            targets: Vec::new(),
            kill: true,
        });
        let a = plan.materialize(6, 6, 0xC1A0);
        let b = plan.materialize(6, 6, 0xC1A0);
        assert!(!a.is_empty(), "2000 s at MTTF 100 s should fail sometimes");
        assert_eq!(a.len(), b.len());
        for ((ta, aa), (tb, ab)) in a.iter().zip(&b) {
            assert_eq!(ta.to_bits(), tb.to_bits());
            assert_eq!(aa, ab);
        }
        let c = plan.materialize(6, 6, 0xC1A1);
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x != y),
            "different seeds should produce different schedules"
        );
    }

    #[test]
    fn generative_windows_never_overlap_per_server() {
        let plan = FaultPlan::default().with_generative(GenerativeFaults {
            mttf_s: 50.0,
            mttr_s: 30.0,
            horizon_s: 5000.0,
            targets: Vec::new(),
            kill: false,
        });
        let tl = plan.materialize(4, 4, 99);
        for s in 0..4 {
            let ws = windows_of(&tl, s);
            assert!(!ws.is_empty(), "server {s} drew no windows");
            for w in &ws {
                assert!(w.0 < w.1, "window {w:?} is empty or inverted");
                assert!(w.0 < 5000.0, "window starts past horizon");
            }
            for pair in ws.windows(2) {
                assert!(
                    pair[0].1 <= pair[1].0,
                    "windows {:?} and {:?} overlap on server {s}",
                    pair[0],
                    pair[1]
                );
            }
        }
    }

    #[test]
    fn generative_targets_limit_the_blast_radius() {
        let plan = FaultPlan::default().with_generative(GenerativeFaults {
            mttf_s: 50.0,
            mttr_s: 10.0,
            horizon_s: 3000.0,
            targets: vec![1],
            kill: false,
        });
        let tl = plan.materialize(6, 6, 5);
        assert!(!tl.is_empty());
        for (_, a) in &tl {
            match a {
                FaultAction::Down { server, .. } | FaultAction::Up { server, .. } => {
                    assert_eq!(*server, 1)
                }
                other => panic!("unexpected action {other:?}"),
            }
        }
    }

    #[test]
    fn health_monitor_publishes_after_lag() {
        let mut hm = HealthMonitor::new(
            HealthConfig {
                period_s: 1.0,
                lag_s: 3.0,
            },
            2,
        );
        assert_eq!(hm.observed(0), 1.0);
        assert_eq!(hm.observed(1), 1.0);
        // Server 0 dies at t=0; probes run every second.
        for t in 0..3 {
            hm.probe(t as f64, &[0.0, 1.0]);
            assert_eq!(hm.observed(0), 1.0, "t={t}: lag not yet elapsed");
        }
        // t=3: the t=0 snapshot (0.0, 1.0) becomes visible.
        hm.probe(3.0, &[0.0, 1.0]);
        assert_eq!(hm.observed(0), 0.0);
        assert_eq!(hm.observed(1), 1.0);
        // Recovery at t=4 likewise takes 3 s to surface.
        hm.probe(4.0, &[1.0, 1.0]);
        assert_eq!(hm.observed(0), 0.0);
        for t in 5..7 {
            hm.probe(t as f64, &[1.0, 1.0]);
        }
        hm.probe(7.0, &[1.0, 1.0]);
        assert_eq!(hm.observed(0), 1.0);
    }

    #[test]
    fn zero_lag_publishes_immediately() {
        let mut hm = HealthMonitor::new(
            HealthConfig {
                period_s: 0.5,
                lag_s: 0.0,
            },
            1,
        );
        hm.probe(0.0, &[0.25]);
        assert_eq!(hm.observed(0), 0.25);
        hm.probe(0.5, &[0.75]);
        assert_eq!(hm.observed(0), 0.75);
    }
}
