//! Edge-cloud cluster assembly: the paper's testbed (five edge servers
//! with dedicated LAN links plus one cloud server behind the shared WAN
//! uplink) generalized to arbitrary multi-tier topologies, and the
//! scheduler-facing resource snapshot (CMAB state space).
//!
//! A [`ClusterConfig`] now carries an explicit `LinkSpec` per server
//! instead of deriving links from the server tier, which is what lets
//! [`super::topology::TopologyConfig`] express heterogeneous EdgeShard-
//! style fleets (per-tier bandwidth, RTT, and energy-per-bit) through the
//! same simulation substrate.

use super::energy::{EnergyBreakdown, EnergyWeights};
use super::faults::HealthMonitor;
use super::net::{LinkSim, LinkSpec};
use super::server::{paper_testbed, ServerKind, ServerSim, ServerSpec};
use super::service_model::ServiceModel;
use super::time::SimTime;
use crate::scheduler::{ClusterView, ServerView, ViewSource};
use crate::workload::service::ServiceRequest;

/// Bandwidth regime (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BandwidthMode {
    Stable,
    /// Varies within ±20 %.
    Fluctuating,
}

/// Injected server outage window (failure injection tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outage {
    pub server: usize,
    pub start: SimTime,
    pub end: SimTime,
}

/// Full cluster configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub servers: Vec<ServerSpec>,
    /// One uplink per server (same indexing as `servers`).
    pub links: Vec<LinkSpec>,
    pub bandwidth: BandwidthMode,
    pub weights: EnergyWeights,
    pub outages: Vec<Outage>,
    pub seed: u64,
    /// Skip the completion-event invalidate+re-push when an occupancy
    /// touch provably did not move the next completion (same finish-work
    /// top, same service rate). Default on; the off position exists so the
    /// churn-regression test can pin that the guard changes stale-event
    /// accounting only, never outcomes.
    pub churn_guard: bool,
}

impl ClusterConfig {
    /// The paper's testbed with the given edge model deployment
    /// ("yi-6b" | "llama2-7b" | "llama3-8b" | "yi-9b").
    pub fn paper(edge_model: &str, bandwidth: BandwidthMode) -> Self {
        let servers = paper_testbed(edge_model);
        let fluct = bandwidth == BandwidthMode::Fluctuating;
        let links = servers
            .iter()
            .enumerate()
            .map(|(i, s)| match s.kind {
                ServerKind::Edge => LinkSpec::edge(i, fluct),
                ServerKind::Cloud => LinkSpec::cloud(fluct),
            })
            .collect();
        ClusterConfig {
            servers,
            links,
            bandwidth,
            weights: EnergyWeights::default(),
            outages: Vec::new(),
            seed: 0xC1A0,
            churn_guard: true,
        }
    }

    pub fn with_outages(mut self, outages: Vec<Outage>) -> Self {
        self.outages = outages;
        self
    }

    pub fn with_churn_guard(mut self, on: bool) -> Self {
        self.churn_guard = on;
        self
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    pub fn cloud_index(&self) -> usize {
        self.servers
            .iter()
            .position(|s| s.kind == ServerKind::Cloud)
            // lint: allow(p1) every topology constructor appends the cloud tier
            .expect("cluster has a cloud server")
    }
}

/// Requests dispatched toward a server but still uploading — the router's
/// own bookkeeping, folded into predictions so decision bursts don't herd
/// onto one server through stale state.
#[derive(Debug, Clone, Copy, Default)]
pub struct InFlight {
    pub n: usize,
    pub work_s: f64,
}

/// Compute one server's scheduler-facing snapshot entry — the single
/// shared pricing function behind every `ClusterView` fill. Extracted
/// from [`ClusterSim::view_into_at`] so the sharded engine's per-shard
/// view-slice fills (sim/shard.rs) run the *identical* float expressions
/// in the identical order: bit-identical `ServerView`s are what make the
/// sequential-vs-sharded decision streams comparable at all.
///
/// `observed` is `None` for ground-truth pricing (no health monitor) and
/// `Some(rate)` for the lagged observed rate; the caller owns looking the
/// rate up so shards can use their barrier-refreshed local copy.
pub fn fill_server_view(
    srv: &ServerSim,
    link: &LinkSim,
    fl: &InFlight,
    observed: Option<f64>,
    req: &ServiceRequest,
) -> ServerView {
    // lint: no-alloc per-server snapshot pricing on the decision hot path
    let tx = link.predict_tx_time(req.payload_bytes);
    // Without a health monitor the view prices ground truth (identity
    // with every pre-fault run); with one, predictions use the *lagged*
    // observed rate — a just-crashed server keeps quoting healthy
    // predictions until the probe pipeline catches up.
    let (service, observed_health) = match observed {
        None => (srv.predict(req, fl.n, fl.work_s), 1.0),
        Some(o) => (srv.predict_with_rate(req, fl.n, fl.work_s, o), o),
    };
    // Bandwidth the upload needs to finish inside a nominal 1-second
    // window (paper C3's B_i).
    let bw_demand = req.payload_bytes as f64 * 8.0;
    let view = ServerView {
        kind: srv.spec.kind,
        predicted_time: tx + service.total_s,
        // Honest first-token estimate from the service model (queue wait
        // + stretched prefill), behind the same upload.
        predicted_ttft: tx + service.ttft_s,
        compute_headroom: srv.compute_headroom_with(fl.n),
        compute_demand: ServerSpec::compute_demand(req),
        bandwidth_headroom: link.bandwidth_headroom(),
        bandwidth_demand: bw_demand,
        tx_energy_est: link.spec.tx_energy(req.payload_bytes),
        infer_energy_est: (srv.spec.p_infer - srv.spec.p_idle) * srv.spec.solo_work(req),
        n_active: srv.n_active(),
        n_waiting: srv.n_waiting(),
        solo_time_est: link.spec.solo_time(req.payload_bytes) + srv.spec.solo_work(req),
        // Raw occupancy (no in-flight bookkeeping): what an external
        // observer without router state sees.
        occupancy: (srv.n_active() + srv.n_waiting()) as f64
            / (srv.model.slot_capacity() + srv.model.queue_capacity()) as f64,
        observed_health,
        // Session affinity signal (PR 10): how much of this request's
        // conversation prefix is KV-resident here (0 for single-shot
        // requests), and how full the prefix cache is (eviction risk).
        // `predicted_time`/`predicted_ttft` above already price the
        // reuse through `srv.predict`; these fields let affinity-aware
        // schedulers weigh stickiness explicitly.
        prefix_hit_tokens: srv.prefix_reuse(req) as f64,
        prefix_pressure: srv.prefix.occupancy(),
    };
    // lint: end-no-alloc
    view
}

/// Live cluster state: one ServerSim + one LinkSim per server.
pub struct ClusterSim {
    pub servers: Vec<ServerSim>,
    pub links: Vec<LinkSim>,
    pub weights: EnergyWeights,
    /// Per-server in-flight dispatch accounting.
    pub in_flight: Vec<InFlight>,
    /// Fleet membership: a server that has gracefully left (fault-plan
    /// `Leave`) finishes its in-service work but admits nothing new and
    /// is never a scheduling candidate. Always `true` without a fault
    /// plan.
    pub accepting: Vec<bool>,
    /// Lagged health observation (fault-plan `HealthConfig`). When
    /// installed, [`Self::view_into_at`] prices servers with *observed*
    /// health instead of ground-truth `rate_mult` and exports it as
    /// `ServerView::observed_health`; when absent, views see ground
    /// truth exactly as before and `observed_health` is pinned at 1.0.
    pub health: Option<HealthMonitor>,
    /// Observation clock: the time of the last event the owner processed.
    /// `ViewSource::view_into` stamps snapshots with it, so the engine and
    /// the live router expose the same two-argument view-filling API.
    pub now: SimTime,
    /// Incremental admissibility index: `admissible[i]` mirrors
    /// `!servers[i].would_drop()` and is refreshed O(1) at every
    /// occupancy-changing touch (the engine calls
    /// [`Self::refresh_admissibility`] after each queue push/reap). The
    /// scheduler snapshot exports it as `ClusterView::candidates`, which
    /// is what lets `decide()` stop scanning servers that cannot admit
    /// anything on 100-server views.
    admissible: Vec<bool>,
    n_admissible: usize,
    /// Timestamp of the last full [`Self::advance_all`]; lets repeated
    /// same-instant calls (one per completion in a reap batch) early-out
    /// instead of touching every server again.
    advanced_at: SimTime,
    /// Versioned-view counter: bumped on every snapshot fill so each
    /// `ClusterView` carries a strictly increasing epoch (the
    /// [`ViewSource`] contract). A `Cell` because `view_into` takes
    /// `&self`; the simulation is single-owner, so interior mutability
    /// here is purely an API-shape concession.
    view_epoch: std::cell::Cell<u64>,
}

impl ClusterSim {
    pub fn new(cfg: &ClusterConfig) -> Self {
        assert_eq!(
            cfg.servers.len(),
            cfg.links.len(),
            "one LinkSpec per server"
        );
        ClusterSim {
            in_flight: vec![InFlight::default(); cfg.servers.len()],
            accepting: vec![true; cfg.servers.len()],
            health: None,
            servers: cfg.servers.iter().cloned().map(ServerSim::new).collect(),
            links: cfg.links.iter().cloned().map(LinkSim::new).collect(),
            weights: cfg.weights,
            now: 0.0,
            admissible: vec![true; cfg.servers.len()],
            n_admissible: cfg.servers.len(),
            advanced_at: -1.0,
            view_epoch: std::cell::Cell::new(0),
        }
    }

    /// Record a dispatch toward `server` (request now uploading).
    pub fn dispatch_in_flight(&mut self, server: usize, req: &ServiceRequest) {
        let w = self.servers[server].spec.solo_work(req);
        self.in_flight[server].n += 1;
        self.in_flight[server].work_s += w;
    }

    /// Record an arrival at `server` (upload finished).
    pub fn land_in_flight(&mut self, server: usize, req: &ServiceRequest) {
        let w = self.servers[server].spec.solo_work(req);
        let f = &mut self.in_flight[server];
        f.n = f.n.saturating_sub(1);
        f.work_s = (f.work_s - w).max(0.0);
    }

    /// Re-derive one server's admissibility after an occupancy change
    /// (queue push, reap, waiter promotion). O(1); the owner must call
    /// this after every touch that can flip `would_drop()` so the
    /// candidate set handed to schedulers never goes stale.
    pub fn refresh_admissibility(&mut self, server: usize) {
        let ok = self.accepting[server] && !self.servers[server].would_drop();
        if ok != self.admissible[server] {
            self.admissible[server] = ok;
            if ok {
                self.n_admissible += 1;
            } else {
                self.n_admissible -= 1;
            }
        }
    }

    /// Servers currently able to admit a request (slot or queue space).
    pub fn n_admissible(&self) -> usize {
        self.n_admissible
    }

    /// Raw admissibility flags, index-aligned with `servers`. The sharded
    /// engine reads these out of each sub-cluster to rebuild the global
    /// candidate set (`ClusterView::candidates`) at the merge barrier.
    pub fn admissible_flags(&self) -> &[bool] {
        &self.admissible
    }

    /// Advance every server and link integrator to `now`. O(servers +
    /// links): each queue advance is a constant-time virtual-time bump, so
    /// this stays cheap even mid-congestion-collapse. Repeated calls at
    /// the same instant (the feedback path advances once per completion in
    /// a reap batch) early-out in O(1).
    pub fn advance_all(&mut self, now: SimTime) {
        self.now = now;
        if now == self.advanced_at {
            return;
        }
        for s in &mut self.servers {
            s.advance_to(now);
        }
        for l in &mut self.links {
            l.advance_to(now);
        }
        self.advanced_at = now;
    }

    /// Build the scheduler-facing snapshot for one request (CMAB state).
    /// Callers must have advanced the cluster to `now` first.
    pub fn view(&self, req: &ServiceRequest, now: SimTime) -> ClusterView {
        let mut out = ClusterView::with_capacity(self.servers.len(), self.weights);
        self.view_into_at(req, now, &mut out);
        out
    }

    /// Fill a caller-owned snapshot in place, stamped with an explicit
    /// observation time. The engine keeps one scratch `ClusterView` and
    /// refills it per decision, so the per-arrival hot path allocates
    /// nothing once the `servers` Vec has reached cluster size. The
    /// trait-level [`ViewSource::view_into`] delegates here with
    /// `self.now`.
    pub fn view_into_at(&self, req: &ServiceRequest, now: SimTime, out: &mut ClusterView) {
        // lint: no-alloc per-decision snapshot refill; `out` buffers amortize to cluster size
        out.now = now;
        // Versioned-view contract: every fill is a fresh, strictly newer
        // snapshot.
        self.view_epoch.set(self.view_epoch.get() + 1);
        out.epoch = self.view_epoch.get();
        out.weights = self.weights;
        out.servers.clear();
        out.servers.extend(
            self.servers
                .iter()
                .zip(&self.links)
                .zip(&self.in_flight)
                .enumerate()
                .map(|(i, ((srv, link), fl))| {
                    let observed = self.health.as_ref().map(|h| h.observed(i));
                    fill_server_view(srv, link, fl, observed, req)
                }),
        );
        // Candidate pruning: when some servers are saturated (cannot admit
        // anything, hence provably infeasible — zero compute headroom), the
        // view names the admissible subset so schedulers skip the rest. An
        // empty list means "no pruning information, scan everything" — used
        // both when every server is admissible (pruning would save nothing)
        // and by view sources without an index (the live router).
        out.candidates.clear();
        if self.n_admissible < self.servers.len() {
            out.candidates.extend(
                self.admissible
                    .iter()
                    .enumerate()
                    .filter(|(_, &ok)| ok)
                    .map(|(i, _)| i as u32),
            );
        }
        // lint: end-no-alloc
    }

    /// Total energy so far, split by objective term.
    pub fn energy(&self) -> EnergyBreakdown {
        let mut e = EnergyBreakdown::default();
        for s in &self.servers {
            e.infer_j += s.energy_infer_j;
            e.idle_j += s.energy_idle_j;
        }
        for l in &self.links {
            // Link energy is attributed per completed upload at dispatch
            // time; integrate moved bytes for the cluster total.
            e.tran_j += l.bytes_moved * 8.0 / 1.0e6 * l.spec.energy_j_per_mbit;
        }
        e
    }

    pub fn tokens_served(&self) -> u64 {
        self.servers.iter().map(|s| s.tokens_served).sum()
    }
}

impl ViewSource for ClusterSim {
    /// The unified-API entry point: same signature the live `Router`
    /// implements, stamped with the cluster's observation clock.
    fn view_into(&self, req: &ServiceRequest, out: &mut ClusterView) {
        self.view_into_at(req, self.now, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::service::ServiceClass;

    fn req() -> ServiceRequest {
        ServiceRequest {
            id: 0,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: 100,
            output_tokens: 40,
            slo: crate::workload::service::SloSpec::completion_only(4.0),
            payload_bytes: 200_000,
            session: None,
        }
    }

    /// Warm KV residency surfaces in the view: the server that served a
    /// session's previous turn quotes `prefix_hit_tokens` and a faster
    /// prediction than its cold twins; single-shot requests see zero.
    #[test]
    fn view_surfaces_prefix_residency() {
        use crate::workload::service::SessionRef;
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        let mut turn1 = req();
        turn1.session = Some(SessionRef {
            session_id: 42,
            turn: 1,
            prefix_tokens: 0,
            xfer_tokens: 0,
        });
        sim.servers[2].admit(1, &turn1, 0.0);
        let mut turn2 = req();
        turn2.prompt_tokens = 240;
        turn2.session = Some(SessionRef {
            session_id: 42,
            turn: 2,
            prefix_tokens: 140,
            xfer_tokens: 0,
        });
        let v = sim.view(&turn2, 0.0);
        assert_eq!(v.servers[2].prefix_hit_tokens, 140.0);
        assert!(v.servers[2].prefix_pressure > 0.0);
        for (i, sv) in v.servers.iter().enumerate() {
            if i != 2 {
                assert_eq!(sv.prefix_hit_tokens, 0.0, "server {i} is cold");
            }
        }
        // Single-shot request: no affinity anywhere.
        let v2 = sim.view(&req(), 0.0);
        assert!(v2.servers.iter().all(|sv| sv.prefix_hit_tokens == 0.0));
    }

    #[test]
    fn paper_cluster_shape() {
        let cfg = ClusterConfig::paper("yi-6b", BandwidthMode::Stable);
        assert_eq!(cfg.n_servers(), 6);
        assert_eq!(cfg.cloud_index(), 5);
        assert_eq!(cfg.links.len(), 6);
        let sim = ClusterSim::new(&cfg);
        assert_eq!(sim.servers.len(), 6);
        assert_eq!(sim.links.len(), 6);
        assert!(sim.links[5].spec.bandwidth_bps > sim.links[0].spec.bandwidth_bps);
    }

    #[test]
    fn view_has_all_servers_and_sane_predictions() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let sim = ClusterSim::new(&cfg);
        let v = sim.view(&req(), 0.0);
        assert_eq!(v.servers.len(), 6);
        for sv in &v.servers {
            assert!(sv.predicted_time > 0.0 && sv.predicted_time.is_finite());
            assert!(
                sv.predicted_ttft > 0.0 && sv.predicted_ttft <= sv.predicted_time,
                "ttft {} vs total {}",
                sv.predicted_ttft,
                sv.predicted_time
            );
            assert!(sv.tx_energy_est > 0.0);
            assert!(sv.infer_energy_est > 0.0);
        }
        // Idle cluster: cloud is predicted faster at inference…
        let cloud = &v.servers[5];
        let edge = &v.servers[0];
        assert!(cloud.predicted_time < edge.predicted_time);
        // …but costs more energy.
        assert!(cloud.infer_energy_est > edge.infer_energy_est);
        assert!(cloud.tx_energy_est > edge.tx_energy_est);
    }

    #[test]
    fn view_into_refills_scratch_snapshot() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let sim = ClusterSim::new(&cfg);
        let fresh = sim.view(&req(), 1.5);
        let mut scratch = ClusterView::with_capacity(cfg.n_servers(), cfg.weights);
        // Fill twice: the second fill must fully replace the first.
        sim.view_into_at(&req(), 0.5, &mut scratch);
        sim.view_into_at(&req(), 1.5, &mut scratch);
        assert_eq!(scratch.now, 1.5);
        assert_eq!(scratch.servers.len(), fresh.servers.len());
        for (a, b) in scratch.servers.iter().zip(&fresh.servers) {
            assert_eq!(a.predicted_time, b.predicted_time);
            assert_eq!(a.n_active, b.n_active);
            assert_eq!(a.occupancy, b.occupancy);
        }
    }

    #[test]
    fn trait_view_uses_observation_clock() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        sim.advance_all(2.5);
        let mut scratch = ClusterView::default();
        ViewSource::view_into(&sim, &req(), &mut scratch);
        assert_eq!(scratch.now, 2.5);
        let mut direct = sim.view(&req(), 2.5);
        // Epochs are strictly increasing per fill; everything else in the
        // two snapshots is identical.
        assert!(direct.epoch > scratch.epoch);
        direct.epoch = scratch.epoch;
        assert_eq!(scratch, direct);
    }

    /// Versioned-view contract: every fill stamps a strictly larger
    /// epoch, whatever mix of entry points produced it.
    #[test]
    fn view_epochs_strictly_increase_across_fills() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        let mut scratch = ClusterView::default();
        let mut last = 0u64;
        for step in 0..5 {
            sim.advance_all(step as f64 * 0.5);
            ViewSource::view_into(&sim, &req(), &mut scratch);
            assert!(scratch.epoch > last, "epoch stalled at step {step}");
            last = scratch.epoch;
        }
        let owned = sim.view(&req(), 2.5);
        assert!(owned.epoch > last);
    }

    /// The extracted per-server pricing helper is exactly the fill the
    /// full snapshot performs — the bit-identity bridge the sharded
    /// engine's view slices stand on.
    #[test]
    fn fill_server_view_matches_full_snapshot_entries() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        sim.servers[2].admit(9, &req(), 0.0);
        sim.dispatch_in_flight(1, &req());
        sim.advance_all(0.25);
        let v = sim.view(&req(), 0.25);
        for i in 0..sim.servers.len() {
            let sv = fill_server_view(
                &sim.servers[i],
                &sim.links[i],
                &sim.in_flight[i],
                None,
                &req(),
            );
            assert_eq!(sv, v.servers[i], "server {i} diverged");
        }
    }

    #[test]
    fn energy_starts_zero_and_grows_idle() {
        let cfg = ClusterConfig::paper("yi-9b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        assert_eq!(sim.energy().total_j(), 0.0);
        sim.advance_all(10.0);
        let e = sim.energy();
        assert!(e.idle_j > 0.0);
        assert_eq!(e.infer_j, 0.0);
        // 5 edges * 6 W + 1 cloud * 65 W, 10 s.
        assert!((e.idle_j - (5.0 * 6.0 + 65.0) * 10.0).abs() < 1e-6);
    }

    #[test]
    fn advance_all_same_instant_early_outs() {
        let cfg = ClusterConfig::paper("yi-9b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        sim.advance_all(5.0);
        let e1 = sim.energy().total_j();
        // Same instant: no double integration, clock still stamped.
        sim.advance_all(5.0);
        assert_eq!(sim.energy().total_j(), e1);
        assert_eq!(sim.now, 5.0);
        sim.advance_all(6.0);
        assert!(sim.energy().total_j() > e1);
    }

    #[test]
    fn fluctuating_mode_sets_link_amplitude() {
        let cfg = ClusterConfig::paper("yi-6b", BandwidthMode::Fluctuating);
        let sim = ClusterSim::new(&cfg);
        assert!(sim.links.iter().all(|l| l.spec.fluctuation > 0.0));
        let cfg2 = ClusterConfig::paper("yi-6b", BandwidthMode::Stable);
        let sim2 = ClusterSim::new(&cfg2);
        assert!(sim2.links.iter().all(|l| l.spec.fluctuation == 0.0));
    }

    /// The admissibility index mirrors `would_drop()` and the view exports
    /// it as a candidate list exactly when some server is saturated.
    #[test]
    fn admissibility_index_tracks_saturation() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        assert_eq!(sim.n_admissible(), 6);
        let mut v = ClusterView::default();
        sim.view_into_at(&req(), 0.0, &mut v);
        assert!(v.candidates.is_empty(), "no pruning while all admissible");

        // Saturate edge 0: 8 slots + 2 waiting places.
        for j in 0..10 {
            sim.servers[0].admit(j, &req(), 0.0);
            sim.refresh_admissibility(0);
        }
        assert!(sim.servers[0].would_drop());
        assert_eq!(sim.n_admissible(), 5);
        sim.view_into_at(&req(), 0.0, &mut v);
        assert_eq!(v.candidates, vec![1, 2, 3, 4, 5]);

        // Drain it again: candidates disappear (full-scan sentinel).
        let mut buf = Vec::new();
        let mut t = 0.0;
        while sim.servers[0].n_active() + sim.servers[0].n_waiting() > 0 {
            t += 100.0;
            sim.servers[0].advance_to(t);
            sim.servers[0].reap_into(t, &mut buf);
            sim.refresh_admissibility(0);
            assert!(t < 1e4, "server failed to drain");
        }
        assert_eq!(sim.n_admissible(), 6);
        sim.view_into_at(&req(), t, &mut v);
        assert!(v.candidates.is_empty());
    }

    /// A server that gracefully left the fleet is not a candidate even
    /// though its queue has room.
    #[test]
    fn left_server_disappears_from_candidates() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        sim.accepting[0] = false;
        sim.refresh_admissibility(0);
        assert_eq!(sim.n_admissible(), 5);
        let mut v = ClusterView::default();
        sim.view_into_at(&req(), 0.0, &mut v);
        assert_eq!(v.candidates, vec![1, 2, 3, 4, 5]);
        sim.accepting[0] = true;
        sim.refresh_admissibility(0);
        assert_eq!(sim.n_admissible(), 6);
    }

    /// With a health monitor installed, views price servers at the
    /// *lagged* observed rate: a crashed server keeps quoting healthy
    /// predictions until the probe pipeline catches up, then goes
    /// (effectively) infinitely slow.
    #[test]
    fn monitored_view_prices_lagged_health() {
        use crate::sim::faults::{HealthConfig, HealthMonitor};
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut sim = ClusterSim::new(&cfg);
        sim.health = Some(HealthMonitor::new(
            HealthConfig {
                period_s: 1.0,
                lag_s: 2.0,
            },
            6,
        ));
        // Ground truth: server 0 is down.
        sim.servers[0].rate_mult = 0.0;
        let v = sim.view(&req(), 0.0);
        assert_eq!(v.servers[0].observed_health, 1.0, "lag hides the crash");
        let healthy_pred = v.servers[0].predicted_time;
        assert!(healthy_pred.is_finite());
        // Drive the truth through the probe pipeline past the lag.
        let truth = [0.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let h = sim.health.as_mut().expect("monitor installed");
        h.probe(0.0, &truth);
        h.probe(1.0, &truth);
        h.probe(2.0, &truth);
        let v2 = sim.view(&req(), 2.0);
        assert_eq!(v2.servers[0].observed_health, 0.0);
        assert!(
            v2.servers[0].predicted_time > 1e6 * healthy_pred,
            "observed-down server must price near-infinitely slow"
        );
        // Unmonitored sibling keeps observed_health pinned at 1.0.
        assert_eq!(v2.servers[1].observed_health, 1.0);
    }
}
