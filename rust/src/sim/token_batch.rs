//! Discrete-iteration continuous-batching service model: the DES
//! counterpart of the live coordinator's Orca-style `Batcher`
//! (`coordinator/batcher.rs`), behind the [`ServiceModel`] trait.
//!
//! Where the PS fluid spreads a server's token rate continuously over
//! every resident request, this model serves the batch in **iterations**:
//! with `n` lanes occupied, one iteration takes
//! `d(n) = n / (decode_rate * eff(n))` seconds (eff is the calibrated
//! [`batch_efficiency`] curve) and grants every lane exactly one token of
//! progress. Per-iteration throughput `n / d(n) = decode_rate * eff(n)`
//! therefore grows **sub-linearly** with occupancy — the batching physics
//! the edge-throughput study arXiv:2405.07140 shows dominates edge
//! serving, invisible to a fluid whose rate split is composition-blind at
//! the iteration scale.
//!
//! A request's demand is expressed in *iteration-equivalents*
//! ([`TokenBatchModel::token_units`]): its `output_tokens` decode
//! iterations plus its prefill converted at the prefill/decode rate
//! ratio. At batch size 1 the model therefore reduces exactly to solo
//! prefill + decode time, and with a linear efficiency curve (alpha = 1)
//! `d(n)` is occupancy-independent — the fluid limit the differential
//! test in `rust/tests/token_batch.rs` checks against [`PsQueue`]
//! predictions.
//!
//! Admission mirrors the live batcher: a request enters a **lane** when
//! one of the `slots` lanes is free *and* the KV-token budget admits its
//! `prompt + output` reservation (the analogue of `KvPool::can_admit`);
//! otherwise it joins the bounded FIFO wait queue. Lane promotion happens
//! at engine touch points (admission and reap) — head-of-line, exactly
//! like the batcher's iteration-boundary admission — never silently
//! between events, so the engine's completion events and admissibility
//! index stay exact. Once the wait queue is at its bound, further
//! arrivals are shed whether the head is lane-blocked or KV-blocked
//! (`would_drop`): KV head-of-line pressure must not grow the queue past
//! its limit just because lanes happen to sit free. Reservations larger
//! than the whole KV budget are clamped to it (the request runs solo
//! with everything the server has), so no waiter is ever unpromotable.
//!
//! [`PsQueue`]: super::ps::PsQueue

use std::collections::VecDeque;

use super::ps::{batch_efficiency, PsJob};
use super::server::ServerSpec;
use super::service_model::{ServiceModel, ServicePrediction};
use super::time::SimTime;
use crate::workload::service::ServiceRequest;

/// Sub-token tolerance: progress within this many iteration-equivalents
/// of zero counts as finished (guards float drift at completion
/// boundaries, like `PsQueue`'s `DONE_EPS_S`).
const TOK_EPS: f64 = 1e-9;

/// One resident sequence in the running batch.
#[derive(Debug, Clone, Copy)]
struct Lane {
    id: u64,
    /// Remaining demand in iteration-equivalents (prefill-converted +
    /// decode tokens); the lane finishes when this reaches zero.
    tokens_left: f64,
    /// KV tokens reserved for this sequence (released at completion).
    kv_tokens: u64,
    enqueued_at: SimTime,
    started_at: SimTime,
    energy_j: f64,
}

/// A request waiting for a lane (untouched by service).
#[derive(Debug, Clone, Copy)]
struct Waiting {
    id: u64,
    tokens: f64,
    kv_tokens: u64,
    solo_s: f64,
    enqueued_at: SimTime,
}

/// Discrete-iteration continuous-batching server state.
#[derive(Debug)]
pub struct TokenBatchModel {
    spec: ServerSpec,
    kv_budget: u64,
    kv_used: u64,
    lanes: Vec<Lane>,
    waiting: VecDeque<Waiting>,
    /// Lanes that reached zero demand, awaiting the engine's reap (the
    /// completion event fires at exactly the finishing instant, so these
    /// never linger across sim time).
    finished: Vec<PsJob>,
    /// Fraction of the current iteration already elapsed, in [0, 1).
    /// Preserved as a fraction across composition changes: a lane joining
    /// mid-iteration rides the in-progress iteration (the live batcher's
    /// boundary admission, averaged out).
    iter_frac: f64,
    /// Completed iterations since the last drain — the absolute iteration
    /// index underlying the reschedule key.
    iters_done: u64,
    /// Sum of waiting solo-seconds (incremental backlog aggregate).
    waiting_work_s: f64,
}

impl TokenBatchModel {
    pub fn new(spec: ServerSpec, kv_budget_tokens: u64) -> Self {
        assert!(spec.slots > 0 && kv_budget_tokens > 0);
        TokenBatchModel {
            kv_budget: kv_budget_tokens,
            kv_used: 0,
            lanes: Vec::with_capacity(spec.slots),
            waiting: VecDeque::new(),
            finished: Vec::new(),
            iter_frac: 0.0,
            iters_done: 0,
            waiting_work_s: 0.0,
            spec,
        }
    }

    /// A request's demand in iteration-equivalents: decode tokens plus
    /// prefill converted at the rate ratio, so
    /// `token_units * d(1) = prompt/prefill_rate + output/decode_rate`
    /// (exact solo reduction).
    pub fn token_units(spec: &ServerSpec, req: &ServiceRequest) -> f64 {
        req.output_tokens as f64
            + req.prompt_tokens as f64 * spec.decode_rate / spec.prefill_rate
    }

    /// KV reservation a request holds while resident (prompt + output,
    /// the same budget the live batcher admits against its `KvPool`),
    /// clamped to the pool size: a sequence larger than the whole budget
    /// runs solo with everything the server has — the DES analogue of
    /// the live batcher truncating prompts to `max_seq` — instead of
    /// becoming an unpromotable head-of-line waiter that would deadlock
    /// the server.
    fn kv_reservation(&self, req: &ServiceRequest) -> u64 {
        ((req.prompt_tokens + req.output_tokens) as u64).min(self.kv_budget)
    }

    /// Nominal seconds one iteration takes at batch size `n`.
    fn iter_time(&self, n: usize) -> f64 {
        debug_assert!(n > 0);
        n as f64 / (self.spec.decode_rate * batch_efficiency(n, self.spec.batch_alpha))
    }

    /// Whole iterations a lane with `tokens_left` demand still needs
    /// (shared by the predictor and the completion schedule, so the
    /// uncontended prediction matches the realized time float-for-float).
    fn iters_needed(tokens_left: f64) -> f64 {
        (tokens_left - TOK_EPS).ceil().max(1.0)
    }

    /// Fewest iterations until some lane finishes.
    fn min_iters_needed(&self) -> Option<f64> {
        self.lanes
            .iter()
            .map(|l| Self::iters_needed(l.tokens_left))
            // lint: allow(p1, n1) iters_needed is ceil of a finite count, never NaN
            .min_by(|a, b| a.partial_cmp(b).expect("finite iteration counts"))
    }

    fn start_lane(&mut self, w: Waiting, now: SimTime) {
        self.kv_used += w.kv_tokens;
        self.lanes.push(Lane {
            id: w.id,
            tokens_left: w.tokens,
            kv_tokens: w.kv_tokens,
            enqueued_at: w.enqueued_at,
            started_at: now,
            energy_j: 0.0,
        });
    }

    /// Head-of-line waiter promotion into free lanes (KV permitting) —
    /// called at engine touch points only (admit/reap), never inside
    /// `advance`, so completion events are always scheduled from the
    /// post-promotion composition.
    fn promote_waiters(&mut self, now: SimTime) {
        while self.lanes.len() < self.spec.slots {
            let Some(&w) = self.waiting.front() else { break };
            if self.kv_used + w.kv_tokens > self.kv_budget {
                break; // KV pressure: strict FIFO, retry at the next touch.
            }
            self.waiting.pop_front();
            self.waiting_work_s -= w.solo_s;
            if self.waiting.is_empty() {
                self.waiting_work_s = 0.0;
            }
            self.start_lane(w, now);
        }
        if self.lanes.is_empty() && self.waiting.is_empty() {
            // Fully drained: reset the iteration phase and counter so
            // float state stays small over arbitrarily long runs.
            self.iter_frac = 0.0;
            self.iters_done = 0;
        }
    }

    /// KV tokens currently reserved by resident sequences.
    pub fn kv_used(&self) -> u64 {
        self.kv_used
    }

    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }
}

impl ServiceModel for TokenBatchModel {
    fn admit(&mut self, id: u64, req: &ServiceRequest, now: SimTime) {
        let w = Waiting {
            id,
            tokens: Self::token_units(&self.spec, req),
            kv_tokens: self.kv_reservation(req),
            solo_s: self.spec.solo_work(req),
            enqueued_at: now,
        };
        // Strict FIFO: an arrival may only enter a lane directly when no
        // earlier request is still waiting (a small request must not jump
        // a KV-blocked head-of-line waiter).
        if self.waiting.is_empty()
            && self.lanes.len() < self.spec.slots
            && self.kv_used + w.kv_tokens <= self.kv_budget
        {
            self.start_lane(w, now);
        } else {
            // Bounded wait (the engine shed anything `would_drop` caught;
            // the KV-blocked corner overflows softly — module docs).
            self.waiting_work_s += w.solo_s;
            self.waiting.push_back(w);
        }
    }

    fn would_drop(&self) -> bool {
        if self.waiting.len() < self.spec.queue_limit {
            return false; // bounded queue still has room
        }
        // Queue at its bound. Strict FIFO means an arrival could only be
        // accepted by starting service immediately, which is impossible
        // whenever any waiter is blocked ahead of it (a non-empty queue
        // after a touch implies its head is lane- or KV-blocked —
        // promotion runs at every touch) or the lanes are full. This is
        // what keeps the wait queue bounded under KV head-of-line
        // blocking even while lanes sit free.
        !self.waiting.is_empty() || self.lanes.len() >= self.spec.slots
    }

    fn advance(&mut self, dt: SimTime, rate_mult: f64, energy_per_job: f64) {
        // lint: no-alloc O(lanes) per-event progress on the DES hot path
        if dt <= 0.0 || self.lanes.is_empty() {
            return;
        }
        // Energy is attributed even at rate 0 (outage: the box still
        // burns inference power over its resident batch), mirroring the
        // PS model's advance_energy semantics.
        for lane in &mut self.lanes {
            lane.energy_j += energy_per_job;
        }
        if rate_mult <= 0.0 {
            return;
        }
        let n = self.lanes.len();
        let d = self.iter_time(n);
        // Progress in nominal seconds; composition is constant between
        // engine events (completions land exactly on events, promotions
        // only at touches), so every iteration in the interval has the
        // same period.
        let total = self.iter_frac * d + dt * rate_mult;
        let k = (total / d + TOK_EPS).floor();
        self.iter_frac = ((total - k * d) / d).clamp(0.0, 1.0);
        if k <= 0.0 {
            return;
        }
        self.iters_done += k as u64;
        let mut i = 0;
        while i < self.lanes.len() {
            self.lanes[i].tokens_left -= k;
            if self.lanes[i].tokens_left <= TOK_EPS {
                // Order-preserving removal: same-iteration finishers
                // complete in admission order (FIFO ties, like PsQueue).
                let lane = self.lanes.remove(i);
                self.kv_used -= lane.kv_tokens;
                self.finished.push(PsJob {
                    id: lane.id,
                    remaining: 0.0,
                    enqueued_at: lane.enqueued_at,
                    started_at: Some(lane.started_at),
                    energy_j: lane.energy_j,
                });
            } else {
                i += 1;
            }
        }
        // lint: end-no-alloc
    }

    fn next_completion_in(&self, rate_mult: f64) -> Option<SimTime> {
        if !self.finished.is_empty() {
            // Lanes already finished (advance crossed their boundary at
            // this exact instant): reap is due now.
            return Some(0.0);
        }
        if rate_mult <= 0.0 {
            return None;
        }
        let m = self.min_iters_needed()?;
        let d = self.iter_time(self.lanes.len());
        Some(((m - self.iter_frac) * d / rate_mult).max(0.0))
    }

    fn completion_key(&self, rate_mult: f64) -> Option<(f64, f64)> {
        if !self.finished.is_empty() {
            // Distinct from any live-batch key (periods are positive),
            // and changes as more lanes finish, so the guard always
            // reschedules an immediate reap.
            return Some((f64::NEG_INFINITY, self.finished.len() as f64));
        }
        if rate_mult <= 0.0 {
            return None;
        }
        let m = self.min_iters_needed()?;
        // (absolute finish-iteration index, effective iteration period):
        // both are constant along an untouched interval — progress moves
        // `iters_done` up exactly as `m` comes down — so an identical
        // pair certifies the scheduled completion instant still holds.
        Some((
            self.iters_done as f64 + m,
            self.iter_time(self.lanes.len()) / rate_mult,
        ))
    }

    fn reap_into(&mut self, now: SimTime, _rate_mult: f64, out: &mut Vec<PsJob>) {
        // lint: no-alloc completion reaping runs per event; `out` is caller-owned
        out.clear();
        out.append(&mut self.finished);
        self.promote_waiters(now);
        // lint: end-no-alloc
    }

    fn predict(
        &self,
        req: &ServiceRequest,
        extra_n: usize,
        extra_work_s: f64,
        rate_mult: f64,
    ) -> ServicePrediction {
        let tokens = Self::token_units(&self.spec, req);
        let occupied = self.lanes.len() + extra_n;
        let n_after = (occupied + 1).min(self.spec.slots);
        let d = self.iter_time(n_after);
        let mult = if rate_mult > 0.0 { rate_mult } else { 1e-9 };
        // Queue wait: solo-second backlog ahead of us over the saturated
        // batch's total service rate — the same estimator shape as the PS
        // model, so scheduler comparisons stay information-symmetric. A
        // non-empty wait queue means we queue behind its (lane- or
        // KV-blocked) head regardless of free lanes — strict FIFO — so
        // the wait term must apply there too, or a KV-starved server
        // would advertise near-solo times exactly when it is congested.
        let wait = if occupied >= self.spec.slots || !self.waiting.is_empty() {
            let eff = batch_efficiency(n_after, self.spec.batch_alpha).max(1e-9);
            (self.backlog_s() + extra_work_s) / (eff * mult)
        } else {
            0.0
        };
        let prefill_units =
            req.prompt_tokens as f64 * self.spec.decode_rate / self.spec.prefill_rate;
        ServicePrediction {
            ttft_s: wait + (prefill_units + 1.0).min(tokens) * d / mult,
            // Whole iterations, matching the completion schedule exactly:
            // on an uncontended server this *is* the realized time.
            total_s: wait + Self::iters_needed(tokens) * d / mult,
        }
    }

    fn n_active(&self) -> usize {
        self.lanes.len()
    }

    fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    fn slot_capacity(&self) -> usize {
        self.spec.slots
    }

    fn queue_capacity(&self) -> usize {
        self.spec.queue_limit
    }

    fn backlog_s(&self) -> f64 {
        let lane_s: f64 = self
            .lanes
            .iter()
            .map(|l| l.tokens_left.max(0.0) / self.spec.decode_rate)
            .sum();
        lane_s + self.waiting_work_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::server::paper_testbed;
    use crate::workload::service::ServiceClass;

    fn spec() -> ServerSpec {
        let mut s = paper_testbed("llama2-7b")[0].clone();
        s.service_model = crate::sim::service_model::ServiceModelKind::token_batch_for(s.slots);
        s
    }

    fn req(id: u64, prompt: u32, output: u32) -> ServiceRequest {
        ServiceRequest {
            id,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: prompt,
            output_tokens: output,
            slo: crate::workload::service::SloSpec::completion_only(10.0),
            payload_bytes: 10_000,
            session: None,
        }
    }

    fn model() -> TokenBatchModel {
        TokenBatchModel::new(spec(), 8 * 1536)
    }

    /// Drive the model alone to the completion of everything, returning
    /// (time, completed jobs) — a miniature of what the engine does.
    fn run_to_empty(m: &mut TokenBatchModel) -> (f64, Vec<PsJob>) {
        let mut t = 0.0;
        let mut done = Vec::new();
        let mut buf = Vec::new();
        while let Some(dt) = m.next_completion_in(1.0) {
            m.advance(dt, 1.0, 0.0);
            t += dt;
            m.reap_into(t, 1.0, &mut buf);
            done.extend(buf.drain(..));
        }
        (t, done)
    }

    #[test]
    fn solo_request_takes_whole_iterations_of_solo_time() {
        let s = spec();
        let mut m = model();
        let r = req(1, 130, 10);
        m.admit(1, &r, 0.0);
        assert_eq!(m.n_active(), 1);
        let (t, done) = run_to_empty(&mut m);
        assert_eq!(done.len(), 1);
        // Solo time quantized up to whole iterations of d(1) = 1/decode.
        let solo = s.solo_work(&r);
        let d1 = 1.0 / s.decode_rate;
        assert!(t >= solo - 1e-9, "{t} < {solo}");
        assert!(t <= solo + d1 + 1e-9, "{t} overshoots solo by > 1 iter");
        assert_eq!(m.kv_used(), 0, "KV released at completion");
    }

    #[test]
    fn uncontended_prediction_matches_realized_time_exactly() {
        let mut m = model();
        let r = req(1, 200, 40);
        let predicted = m.predict(&r, 0, 0.0, 1.0);
        m.admit(1, &r, 0.0);
        let (t, _) = run_to_empty(&mut m);
        assert!(
            (predicted.total_s - t).abs() < 1e-12,
            "predicted {} vs realized {t}",
            predicted.total_s
        );
        assert!(predicted.ttft_s > 0.0 && predicted.ttft_s <= predicted.total_s);
    }

    #[test]
    fn per_iteration_throughput_grows_sublinearly() {
        // n identical requests served together: total token throughput
        // must follow eff(n) — above 1x (batching helps) but below n
        // (sub-linear), matching the efficiency curve within the
        // whole-iteration quantization.
        let s = spec();
        let time_for = |n: usize| {
            let mut m = model();
            for i in 0..n as u64 {
                m.admit(i, &req(i, 100, 60), 0.0);
            }
            let (t, done) = run_to_empty(&mut m);
            assert_eq!(done.len(), n);
            t
        };
        let t1 = time_for(1);
        let t4 = time_for(4);
        let t8 = time_for(8);
        // Same per-request demand: T(n) = T(1) * n / eff(n) (+quantization).
        assert!(t4 > t1 * 1.05, "batching cannot be free: {t4} vs {t1}");
        assert!(t4 < t1 * 4.0, "batching must beat serial: {t4} vs {t1}");
        let eff4 = batch_efficiency(4, s.batch_alpha);
        let eff8 = batch_efficiency(8, s.batch_alpha);
        assert!(
            (t4 / t1 - 4.0 / eff4).abs() < 0.05 * (4.0 / eff4),
            "T(4)/T(1) = {} expected {}",
            t4 / t1,
            4.0 / eff4
        );
        // Throughput (requests per second) keeps rising with occupancy…
        assert!(8.0 / t8 > 4.0 / t4 && 4.0 / t4 > 1.0 / t1);
        // …but sub-linearly, tracking eff.
        assert!((t8 / t1 - 8.0 / eff8).abs() < 0.05 * (8.0 / eff8));
    }

    #[test]
    fn bounded_queue_and_promotion() {
        let s = spec();
        let mut m = model();
        let cap = s.slots + s.queue_limit;
        for i in 0..cap as u64 {
            assert!(!m.would_drop());
            // Staggered lengths: completions arrive one lane at a time.
            m.admit(i, &req(i, 50, 20 + 10 * i as u32), 0.0);
        }
        assert_eq!(m.n_active(), s.slots);
        assert_eq!(m.n_waiting(), s.queue_limit);
        assert!(m.would_drop());
        // First completion frees a lane; reap promotes the head waiter.
        let dt = m.next_completion_in(1.0).unwrap();
        m.advance(dt, 1.0, 0.0);
        let mut buf = Vec::new();
        m.reap_into(dt, 1.0, &mut buf);
        assert!(!buf.is_empty());
        assert_eq!(m.n_active(), s.slots, "promotion refills the batch");
        assert!(m.n_waiting() < s.queue_limit);
        assert!(!m.would_drop());
    }

    #[test]
    fn kv_budget_blocks_lane_admission() {
        // Budget fits exactly one 600-token sequence: the second request
        // waits even though lanes are free, and is promoted only after
        // the first completes.
        let mut m = TokenBatchModel::new(spec(), 700);
        m.admit(1, &req(1, 500, 100), 0.0);
        assert_eq!(m.n_active(), 1);
        assert_eq!(m.kv_used(), 600);
        m.admit(2, &req(2, 100, 50), 0.0);
        assert_eq!(m.n_active(), 1, "KV pressure must queue, not lane");
        assert_eq!(m.n_waiting(), 1);
        let (_, done) = run_to_empty(&mut m);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[1].id, 2);
        assert_eq!(m.kv_used(), 0);
    }

    #[test]
    fn would_drop_under_kv_exhaustion_with_free_lanes() {
        // Tiny budget: one resident sequence exhausts KV; once the
        // bounded queue fills, further arrivals are shed even though
        // lanes remain free.
        let mut s = spec();
        s.queue_limit = 1;
        let mut m = TokenBatchModel::new(s, 600);
        m.admit(1, &req(1, 500, 100), 0.0);
        assert!(!m.would_drop());
        m.admit(2, &req(2, 100, 50), 0.0);
        assert!(m.n_active() == 1 && m.n_waiting() == 1);
        assert!(m.would_drop(), "KV-exhausted + full queue must shed");
    }

    /// Regression (review): with a KV-blocked head and free lanes,
    /// `would_drop` used to require exact budget exhaustion, so the
    /// bounded queue grew without limit. The queue bound must hold
    /// whatever is blocking the head.
    #[test]
    fn kv_blocked_head_keeps_queue_bounded() {
        let mut s = spec();
        s.queue_limit = 2;
        // Budget 601: a resident 600-token sequence leaves 1 spare token,
        // so kv_used < budget forever while no waiter can promote.
        let mut m = TokenBatchModel::new(s, 601);
        m.admit(1, &req(1, 500, 100), 0.0);
        m.admit(2, &req(2, 100, 50), 0.0);
        m.admit(3, &req(3, 100, 50), 0.0);
        assert_eq!(m.n_active(), 1);
        assert_eq!(m.n_waiting(), 2);
        assert!(
            m.would_drop(),
            "queue at its bound must shed even though lanes are free and kv_used < budget"
        );
        // Draining the resident promotes the head again.
        let (_, done) = run_to_empty(&mut m);
        assert_eq!(done.len(), 3);
    }

    /// Regression (review): with free lanes but a KV-blocked wait queue,
    /// `predict` used to report zero wait — advertising near-solo times
    /// exactly when the server is KV-congested.
    #[test]
    fn predict_counts_kv_blocked_queue() {
        let probe = req(9, 100, 50);
        let idle = TokenBatchModel::new(spec(), 700).predict(&probe, 0, 0.0, 1.0);
        let mut m = TokenBatchModel::new(spec(), 700);
        m.admit(1, &req(1, 500, 100), 0.0); // resident: kv 600 of 700
        m.admit(2, &req(2, 100, 50), 0.0); // KV-blocked waiter, lanes free
        assert_eq!(m.n_waiting(), 1);
        assert!(m.n_active() < m.slot_capacity());
        let loaded = m.predict(&probe, 0, 0.0, 1.0);
        assert!(
            loaded.total_s > idle.total_s,
            "KV-congested server must not advertise idle times: {} vs {}",
            loaded.total_s,
            idle.total_s
        );
        assert!(loaded.ttft_s > idle.ttft_s);
    }

    /// Regression (review): a request whose prompt+output reservation
    /// exceeds the whole KV budget used to become an unpromotable
    /// head-of-line waiter, deadlocking the server. It now runs solo
    /// with the clamped full-budget reservation.
    #[test]
    fn oversized_request_runs_solo_instead_of_deadlocking() {
        let mut m = TokenBatchModel::new(spec(), 300); // < 500 + 100
        m.admit(1, &req(1, 500, 100), 0.0);
        assert_eq!(m.n_active(), 1, "oversized request must still start");
        assert_eq!(m.kv_used(), 300, "reservation clamped to the budget");
        m.admit(2, &req(2, 100, 50), 0.0);
        assert_eq!(m.n_waiting(), 1, "budget fully held: next request waits");
        let (_, done) = run_to_empty(&mut m);
        assert_eq!(done.len(), 2, "server must drain, not deadlock");
        assert_eq!(m.kv_used(), 0);
    }

    #[test]
    fn completion_key_is_stable_along_untouched_intervals() {
        let mut m = model();
        m.admit(1, &req(1, 100, 40), 0.0);
        m.admit(2, &req(2, 100, 80), 0.0);
        let k0 = m.completion_key(1.0).unwrap();
        // Advance by a third of the way to the first completion: the key
        // must not move (the scheduled event is still exact)…
        let eta = m.next_completion_in(1.0).unwrap();
        m.advance(eta / 3.0, 1.0, 0.0);
        let k1 = m.completion_key(1.0).unwrap();
        assert_eq!(k0, k1);
        // …and the remaining time must shrink by exactly the elapsed dt.
        let eta1 = m.next_completion_in(1.0).unwrap();
        assert!((eta - eta / 3.0 - eta1).abs() < 1e-9);
        // An admission changes the composition: key must move.
        m.admit(3, &req(3, 100, 40), 0.5);
        assert_ne!(m.completion_key(1.0).unwrap(), k1);
    }

    #[test]
    fn outage_freezes_progress_but_attributes_energy() {
        let mut m = model();
        m.admit(1, &req(1, 100, 40), 0.0);
        assert!(m.next_completion_in(0.0).is_none());
        assert!(m.completion_key(0.0).is_none());
        let backlog = m.backlog_s();
        m.advance(100.0, 0.0, 7.0);
        assert_eq!(m.backlog_s(), backlog, "no progress at rate 0");
        let dt = m.next_completion_in(1.0).unwrap();
        m.advance(dt, 1.0, 1.0);
        let mut buf = Vec::new();
        m.reap_into(100.0 + dt, 1.0, &mut buf);
        assert_eq!(buf.len(), 1);
        assert!((buf[0].energy_j - 8.0).abs() < 1e-12, "{}", buf[0].energy_j);
    }

    #[test]
    fn drained_model_resets_iteration_state() {
        let mut m = model();
        m.admit(1, &req(1, 33, 7), 0.0);
        let (t, _) = run_to_empty(&mut m);
        assert!(t > 0.0);
        assert_eq!(m.iters_done, 0);
        assert_eq!(m.iter_frac, 0.0);
        assert_eq!(m.backlog_s(), 0.0);
    }
}
