//! The discrete-event simulation engine that replays a workload through a
//! scheduler over the edge-cloud cluster.
//!
//! Arrivals are pulled lazily from an [`ArrivalSource`] cursor: the engine
//! prefetches exactly one pending request, so the event heap holds at most
//! one `Arrival` event at a time and its size is bounded by in-flight
//! concurrency, not trace length (a 1M-request run used to begin by
//! pushing 1M arrival events).
//!
//! Event flow per service: Arrival → scheduler [`Action`] — `Assign`
//! dispatches now, `Defer` schedules a delayed Dispatch, `Shed` resolves
//! the request immediately as dropped (with bandit feedback) → upload on
//! the target's link (fair-share PS) → ComputeArrive (after link RTT) →
//! batch slot on the server (PS with batching curve) → ServerDone →
//! outcome + bandit feedback.
//!
//! Completion events for PS queues are generation-stamped: any occupancy or
//! rate change bumps the generation and re-schedules, stale events are
//! dropped on pop (sim/time.rs).

use std::collections::BinaryHeap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use super::cluster::{ClusterConfig, ClusterSim, Outage};
use super::energy::EnergyBreakdown;
use super::faults::{CrashPolicy, FaultAction, FaultPlan, HealthMonitor};
use super::prefix::CacheCounters;
use super::ps::PsJob;
use super::shard::{
    orch_stamp, worker, BoundaryOut, Cmd, CompletionRec, FailRec, Key, LandKind, Reply,
    ShardFinish, ShardSim, ShardStatus,
};
use super::time::{EventQueue, SimTime};
use super::topology::ShardPlan;
use crate::scheduler::{
    Action, ClusterView, FleetEvent, Scheduler, ServerView, ShedReason, ViewSource,
};
use crate::util::rng::Rng;
use crate::util::stats::{Percentiles, Running};
use crate::workload::service::{ServiceOutcome, ServiceRequest, SessionRef};
use crate::workload::{ArrivalSource, TraceSource};

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// The prefetched request arrives at the router (at most one pending).
    Arrival,
    /// Deferred dispatch of service id to server.
    Dispatch { svc: usize, server: usize },
    /// Earliest upload completion on link (generation-stamped).
    LinkDone { link: usize, gen: u64 },
    /// Upload finished + RTT elapsed: service reaches the server.
    ComputeArrive { svc: usize, server: usize },
    /// Earliest batch completion on server (generation-stamped).
    ServerDone { server: usize, gen: u64 },
    /// Re-draw a link's bandwidth fluctuation multiplier.
    FluctTick { link: usize },
    OutageStart { server: usize },
    OutageEnd { server: usize },
    /// Replay one lowered fault-plan action (see `sim::faults`).
    Fault { action: FaultAction },
    /// Probe ground-truth health into the lagged monitor; re-arms itself
    /// every `health_period` while one is configured.
    HealthProbe,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Pending,
    Uploading,
    Computing,
    Done,
    Failed,
}

struct SvcState {
    /// The request itself — owned here since arrivals stream in (there is
    /// no longer a backing trace slice to index).
    req: ServiceRequest,
    server: usize,
    phase: Phase,
    dispatched_at: SimTime,
    upload_done_at: SimTime,
    compute_started_at: SimTime,
    /// Absolute instant the first token lands: stamped at server
    /// admission from the service model's own `predict` (upload already
    /// elapsed, queue wait + stretched prefill from the model) — the
    /// honest-predictor regression pins `predict` exact against the
    /// completion schedule when uncontended, so this is a measurement
    /// there and the model's best estimate under contention. `+inf` until
    /// admission (and forever for drops/sheds).
    first_token_at: SimTime,
    tx_energy_j: f64,
}

/// Per-class attainment counter for one SLO constraint family: how many
/// outcomes carried the constraint, and how many met it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Attainment {
    pub met: usize,
    pub total: usize,
}

impl Attainment {
    fn add(&mut self, met: bool) {
        self.total += 1;
        self.met += met as usize;
    }

    /// Attainment rate; NaN when no outcome carried the constraint
    /// (render as "—", never as a fake 100%).
    pub fn rate(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.met as f64 / self.total as f64
        }
    }
}

/// Incident accounting for a faulted run (PR 6): what went down, what it
/// cost in flight, and how fast the scheduler earned its success rate
/// back after recovery.
#[derive(Debug, Clone)]
pub struct AvailabilityReport {
    /// Per-server down transitions (each time a server's covering-window
    /// stack goes from empty to covered counts once, however nested).
    pub incidents: u64,
    /// First instant any server went down (`inf` when only membership
    /// churn happened).
    pub incident_start_s: f64,
    /// Instant the fleet last returned to fully up; `inf` when some
    /// server never recovered inside the run.
    pub incident_end_s: f64,
    /// In-flight requests killed by hard crashes under
    /// [`CrashPolicy::Fail`], including uploads that landed on a crashed
    /// or departed server.
    pub failed_in_flight: u64,
    /// In-flight requests bounced back through the scheduler under
    /// [`CrashPolicy::Requeue`].
    pub requeued_in_flight: u64,
    pub leaves: u64,
    pub joins: u64,
    /// SLO success attainment bucketed by completion time:
    /// `[pre-incident, during, post-recovery]`.
    pub attainment: [Attainment; 3],
    /// Seconds after full recovery until the cumulative post-recovery
    /// success rate (over at least 20 outcomes) reaches 90 % of the
    /// pre-incident rate; `inf` when it never does, or when nothing
    /// completed pre-incident to compare against.
    pub time_to_recover_s: f64,
    /// Admission-gate door sheds bucketed the same way (all zero without
    /// a gate installed).
    pub gate_sheds_by_phase: [u64; 3],
}

impl AvailabilityReport {
    /// One-line incident summary for the example binaries.
    pub fn availability_row(&self) -> String {
        let pct = |a: &Attainment| {
            if a.total == 0 {
                format!("{:>5}", "—")
            } else {
                format!("{:4.1}%", a.rate() * 100.0)
            }
        };
        let ttr = if self.time_to_recover_s.is_finite() {
            format!("{:.1}s", self.time_to_recover_s)
        } else {
            "—".into()
        };
        let end = if self.incident_end_s.is_finite() {
            format!("{:.1}s", self.incident_end_s)
        } else {
            "never".into()
        };
        format!(
            "availability: incidents {} ({:.1}s → {end}) | attainment pre {} / during {} / post {} \
             | ttr {ttr} | in-flight failed {} requeued {} | leave/join {}/{} | gate sheds {}/{}/{}",
            self.incidents,
            self.incident_start_s,
            pct(&self.attainment[0]),
            pct(&self.attainment[1]),
            pct(&self.attainment[2]),
            self.failed_in_flight,
            self.requeued_in_flight,
            self.leaves,
            self.joins,
            self.gate_sheds_by_phase[0],
            self.gate_sheds_by_phase[1],
            self.gate_sheds_by_phase[2],
        )
    }
}

/// Aggregate results of one simulation run (one cell of a paper table).
pub struct RunReport {
    pub scheduler: &'static str,
    pub outcomes: Vec<ServiceOutcome>,
    pub energy: EnergyBreakdown,
    /// Simulated makespan (first arrival to last completion), seconds.
    pub makespan_s: f64,
    /// Tokens fully processed per simulated second.
    pub throughput_tok_s: f64,
    pub success_rate: f64,
    /// Weighted energy per *successful* service, J — the paper's Fig-2/6
    /// "energy cost per service" metric.
    pub energy_per_success_j: f64,
    pub mean_processing_s: f64,
    pub p95_processing_s: f64,
    /// Requests that never finished inside the horizon.
    pub unfinished: usize,
    /// Requests dropped before completing service, counted where they
    /// happen — scheduler `Shed` actions plus bounded-queue admission
    /// failures — and disjoint from `unfinished` by construction.
    pub dropped: usize,
    /// The subset of `dropped` rejected by an explicit scheduler
    /// `Action::Shed` (no upload energy spent).
    pub dropped_by_policy: usize,
    /// Requests that finished but violated some timing constraint of
    /// their SLO contract (late completion OR late first token).
    pub late: usize,
    /// Per-class TTFT attainment (outcomes carrying a TTFT bound only).
    pub ttft_attainment: [Attainment; 4],
    /// Per-class completion attainment (outcomes carrying a completion
    /// bound only).
    pub completion_attainment: [Attainment; 4],
    /// SLO violations split by constraint family, over all outcomes that
    /// carry the constraint (sheds/drops/unfinished count against every
    /// constraint they carry — the contract was not honored).
    pub slo_ttft_violations: usize,
    pub slo_completion_violations: usize,
    pub slo_energy_violations: usize,
    /// Requests rejected at the admission gate
    /// (`scheduler::admission::TokenBucketGate`), surfaced from the
    /// gate's diagnostics; a subset of `dropped_by_policy`. Zero when no
    /// gate is installed.
    pub gate_sheds: u64,
    /// Incident accounting when the run saw fleet faults or membership
    /// churn; `None` for fault-free runs.
    pub availability: Option<AvailabilityReport>,
    /// Scheduler-specific diagnostics (e.g. CS-UCB regret).
    pub diagnostics: Vec<(String, f64)>,
    /// Wall-clock perf of the DES itself.
    pub wall_s: f64,
    pub events_processed: u64,
    pub events_per_sec: f64,
    /// Popped events that were generation-invalidated and dropped. These
    /// inflate `events_processed` without doing work, so the honest DES
    /// throughput is `events_per_sec * (1 - stale_ratio)`.
    pub stale_events: u64,
    pub stale_ratio: f64,
    /// High-water mark of the event heap. With streaming arrivals this is
    /// bounded by in-flight concurrency (≪ number of requests).
    pub peak_event_queue_len: usize,
    /// Per-shard execution/sync telemetry; `None` on the sequential
    /// engine. Substrate-specific like the perf counters above, so it is
    /// excluded from the bit-identity comparison by design.
    pub shard_perf: Option<ShardPerfReport>,
    /// KV-prefix cache observability (PR 10), folded over every server
    /// in global index order: per-class hit rates, prefill tokens saved,
    /// KV-transfer bytes, evictions. All-zero on session-free runs.
    /// Observability only — excluded from bit-identity comparisons like
    /// the perf counters above (though it is in fact deterministic).
    pub cache: CacheCounters,
}

impl RunReport {
    pub fn summary_row(&self) -> String {
        // Zero successes (the Fig-2 collapse regime, or an all-shed run)
        // has no meaningful per-success energy: render "—" rather than a
        // number that silently means "total energy".
        let per_success = if self.energy_per_success_j.is_finite() {
            format!("{:7.1}", self.energy_per_success_j)
        } else {
            format!("{:>7}", "—")
        };
        format!(
            "{:<22} success {:5.1}%  mean {:6.3}s  p95 {:6.3}s  thpt {:8.1} tok/s  \
             energy {:8.1} kJ (tran {:6.1} / infer {:7.1} / idle {:7.1})  {per_success} J/succ",
            self.scheduler,
            self.success_rate * 100.0,
            self.mean_processing_s,
            self.p95_processing_s,
            self.throughput_tok_s,
            self.energy.total_j() / 1e3,
            self.energy.tran_j / 1e3,
            self.energy.infer_j / 1e3,
            self.energy.idle_j / 1e3,
        )
    }

    /// One-line SLO attainment summary: per-class TTFT / completion
    /// attainment plus the per-family violation split and gate sheds.
    /// Classes with no constrained outcomes render "—".
    pub fn slo_summary_row(&self) -> String {
        let pct = |a: &Attainment| {
            if a.total == 0 {
                format!("{:>5}", "—")
            } else {
                format!("{:4.1}%", a.rate() * 100.0)
            }
        };
        use crate::workload::service::ServiceClass;
        let mut ttft = String::new();
        let mut comp = String::new();
        for c in ServiceClass::ALL {
            ttft.push_str(&format!(" {}={}", c.name(), pct(&self.ttft_attainment[c.index()])));
            comp.push_str(&format!(
                " {}={}",
                c.name(),
                pct(&self.completion_attainment[c.index()])
            ));
        }
        format!(
            "SLO: ttft{ttft} | completion{comp} | violations ttft {} / completion {} / energy {} | gate sheds {}",
            self.slo_ttft_violations,
            self.slo_completion_violations,
            self.slo_energy_violations,
            self.gate_sheds,
        )
    }

    /// One-line KV-prefix cache summary for sessioned runs: overall and
    /// per-class hit rates, prefill tokens skipped, KV bytes shipped over
    /// links, and LRU evictions. Classes that saw no session turns
    /// render "—".
    pub fn cache_row(&self) -> String {
        use crate::workload::service::ServiceClass;
        let pct = |hits: u64, lookups: u64| {
            if lookups == 0 {
                format!("{:>5}", "—")
            } else {
                format!("{:4.1}%", hits as f64 / lookups as f64 * 100.0)
            }
        };
        let mut per_class = String::new();
        for c in ServiceClass::ALL {
            per_class.push_str(&format!(
                " {}={}",
                c.name(),
                pct(self.cache.hits[c.index()], self.cache.lookups[c.index()])
            ));
        }
        format!(
            "cache: hit {} ({}/{} turns) |{per_class} | prefill saved {} tok | \
             kv xfer {:.2} MB | evictions {}",
            pct(self.cache.total_hits(), self.cache.total_lookups()),
            self.cache.total_hits(),
            self.cache.total_lookups(),
            self.cache.prefill_tokens_saved,
            self.cache.kv_transfer_bytes as f64 / 1e6,
            self.cache.evictions,
        )
    }
}

/// One shard's execution and sync-protocol counters for a sharded run.
///
/// `events` is the shard's processed-event count (stale pops included,
/// matching `events_processed` semantics); `grants` counts `Grant`
/// commands received, `events_per_grant` is their ratio, `stall_wall_s`
/// is the orchestrator's cumulative wall-clock time blocked on this
/// shard's replies (barrier stall + mailbox latency), and `round_trips`
/// counts every command/reply exchange (grants, boundary pops, view
/// snapshots, dispatches, faults, finish).
#[derive(Debug, Clone, Copy)]
pub struct ShardPerf {
    /// Global `[lo, hi)` server range this shard owned.
    pub range: (usize, usize),
    pub events: u64,
    pub grants: u64,
    pub events_per_grant: f64,
    pub stall_wall_s: f64,
    pub round_trips: u64,
}

impl ShardPerf {
    /// One renderable row per shard; the fixed `shard-perf` prefix is
    /// what CI greps for (and filters out of identity diffs).
    pub fn row(&self, shard: usize) -> String {
        format!(
            "shard-perf[{shard}] servers [{:>4},{:>4})  events {:>10}  grants {:>8}  \
             ev/grant {:>8.1}  stall {:>7.3}s  round-trips {:>8}",
            self.range.0,
            self.range.1,
            self.events,
            self.grants,
            self.events_per_grant,
            self.stall_wall_s,
            self.round_trips,
        )
    }
}

/// Aggregated shard telemetry attached to a sharded [`RunReport`].
///
/// `imbalance` is max/min *measured* per-shard event volume — the
/// lowering-quality number the volume-weighted partitioner optimizes
/// (1.0 = perfectly balanced; the tier-`Auto` plan on `edgeshard-100x`
/// sits near the edge-tier share ratio without rebalancing).
#[derive(Debug, Clone)]
pub struct ShardPerfReport {
    pub shards: Vec<ShardPerf>,
    pub imbalance: f64,
}

impl ShardPerfReport {
    fn from_parts(parts: Vec<ShardPerf>) -> Self {
        let mut max = 0u64;
        let mut min = u64::MAX;
        for p in &parts {
            if p.events > max {
                max = p.events;
            }
            if p.events < min {
                min = p.events;
            }
        }
        let imbalance = if parts.is_empty() || max == 0 {
            1.0
        } else if min == 0 {
            f64::INFINITY
        } else {
            max as f64 / min as f64
        };
        ShardPerfReport { shards: parts, imbalance }
    }

    /// All per-shard rows plus the imbalance summary line.
    pub fn rows(&self) -> String {
        let mut out = String::new();
        for (s, p) in self.shards.iter().enumerate() {
            out.push_str(&p.row(s));
            out.push('\n');
        }
        out.push_str(&format!(
            "shard-perf imbalance (max/min events) {:.3} over {} shards",
            self.imbalance,
            self.shards.len()
        ));
        out
    }
}

/// Per-resource completion-event bookkeeping for the reschedule guard:
/// the exact inputs (heap-top finish work, per-job service rate) the
/// outstanding event's time was computed from, plus that time. An
/// occupancy touch whose recomputed inputs are *float-identical* provably
/// leaves the completion time unchanged (rate changes always pass through
/// a reschedule, so an unchanged pair means the rate held constant since
/// the event was scheduled) — the engine then keeps the outstanding event
/// instead of invalidating it and pushing a duplicate, which is what cut
/// the simultaneous-400 scenario's stale-event churn (see
/// `ClusterConfig::churn_guard`).
#[derive(Debug, Clone, Copy, Default)]
struct SchedCache {
    /// A current-generation completion event for this resource is in the
    /// event queue at time `at`.
    live: bool,
    fw: f64,
    rate: f64,
    at: SimTime,
}

/// Simulation horizon guard: requests still unfinished at
/// `last_arrival + HORIZON_SLACK_S` are recorded as failures.
const HORIZON_SLACK_S: f64 = 300.0;

/// Per-server fault bookkeeping: how many down windows and hard crashes
/// currently cover the server, plus the composed degradation factor.
/// Depth-counted so overlapping windows only clear when the *last* one
/// ends (the nested-outage bug this PR fixes), and the factor snaps back
/// to exactly 1.0 at depth zero so fault-free rates carry no float
/// residue.
#[derive(Debug, Clone, Copy)]
struct ServerFault {
    down: u32,
    crash: u32,
    degrade: u32,
    degrade_factor: f64,
}

impl Default for ServerFault {
    fn default() -> Self {
        ServerFault {
            down: 0,
            crash: 0,
            degrade: 0,
            degrade_factor: 1.0,
        }
    }
}

/// Incident accounting feeding [`AvailabilityReport`], grouped so both
/// engine substrates (the sequential [`Engine`] and the sharded
/// orchestrator) share the exact counting rules and report assembly.
#[derive(Debug, Default)]
struct IncidentCounters {
    incidents: u64,
    down_servers: usize,
    incident_first_at: Option<SimTime>,
    incident_last_end: Option<SimTime>,
    failed_in_flight: u64,
    requeued_in_flight: u64,
    leaves: u64,
    joins: u64,
    gate_sheds_at_incident: u64,
    gate_sheds_at_recovery: Option<u64>,
}

pub struct Engine<'a> {
    cluster: ClusterSim,
    events: EventQueue<Ev>,
    source: &'a mut dyn ArrivalSource,
    /// Per-request state, indexed by dense arrival order (event payloads
    /// carry these indices). Grows as requests stream in.
    svc: Vec<SvcState>,
    /// The single prefetched arrival; its `Arrival` event is in the heap.
    pending_arrival: Option<ServiceRequest>,
    scheduler: &'a mut dyn Scheduler,
    rng: Rng,
    outcomes: Vec<ServiceOutcome>,
    /// Requests arrived but not yet resolved (done/failed/shed).
    in_flight: usize,
    first_arrival: Option<SimTime>,
    last_arrival: SimTime,
    /// Infinite while the source still has requests; armed to
    /// `last_arrival + HORIZON_SLACK_S` once it is exhausted.
    horizon: SimTime,
    /// Total drops: policy sheds + bounded-queue admission failures,
    /// counted where they happen so horizon-unfinished requests are never
    /// misclassified.
    shed: usize,
    /// Drops from explicit scheduler `Shed` actions.
    policy_shed: usize,
    /// Out-of-range `Assign`/`Defer` targets recovered via the
    /// least-violating fallback (a scheduler bug, surfaced not masked).
    bad_actions: u64,
    /// Scratch scheduler snapshot, refilled in place per decision/feedback
    /// instead of collecting a fresh `ClusterView` per event.
    view: ClusterView,
    /// Scratch reap output, reused across every completion event.
    reap_buf: Vec<PsJob>,
    /// Reschedule guard state per link / per server (see [`SchedCache`]).
    link_sched: Vec<SchedCache>,
    server_sched: Vec<SchedCache>,
    /// From `ClusterConfig::churn_guard`: skip the invalidate+push when a
    /// touch provably left the next completion unchanged.
    churn_guard: bool,
    /// Per-server fault window stack (down/crash depth + degradation).
    fault: Vec<ServerFault>,
    /// Link-flap depth per link: while > 0 the fluctuation process keeps
    /// drawing (stream-preserving) but its draws are not applied.
    link_flap: Vec<u32>,
    crash_policy: CrashPolicy,
    /// Probe period when a health monitor is installed; drives the
    /// self-rearming `Ev::HealthProbe` chain.
    health_period: Option<f64>,
    /// Scratch ground-truth snapshot reused across health probes.
    health_snap: Vec<f64>,
    /// Incident accounting feeding `AvailabilityReport`.
    inc: IncidentCounters,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: &ClusterConfig,
        source: &'a mut dyn ArrivalSource,
        scheduler: &'a mut dyn Scheduler,
    ) -> Self {
        // The empty plan pushes no events and installs no monitor, so this
        // path stays bit-identical to the pre-fault engine
        // (tests/faults_identity.rs pins it).
        Self::new_with_faults(cfg, source, scheduler, &FaultPlan::default())
    }

    /// Build an engine with a chaos layer: the plan's lowered timeline is
    /// pushed as ordinary events *after* the legacy outage seeding (so
    /// outage replays keep identical event sequence numbers), and the
    /// health monitor, when configured, starts its probe chain one period
    /// in.
    pub fn new_with_faults(
        cfg: &ClusterConfig,
        source: &'a mut dyn ArrivalSource,
        scheduler: &'a mut dyn Scheduler,
        plan: &FaultPlan,
    ) -> Self {
        let mut cluster = ClusterSim::new(cfg);
        let mut events = EventQueue::new();
        for (li, link) in cluster.links.iter().enumerate() {
            if link.spec.fluctuation > 0.0 {
                events.push_at(link.spec.fluct_period, Ev::FluctTick { link: li });
            }
        }
        for Outage { server, start, end } in &cfg.outages {
            events.push_at(*start, Ev::OutageStart { server: *server });
            events.push_at(*end, Ev::OutageEnd { server: *server });
        }
        let n_links = cluster.links.len();
        for (at, action) in plan.materialize(cfg.servers.len(), n_links, cfg.seed) {
            events.push_at(at, Ev::Fault { action });
        }
        let health_period = plan.health.map(|hc| {
            cluster.health = Some(HealthMonitor::new(hc, cfg.servers.len()));
            events.push_at(hc.period_s, Ev::HealthProbe);
            hc.period_s
        });
        let view = ClusterView::with_capacity(cfg.servers.len(), cfg.weights);
        // len_hint only sizes buffers (capped so a huge hint cannot force
        // a huge reservation); correctness never depends on it.
        let hint = source.len_hint().unwrap_or(0).min(1 << 20);
        let n_servers = cfg.servers.len();
        let mut engine = Engine {
            cluster,
            events,
            source,
            svc: Vec::with_capacity(hint),
            pending_arrival: None,
            scheduler,
            rng: Rng::new(cfg.seed), // lint: allow(raw-seed) the engine owns the primary stream; side-streams salt off it
            outcomes: Vec::with_capacity(hint),
            in_flight: 0,
            first_arrival: None,
            last_arrival: 0.0,
            horizon: f64::INFINITY,
            shed: 0,
            policy_shed: 0,
            bad_actions: 0,
            view,
            reap_buf: Vec::new(),
            link_sched: vec![SchedCache::default(); n_servers],
            server_sched: vec![SchedCache::default(); n_servers],
            churn_guard: cfg.churn_guard,
            fault: vec![ServerFault::default(); n_servers],
            link_flap: vec![0; n_links],
            crash_policy: plan.crash_policy,
            health_period,
            health_snap: Vec::with_capacity(n_servers),
            inc: IncidentCounters::default(),
        };
        engine.prefetch_arrival();
        engine
    }

    /// Mutable access to the cluster before [`Self::run`] — the hook the
    /// executable-spec identity tests use to swap server `ServiceModel`
    /// implementations (e.g. the pre-trait reference PS model) under an
    /// otherwise identical engine.
    pub fn cluster_mut(&mut self) -> &mut ClusterSim {
        &mut self.cluster
    }

    /// Pull the next request from the source and schedule its arrival, or
    /// arm the horizon guard once the source is exhausted. The invariant —
    /// at most one pending `Arrival` event — is what keeps the event heap
    /// bounded by in-flight concurrency instead of trace length.
    fn prefetch_arrival(&mut self) {
        match self.source.next_arrival() {
            Some(r) => {
                // The ArrivalSource contract: nondecreasing arrival times.
                // An out-of-order request would be silently clamped to the
                // current sim clock by the event queue (changing results),
                // so catch the contract violation in debug builds.
                debug_assert!(
                    r.arrival >= self.last_arrival,
                    "ArrivalSource yielded out-of-order arrival {} after {}",
                    r.arrival,
                    self.last_arrival
                );
                self.events.push_at(r.arrival, Ev::Arrival);
                self.pending_arrival = Some(r);
            }
            None => {
                self.horizon = self.last_arrival + HORIZON_SLACK_S;
            }
        }
    }

    /// Run to completion and summarize.
    pub fn run(mut self) -> RunReport {
        let t0 = Instant::now(); // lint: allow(wall-clock) measures simulator throughput only; no sim behavior reads it
        // Hoisted out of the loop: an env lookup per event costs more than
        // the event handling itself on the million-request path.
        let trace_events = std::env::var("PERLLM_TRACE_EVENTS").is_ok();
        // Every sourced request resolves inside the horizon guard: arrival
        // events fire at times <= last_arrival < horizon, so a horizon
        // break can only strand already-arrived (unfinished) work.
        while self.in_flight > 0 || self.pending_arrival.is_some() {
            let Some((now, ev)) = self.events.pop() else {
                break;
            };
            if now > self.horizon {
                break;
            }
            if trace_events {
                eprintln!("t={now:.6} {ev:?} in_flight={}", self.in_flight);
            }
            self.handle(now, ev);
        }
        let end = self.events.now();
        self.cluster.advance_all(end);

        // Anything still in flight failed the horizon.
        let mut unfinished = 0;
        for st in &self.svc {
            if st.phase != Phase::Done && st.phase != Phase::Failed {
                unfinished += 1;
                self.outcomes.push(ServiceOutcome {
                    id: st.req.id,
                    class: st.req.class,
                    server: st.server.min(self.cluster.servers.len().saturating_sub(1)),
                    tx_time: 0.0,
                    infer_time: 0.0,
                    processing_time: f64::INFINITY,
                    // A horizon-stranded request may still have produced
                    // its first token (admitted, mid-decode): judge the
                    // TTFT constraint on the stamped instant when it falls
                    // inside the horizon, `+inf` only when no token ever
                    // landed.
                    ttft_time: if st.first_token_at <= end {
                        st.first_token_at - st.req.arrival
                    } else {
                        f64::INFINITY
                    },
                    slo: st.req.slo,
                    energy_j: st.tx_energy_j,
                    tokens: 0,
                    completed_at: end,
                });
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        let energy = self.cluster.energy();
        let tokens = self.cluster.tokens_served();
        let diagnostics = self.scheduler.diagnostics();
        let q = QueueStats {
            processed: self.events.processed(),
            stale: self.events.stale(),
            stale_ratio: self.events.stale_ratio(),
            peak: self.events.peak_len(),
        };
        // Fold per-server prefix-cache counters in global index order —
        // the same order the sharded engine reassembles from its
        // `ShardFinish` parts.
        let mut cache = CacheCounters::default();
        for srv in &self.cluster.servers {
            cache.absorb(&srv.cache);
        }
        assemble_report(
            self.scheduler.name(),
            self.outcomes,
            energy,
            end,
            self.first_arrival.unwrap_or(0.0),
            tokens,
            unfinished,
            self.shed,
            self.policy_shed,
            self.bad_actions,
            diagnostics,
            &self.inc,
            wall,
            q,
            cache,
        )
    }

    fn handle(&mut self, now: SimTime, ev: Ev) {
        // Events arrive in time order, so this keeps the cluster's
        // observation clock (used by the unified `ViewSource` snapshots)
        // current on every path.
        self.cluster.now = now;
        match ev {
            Ev::Arrival => {
                let Some(req) = self.pending_arrival.take() else {
                    // One arrival event exists per prefetched request, so
                    // this cannot fire on a well-formed run; a stray event
                    // must not kill a million-request simulation.
                    log::error!("Arrival event with no pending request; dropping event");
                    return;
                };
                if self.first_arrival.is_none() {
                    self.first_arrival = Some(req.arrival);
                }
                self.last_arrival = req.arrival;
                self.in_flight += 1;
                self.prefetch_arrival();

                self.cluster.advance_all(now);
                self.cluster.view_into(&req, &mut self.view);
                let action = self.scheduler.decide(&req, &self.view);
                let idx = self.svc.len();
                self.svc.push(SvcState {
                    req,
                    server: usize::MAX,
                    phase: Phase::Pending,
                    dispatched_at: 0.0,
                    upload_done_at: 0.0,
                    compute_started_at: 0.0,
                    first_token_at: f64::INFINITY,
                    tx_energy_j: 0.0,
                });
                self.act_on(now, idx, action);
            }
            Ev::Dispatch { svc, server } => {
                self.dispatch(now, svc, server);
            }
            Ev::LinkDone { link, gen } => {
                if !self.cluster.links[link].gen.is_current(gen) {
                    self.events.note_stale();
                    return;
                }
                // The outstanding completion event is consumed: the guard
                // cache must not claim one is still scheduled.
                self.link_sched[link].live = false;
                self.cluster.links[link].advance_to(now);
                let rate = self.cluster.links[link].per_flow_rate();
                // Reuse the scratch buffer across events (take/put-back so
                // the borrow checker allows pushing events while iterating).
                let mut done = std::mem::take(&mut self.reap_buf);
                self.cluster.links[link].queue.reap_into(now, rate, &mut done);
                let rtt = self.cluster.links[link].spec.rtt_s;
                for job in &done {
                    let i = job.id as usize;
                    self.svc[i].upload_done_at = now + rtt;
                    self.events.push_in(
                        rtt,
                        Ev::ComputeArrive {
                            svc: i,
                            server: self.svc[i].server,
                        },
                    );
                }
                self.reap_buf = done;
                self.reschedule_link(link);
            }
            Ev::ComputeArrive { svc, server } => {
                self.cluster.land_in_flight(server, &self.svc[svc].req);
                // Landing on a hard-crashed or departed server is an
                // explicit casualty — the upload was already paid for and
                // the router learns about it through feedback. Soft
                // outages keep the legacy behavior (admit and stall).
                if self.fault[server].crash > 0 || !self.cluster.accepting[server] {
                    self.cluster.servers[server].advance_to(now);
                    if self.fault[server].crash > 0 && self.crash_policy == CrashPolicy::Requeue {
                        self.inc.requeued_in_flight += 1;
                        self.requeue(now, svc);
                    } else {
                        self.inc.failed_in_flight += 1;
                        self.fail(now, svc, server);
                    }
                    return;
                }
                let srv = &mut self.cluster.servers[server];
                srv.advance_to(now);
                if srv.would_drop() {
                    // Bounded queue: load shedding (admission failure). The
                    // upload energy is already spent — that waste is the
                    // congestion cost the paper's Figure 2 measures.
                    self.fail(now, svc, server);
                    return;
                }
                // Stamp the first-token instant from the model's own
                // prediction *at admission* (extra in-flight work excluded:
                // this request is the one landing). Pure float work — no
                // RNG, no events — so completion-only runs stay
                // bit-identical to pre-PR5.
                let ttft_s = srv.predict(&self.svc[svc].req, 0, 0.0).ttft_s;
                self.svc[svc].first_token_at = now + ttft_s;
                srv.admit(svc as u64, &self.svc[svc].req, now);
                self.cluster.refresh_admissibility(server);
                self.svc[svc].phase = Phase::Computing;
                self.svc[svc].compute_started_at = now;
                self.reschedule_server(server);
            }
            Ev::ServerDone { server, gen } => {
                if !self.cluster.servers[server].gen.is_current(gen) {
                    self.events.note_stale();
                    return;
                }
                // Consumed: see the LinkDone cache note.
                self.server_sched[server].live = false;
                self.cluster.servers[server].advance_to(now);
                let mut done = std::mem::take(&mut self.reap_buf);
                self.cluster.servers[server].reap_into(now, &mut done);
                self.cluster.refresh_admissibility(server);
                for job in &done {
                    self.complete(now, job.id as usize, server, job.energy_j);
                }
                self.reap_buf = done;
                self.reschedule_server(server);
            }
            Ev::FluctTick { link } => {
                let l = &mut self.cluster.links[link];
                l.advance_to(now);
                let a = l.spec.fluctuation;
                // Always consume the draw so a flap never desynchronizes
                // the fluctuation stream; only apply it when no flap
                // window pins the multiplier.
                let m = self.rng.uniform(1.0 - a, 1.0 + a);
                if self.link_flap[link] == 0 {
                    l.mult = m;
                }
                let period = l.spec.fluct_period;
                self.reschedule_link(link);
                self.events.push_in(period, Ev::FluctTick { link });
            }
            Ev::OutageStart { server } => self.fault_down(now, server, false),
            Ev::OutageEnd { server } => self.fault_up(now, server, false),
            Ev::Fault { action } => self.apply_fault(now, action),
            Ev::HealthProbe => self.health_probe(now),
        }
    }

    /// Execute a scheduler [`Action`] for request `idx` (shared by the
    /// arrival path and crash requeues — pure code motion from the
    /// `Ev::Arrival` arm).
    fn act_on(&mut self, now: SimTime, idx: usize, action: Action) {
        match action {
            Action::Assign { server } => {
                let server = self.checked_server(idx, server);
                self.svc[idx].server = server;
                self.stamp_kv_transfer(idx, server);
                self.dispatch(now, idx, server);
            }
            Action::Defer { server, delay_s } => {
                let server = self.checked_server(idx, server);
                self.svc[idx].server = server;
                self.stamp_kv_transfer(idx, server);
                if delay_s.is_finite() && delay_s > 0.0 {
                    self.events.push_in(delay_s, Ev::Dispatch { svc: idx, server });
                } else {
                    self.dispatch(now, idx, server);
                }
            }
            Action::Shed { reason } => self.shed_at_decision(now, idx, reason),
        }
    }

    /// KV-transfer economics (PR 10): the decision just routed a session
    /// turn to `server`. If some *other* server holds more of the
    /// session's KV prefix than the target does, shipping the missing
    /// tail over the target's link can beat re-prefilling it — take the
    /// deal exactly when the link's solo transfer time undercuts the
    /// prefill time it saves, and stamp the shipped token count on the
    /// stored request so admission (`ServerSim::admit`) sees the prefix
    /// as warm and the dispatch payload carries the extra bytes. Derived
    /// purely from the decision-time view (`prefix_hit_tokens` is the
    /// per-candidate usable prefix), so the sharded orchestrator makes
    /// the identical call from its snapshot views. Single-shot requests
    /// return on the first branch: the pre-session instruction stream is
    /// untouched.
    fn stamp_kv_transfer(&mut self, idx: usize, server: usize) {
        let Some(sess) = self.svc[idx].req.session else {
            return;
        };
        if sess.prefix_tokens == 0 {
            return;
        }
        let local = self.view.servers[server].prefix_hit_tokens;
        let mut remote = 0.0f64;
        for (j, sv) in self.view.servers.iter().enumerate() {
            if j != server && sv.prefix_hit_tokens > remote {
                remote = sv.prefix_hit_tokens;
            }
        }
        let ship = remote - local;
        if ship < 1.0 {
            return;
        }
        let ship_tokens = ship as u32;
        let xfer_s = self.cluster.links[server]
            .spec
            .solo_time(SessionRef::kv_bytes(ship_tokens));
        let saved_s = ship_tokens as f64 / self.cluster.servers[server].spec.prefill_rate;
        if xfer_s < saved_s {
            if let Some(s) = self.svc[idx].req.session.as_mut() {
                s.xfer_tokens = ship_tokens;
            }
        }
    }

    /// Replay one lowered fault-plan action on the shared event clock.
    fn apply_fault(&mut self, now: SimTime, action: FaultAction) {
        match action {
            FaultAction::Down { server, crash } => self.fault_down(now, server, crash),
            FaultAction::Up { server, crash } => self.fault_up(now, server, crash),
            FaultAction::DegradeStart { server, factor } => {
                self.cluster.servers[server].advance_to(now);
                let f = &mut self.fault[server];
                f.degrade += 1;
                f.degrade_factor *= factor;
                self.apply_rate(server);
                self.reschedule_server(server);
            }
            FaultAction::DegradeEnd { server, factor } => {
                self.cluster.servers[server].advance_to(now);
                let f = &mut self.fault[server];
                f.degrade -= 1;
                if f.degrade == 0 {
                    // Snap back to exactly 1.0: dividing the factor out
                    // would leave float residue on the healthy rate.
                    f.degrade_factor = 1.0;
                } else {
                    f.degrade_factor /= factor;
                }
                self.apply_rate(server);
                self.reschedule_server(server);
            }
            FaultAction::FlapStart { link, factor } => {
                self.link_flap[link] += 1;
                let l = &mut self.cluster.links[link];
                l.advance_to(now);
                l.mult = factor;
                self.reschedule_link(link);
            }
            FaultAction::FlapEnd { link } => {
                self.link_flap[link] -= 1;
                if self.link_flap[link] == 0 {
                    let l = &mut self.cluster.links[link];
                    l.advance_to(now);
                    l.mult = 1.0;
                    self.reschedule_link(link);
                }
            }
            FaultAction::Leave { server } => {
                self.cluster.accepting[server] = false;
                self.cluster.refresh_admissibility(server);
                self.inc.leaves += 1;
                self.scheduler.fleet_event(&FleetEvent::Left { server }, now);
            }
            FaultAction::Join { server } => {
                self.cluster.accepting[server] = true;
                self.cluster.refresh_admissibility(server);
                self.inc.joins += 1;
                self.scheduler.fleet_event(&FleetEvent::Joined { server }, now);
            }
        }
    }

    /// Effective service rate from the fault stack: a covering down
    /// window wins, otherwise the composed degradation (exactly 1.0 when
    /// nothing covers the server).
    fn apply_rate(&mut self, server: usize) {
        let f = self.fault[server];
        self.cluster.servers[server].rate_mult = if f.down > 0 { 0.0 } else { f.degrade_factor };
    }

    /// One more down window covers `server`. Shared by the legacy outage
    /// events and the fault layer: same advance/set/reschedule order as
    /// the pre-PR6 `OutageStart` arm, so single-window replays stay
    /// bit-identical.
    fn fault_down(&mut self, now: SimTime, server: usize, crash: bool) {
        self.cluster.servers[server].advance_to(now);
        self.fault[server].down += 1;
        if crash {
            self.fault[server].crash += 1;
        }
        self.apply_rate(server);
        self.reschedule_server(server);
        if crash {
            self.crash_in_flight(now, server);
        }
        if self.fault[server].down == 1 {
            self.inc.incidents += 1;
            if self.inc.down_servers == 0 && self.inc.incident_first_at.is_none() {
                self.inc.incident_first_at = Some(now);
                self.inc.gate_sheds_at_incident = self.current_gate_sheds();
            }
            self.inc.down_servers += 1;
            self.scheduler.fleet_event(&FleetEvent::Down { server }, now);
        }
    }

    /// One covering window ends. Only when the stack empties does the
    /// rate return to the composed healthy value — the nested-outage fix:
    /// the old `OutageEnd` arm blindly restored `rate_mult = 1.0`, so an
    /// inner window's end revived a server still covered by an outer one.
    fn fault_up(&mut self, now: SimTime, server: usize, crash: bool) {
        self.cluster.servers[server].advance_to(now);
        let f = &mut self.fault[server];
        debug_assert!(f.down > 0, "Up without covering Down on server {server}");
        f.down = f.down.saturating_sub(1);
        if crash {
            f.crash = f.crash.saturating_sub(1);
        }
        self.apply_rate(server);
        self.reschedule_server(server);
        if self.fault[server].down == 0 {
            self.inc.down_servers = self.inc.down_servers.saturating_sub(1);
            if self.inc.down_servers == 0 {
                self.inc.incident_last_end = Some(now);
                self.inc.gate_sheds_at_recovery = Some(self.current_gate_sheds());
            }
            self.scheduler.fleet_event(&FleetEvent::Up { server }, now);
        }
    }

    /// Hard-crash cleanup: every request computing on the server is a
    /// casualty (failed or requeued per [`CrashPolicy`]) and the server
    /// restarts cold — its service-model state is rebuilt, so queue
    /// contents and batch history are lost while cumulative accounting
    /// (tokens served, energy) survives. The linear scan over request
    /// state is fine even on million-request runs: crashes are
    /// O(incidents), not O(events).
    fn crash_in_flight(&mut self, now: SimTime, server: usize) {
        let victims: Vec<usize> = (0..self.svc.len())
            .filter(|&i| self.svc[i].phase == Phase::Computing && self.svc[i].server == server)
            .collect();
        self.cluster.servers[server].crash_reset(now);
        self.reschedule_server(server);
        self.cluster.refresh_admissibility(server);
        for i in victims {
            match self.crash_policy {
                CrashPolicy::Fail => {
                    self.inc.failed_in_flight += 1;
                    self.fail(now, i, server);
                }
                CrashPolicy::Requeue => {
                    self.inc.requeued_in_flight += 1;
                    self.requeue(now, i);
                }
            }
        }
    }

    /// Bounce a crash casualty back through the scheduler: the request
    /// keeps its identity and arrival clock (its SLO keeps ticking) and
    /// pays a fresh upload to wherever it lands next.
    fn requeue(&mut self, now: SimTime, i: usize) {
        self.svc[i].phase = Phase::Pending;
        self.svc[i].server = usize::MAX;
        self.svc[i].first_token_at = f64::INFINITY;
        // Any stamped KV transfer died with the crashed placement: the
        // fresh decision re-derives it (a stale stamp would both warm
        // the wrong server's view and bill phantom bytes).
        if let Some(s) = self.svc[i].req.session.as_mut() {
            s.xfer_tokens = 0;
        }
        self.cluster.advance_all(now);
        ViewSource::view_into(&self.cluster, &self.svc[i].req, &mut self.view);
        let action = self.scheduler.decide(&self.svc[i].req, &self.view);
        self.act_on(now, i, action);
    }

    /// Snapshot ground truth into the lagged monitor and re-arm. The
    /// chain only exists when a monitor is configured, and the run loop's
    /// exit condition ignores it, so it never extends a run past its last
    /// real work.
    fn health_probe(&mut self, now: SimTime) {
        let Some(period) = self.health_period else {
            return;
        };
        self.health_snap.clear();
        for (i, srv) in self.cluster.servers.iter().enumerate() {
            self.health_snap
                .push(if self.cluster.accepting[i] { srv.rate_mult } else { 0.0 });
        }
        if let Some(h) = self.cluster.health.as_mut() {
            h.probe(now, &self.health_snap);
        }
        self.events.push_in(period, Ev::HealthProbe);
    }

    /// Cumulative admission-gate door sheds right now (diagnostics
    /// scrape; only called at incident boundaries).
    fn current_gate_sheds(&self) -> u64 {
        self.scheduler
            .diagnostics()
            .iter()
            .find_map(|(k, v)| (k == "gate_sheds").then_some(*v as u64))
            .unwrap_or(0)
    }

    /// Validate a scheduler-chosen server index. An out-of-range target is
    /// a scheduler bug: log it and recover with the paper's
    /// least-violating fallback rather than masking it with a clamp.
    fn checked_server(&mut self, idx: usize, server: usize) -> usize {
        if server < self.cluster.servers.len() {
            return server;
        }
        self.bad_actions += 1;
        log::warn!(
            "scheduler {:?} chose out-of-range server {server} (cluster has {}); \
             falling back to least-violating",
            self.scheduler.name(),
            self.cluster.servers.len()
        );
        self.view.least_violating(&self.svc[idx].req)
    }

    fn dispatch(&mut self, now: SimTime, i: usize, server: usize) {
        self.cluster.dispatch_in_flight(server, &self.svc[i].req);
        // A stamped KV transfer rides the same upload: its bytes share
        // the link fairly and cost tx energy like any other payload.
        let payload = self.svc[i].req.payload_bytes
            + match self.svc[i].req.session {
                Some(s) => SessionRef::kv_bytes(s.xfer_tokens),
                None => 0,
            };
        let link = &mut self.cluster.links[server];
        link.advance_to(now);
        link.queue.push(i as u64, payload as f64, now);
        let tx_energy_j = link.spec.tx_energy(payload);
        let st = &mut self.svc[i];
        st.phase = Phase::Uploading;
        st.dispatched_at = now;
        st.tx_energy_j = tx_energy_j;
        self.reschedule_link(server);
    }

    /// (Re)schedule a link's earliest upload completion. Guarded: when the
    /// recomputed (finish-work top, per-flow rate) pair is float-identical
    /// to what the outstanding event was scheduled from, the completion
    /// time cannot have moved (rate changes always pass through here, so
    /// an unchanged pair certifies the rate held constant since) — keep
    /// the event instead of stranding it as a stale pop and pushing a
    /// duplicate. This is what removes the re-scheduling churn of
    /// same-instant dispatch bursts: a capped shared uplink absorbing new
    /// flows below its per-flow-cap knee, or a full batch queue taking
    /// waiters, used to invalidate on every touch.
    fn reschedule_link(&mut self, li: usize) {
        let link = &mut self.cluster.links[li];
        let rate = link.per_flow_rate();
        let cache = &mut self.link_sched[li];
        match link.queue.peek_finish_work() {
            Some(fw) if rate > 0.0 => {
                if cache.live && cache.fw == fw && cache.rate == rate {
                    if self.churn_guard {
                        return;
                    }
                    // Guard off (churn-regression baseline): re-push at the
                    // *cached* time so the event sequence is bit-identical
                    // to the guarded run, modulo the extra stale pops the
                    // test pins.
                    let gen = link.gen.invalidate();
                    self.events.push_at(cache.at, Ev::LinkDone { link: li, gen });
                    return;
                }
                let gen = link.gen.invalidate();
                let dt = (fw - link.queue.attained()).max(0.0) / rate;
                let at = self.events.now() + dt;
                self.events.push_at(at, Ev::LinkDone { link: li, gen });
                *cache = SchedCache {
                    live: true,
                    fw,
                    rate,
                    at,
                };
            }
            _ => {
                link.gen.invalidate();
                cache.live = false;
            }
        }
    }

    /// Server twin of [`Self::reschedule_link`], same guard — expressed
    /// against the model-agnostic [`ServerSim::completion_key`] /
    /// [`ServerSim::next_completion_in`] pair. For the PS model the key
    /// is exactly the historical (finish-work top, per-job rate) pair and
    /// the completion estimate the same float expression, so PS runs are
    /// bit-identical to the pre-trait engine (pinned by
    /// `tests/service_model_identity.rs`).
    fn reschedule_server(&mut self, si: usize) {
        let srv = &mut self.cluster.servers[si];
        let cache = &mut self.server_sched[si];
        match srv.completion_key() {
            Some((fw, rate)) => {
                if cache.live && cache.fw == fw && cache.rate == rate {
                    if self.churn_guard {
                        return;
                    }
                    let gen = srv.gen.invalidate();
                    self.events.push_at(cache.at, Ev::ServerDone { server: si, gen });
                    return;
                }
                let gen = srv.gen.invalidate();
                let Some(dt) = srv.next_completion_in() else {
                    // completion_key() and next_completion_in() are Some
                    // together for every service model; recover by leaving
                    // the server descheduled rather than killing the run.
                    log::error!("server {si}: completion key without completion estimate");
                    cache.live = false;
                    return;
                };
                let at = self.events.now() + dt;
                self.events.push_at(at, Ev::ServerDone { server: si, gen });
                *cache = SchedCache {
                    live: true,
                    fw,
                    rate,
                    at,
                };
            }
            None => {
                srv.gen.invalidate();
                cache.live = false;
            }
        }
    }

    /// Record an explicit scheduler shed: the request is resolved on the
    /// spot as dropped — no server involved, no energy spent — and the
    /// policy receives bandit feedback for it (counted exactly once).
    fn shed_at_decision(&mut self, now: SimTime, i: usize, _reason: ShedReason) {
        self.svc[i].phase = Phase::Failed;
        self.shed += 1;
        self.policy_shed += 1;
        let outcome = ServiceOutcome::shed(&self.svc[i].req, now);
        self.in_flight -= 1;
        // The decision-time view in `self.view` is still current: no
        // cluster state changed between decide() and the shed.
        self.scheduler.feedback(&outcome, &self.view);
        self.outcomes.push(outcome);
    }

    /// Record a queue-admission shed: failed outcome, transmission energy
    /// only (already spent on the upload).
    fn fail(&mut self, now: SimTime, i: usize, server: usize) {
        self.shed += 1;
        let st = &mut self.svc[i];
        st.phase = Phase::Failed;
        let outcome = ServiceOutcome {
            id: st.req.id,
            class: st.req.class,
            server,
            tx_time: st.upload_done_at - st.dispatched_at,
            infer_time: 0.0,
            processing_time: f64::INFINITY,
            ttft_time: f64::INFINITY,
            slo: st.req.slo,
            energy_j: st.tx_energy_j,
            tokens: 0,
            completed_at: now,
        };
        self.in_flight -= 1;
        // Advance the whole cluster before snapshotting: the feedback view
        // must show backlogs/occupancy at `now`, not frozen at each
        // server's last-touched time (the decision path at
        // `Ev::Arrival` does the same; `advance_all` early-outs when a
        // same-instant completion batch already advanced).
        self.cluster.advance_all(now);
        ViewSource::view_into(&self.cluster, &self.svc[i].req, &mut self.view);
        self.scheduler.feedback(&outcome, &self.view);
        self.outcomes.push(outcome);
    }

    fn complete(&mut self, now: SimTime, i: usize, server: usize, infer_energy_j: f64) {
        let st = &mut self.svc[i];
        st.phase = Phase::Done;
        let tokens = st.req.total_tokens();
        let outcome = ServiceOutcome {
            id: st.req.id,
            class: st.req.class,
            server,
            tx_time: st.upload_done_at - st.dispatched_at,
            infer_time: now - st.compute_started_at,
            processing_time: now - st.req.arrival,
            // A first token cannot land after the whole answer did: clamp
            // the admission-time estimate to the realized completion.
            ttft_time: st.first_token_at.min(now) - st.req.arrival,
            slo: st.req.slo,
            energy_j: st.tx_energy_j + infer_energy_j,
            tokens,
            completed_at: now,
        };
        self.cluster.servers[server].tokens_served += tokens;
        self.in_flight -= 1;
        // Fresh snapshot at `now` for the bandit (see the note in `fail`).
        self.cluster.advance_all(now);
        ViewSource::view_into(&self.cluster, &self.svc[i].req, &mut self.view);
        self.scheduler.feedback(&outcome, &self.view);
        self.outcomes.push(outcome);
    }
}

/// Event-queue accounting for one run, merged across however many queues
/// the substrate used (one for the sequential engine; one global + one
/// per shard for the sharded engine).
struct QueueStats {
    processed: u64,
    stale: u64,
    stale_ratio: f64,
    peak: usize,
}

/// Fold outcomes and accounting into a [`RunReport`] — pure code motion
/// from the sequential `run()` tail, shared with the sharded engine so
/// both substrates assemble their reports through byte-identical
/// arithmetic (same fold orders, same edge-case handling).
#[allow(clippy::too_many_arguments)]
fn assemble_report(
    name: &'static str,
    outcomes: Vec<ServiceOutcome>,
    energy: EnergyBreakdown,
    end: SimTime,
    first_arrival: f64,
    tokens: u64,
    unfinished: usize,
    shed: usize,
    policy_shed: usize,
    bad_actions: u64,
    mut diagnostics: Vec<(String, f64)>,
    inc: &IncidentCounters,
    wall: f64,
    q: QueueStats,
    cache: CacheCounters,
) -> RunReport {
    let mut proc = Running::new();
    let mut pcts = Percentiles::new();
    let mut ok = 0usize;
    let mut late = 0usize;
    let mut ttft_attainment = [Attainment::default(); 4];
    let mut completion_attainment = [Attainment::default(); 4];
    let (mut v_ttft, mut v_completion, mut v_energy) = (0usize, 0usize, 0usize);
    for o in &outcomes {
        if o.processing_time.is_finite() {
            proc.push(o.processing_time);
            pcts.push(o.processing_time);
            if !o.success() {
                late += 1;
            }
        }
        if o.success() {
            ok += 1;
        }
        // Per-constraint attainment: judged on every outcome carrying
        // the constraint — a shed/dropped/unfinished request missed
        // whatever its contract promised.
        if let Some(met) = o.ttft_met() {
            ttft_attainment[o.class.index()].add(met);
            v_ttft += !met as usize;
        }
        if let Some(met) = o.completion_met() {
            completion_attainment[o.class.index()].add(met);
            v_completion += !met as usize;
        }
        if let Some(met) = o.energy_met() {
            v_energy += !met as usize;
        }
    }
    // Shed requests are counted at shed time (policy sheds and queue
    // admission failures), not inferred from outcome fields:
    // horizon-unfinished requests also carry (tokens 0, infer 0) and
    // used to be double-counted here.
    let dropped = shed;
    let makespan = (end - first_arrival).max(1e-9);
    let n = outcomes.len().max(1);
    // Admission-gate wiring: surface the gate's door-shed counter as a
    // first-class report field (stays 0 without a gate installed).
    let gate_sheds = diagnostics
        .iter()
        .find_map(|(k, v)| (k == "gate_sheds").then_some(*v as u64))
        .unwrap_or(0);
    if bad_actions > 0 {
        // Surface scheduler bugs (out-of-range targets) in the report
        // instead of hiding them behind the fallback.
        diagnostics.push(("engine_bad_actions".into(), bad_actions as f64));
    }
    let availability = if inc.incidents > 0 || inc.leaves > 0 || inc.joins > 0 {
        let start = inc.incident_first_at.unwrap_or(f64::INFINITY);
        // "Recovered" means the fleet is fully up at run end; a
        // mid-run recovery followed by a still-open incident leaves
        // the during-phase open-ended.
        let end_rec = if inc.down_servers == 0 {
            inc.incident_last_end.unwrap_or(f64::INFINITY)
        } else {
            f64::INFINITY
        };
        let mut attainment = [Attainment::default(); 3];
        for o in &outcomes {
            let ph = if o.completed_at < start {
                0
            } else if o.completed_at < end_rec {
                1
            } else {
                2
            };
            attainment[ph].add(o.success());
        }
        // Time to recover: first instant the cumulative post-recovery
        // success rate (>= 20 outcomes) reaches 90 % of the
        // pre-incident rate. Outcomes are pushed in completion order,
        // so this pass is chronological.
        let pre_rate = attainment[0].rate();
        let mut ttr = f64::INFINITY;
        if end_rec.is_finite() && pre_rate.is_finite() {
            let (mut met, mut total) = (0usize, 0usize);
            for o in &outcomes {
                if o.completed_at < end_rec {
                    continue;
                }
                total += 1;
                met += o.success() as usize;
                if total >= 20 && met as f64 / total as f64 >= 0.9 * pre_rate {
                    ttr = o.completed_at - end_rec;
                    break;
                }
            }
        }
        let (g1, g2) = match inc.incident_first_at {
            // Membership churn only: every gate shed is "pre".
            None => (gate_sheds, gate_sheds),
            Some(_) => {
                let g1 = inc.gate_sheds_at_incident.min(gate_sheds);
                let g2 = inc
                    .gate_sheds_at_recovery
                    .unwrap_or(gate_sheds)
                    .clamp(g1, gate_sheds);
                (g1, g2)
            }
        };
        Some(AvailabilityReport {
            incidents: inc.incidents,
            incident_start_s: start,
            incident_end_s: end_rec,
            failed_in_flight: inc.failed_in_flight,
            requeued_in_flight: inc.requeued_in_flight,
            leaves: inc.leaves,
            joins: inc.joins,
            attainment,
            time_to_recover_s: ttr,
            gate_sheds_by_phase: [g1, g2 - g1, gate_sheds - g2],
        })
    } else {
        None
    };
    RunReport {
        scheduler: name,
        // Zero successes have no per-success energy: infinity, not
        // "total energy relabeled" (`summary_row` renders it as "—").
        energy_per_success_j: if ok == 0 {
            f64::INFINITY
        } else {
            energy.total_j() / ok as f64
        },
        energy,
        makespan_s: makespan,
        throughput_tok_s: tokens as f64 / makespan,
        success_rate: ok as f64 / n as f64,
        mean_processing_s: proc.mean(),
        p95_processing_s: pcts.p95(),
        unfinished,
        dropped,
        dropped_by_policy: policy_shed,
        late,
        ttft_attainment,
        completion_attainment,
        slo_ttft_violations: v_ttft,
        slo_completion_violations: v_completion,
        slo_energy_violations: v_energy,
        gate_sheds,
        availability,
        diagnostics,
        wall_s: wall,
        events_processed: q.processed,
        events_per_sec: q.processed as f64 / wall.max(1e-9),
        stale_events: q.stale,
        stale_ratio: q.stale_ratio,
        peak_event_queue_len: q.peak,
        shard_perf: None,
        cache,
        outcomes,
    }
}

/// Convenience: run one (config, trace, scheduler) combination from an
/// in-memory trace. The trace is streamed through a [`TraceSource`], so
/// even this path keeps the event heap bounded.
///
/// The trace must be sorted by `arrival` (everything `generate` produces
/// is). Out-of-order arrivals violate the [`ArrivalSource`] contract:
/// debug builds assert, release builds clamp them to the current sim
/// clock.
pub fn simulate(
    cfg: &ClusterConfig,
    trace: &[ServiceRequest],
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    let mut source = TraceSource::new(trace);
    Engine::new(cfg, &mut source, scheduler).run()
}

/// Run one (config, arrival-source, scheduler) combination without ever
/// materializing the workload — the entry point for million-request runs.
pub fn simulate_stream(
    cfg: &ClusterConfig,
    source: &mut dyn ArrivalSource,
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    Engine::new(cfg, source, scheduler).run()
}

/// [`simulate`] with a chaos layer: replay `plan` on top of the config.
pub fn simulate_faulted(
    cfg: &ClusterConfig,
    plan: &FaultPlan,
    trace: &[ServiceRequest],
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    let mut source = TraceSource::new(trace);
    Engine::new_with_faults(cfg, &mut source, scheduler, plan).run()
}

/// [`simulate_stream`] with a chaos layer — the entry point the chaos
/// scenarios and `paper_scale_sim --faults` use.
pub fn simulate_stream_faulted(
    cfg: &ClusterConfig,
    plan: &FaultPlan,
    source: &mut dyn ArrivalSource,
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    Engine::new_with_faults(cfg, source, scheduler, plan).run()
}

// ---------------------------------------------------------------------------
// Sharded parallel engine. The shard side (per-tier worker state machine)
// and the synchronization-protocol documentation live in sim/shard.rs;
// this section is the orchestrator: the global calendar, the settle loop,
// and the merge-barrier handlers that mirror the sequential `handle()`
// arms one for one.
// ---------------------------------------------------------------------------

/// Orchestrator-owned events: everything that touches the scheduler or
/// spans shards. Pure physics events (`LinkDone`/`ServerDone`/
/// `ComputeArrive`/`FluctTick`) live in the shard-local queues.
#[derive(Debug, Clone, Copy)]
enum GlobalEv {
    /// The prefetched request arrives at the router (at most one pending).
    Arrival,
    /// Deferred dispatch of service id to (global) server.
    Dispatch { svc: usize, server: usize },
    OutageStart { server: usize },
    OutageEnd { server: usize },
    /// Replay one lowered fault-plan action (global indices).
    Fault { action: FaultAction },
    /// Probe ground-truth health across all shards; re-arms itself.
    HealthProbe,
}

/// Orchestrator-side request state. Flow timing (dispatch/upload/compute
/// instants) lives on the owning shard; the orchestrator keeps only the
/// scheduling phase plus what the horizon-stranded outcome pass needs.
struct GSvc {
    req: ServiceRequest,
    /// Global server of the last dispatch decision (`usize::MAX` while
    /// pending, mirroring the sequential `SvcState`).
    server: usize,
    phase: Phase,
    /// Mirror of the sequential `SvcState::tx_energy_j`: recomputed at
    /// every dispatch from the link spec (a pure function of the payload,
    /// so float-identical to the shard's own stamp) and deliberately NOT
    /// reset on requeue — a horizon-stranded requeued request still
    /// reports the energy of its last upload.
    tx_energy_j: f64,
}

/// One worker thread's command/reply endpoints plus its server range and
/// sync-protocol counters (the raw inputs of [`ShardPerf`]). Counters
/// live in `Cell`s so the shared-ref send/recv paths stay untouched.
struct ShardHandle {
    tx: SyncSender<Cmd>,
    rx: Receiver<Reply>,
    lo: usize,
    hi: usize,
    /// `Grant` commands sent to this shard.
    grants: std::cell::Cell<u64>,
    /// Every command/reply round trip (send+recv pairs; the protocol is
    /// strictly 1-in-flight, so counting sends counts exchanges).
    round_trips: std::cell::Cell<u64>,
    /// Orchestrator wall time spent blocked in `recv` on this shard.
    stall_s: std::cell::Cell<f64>,
}

impl ShardHandle {
    fn send(&self, cmd: Cmd) {
        if matches!(cmd, Cmd::Grant { .. }) {
            self.grants.set(self.grants.get() + 1);
        }
        self.round_trips.set(self.round_trips.get() + 1);
        // lint: allow(p1) a dead worker already panicked with the root cause; propagate
        self.tx.send(cmd).expect("shard worker hung up");
    }

    fn recv(&self) -> Reply {
        let t = Instant::now(); // lint: allow(wall-clock) measures barrier stall only; no sim behavior reads it
        // lint: allow(p1) a dead worker already panicked with the root cause; propagate
        let reply = self.rx.recv().expect("shard worker hung up");
        self.stall_s.set(self.stall_s.get() + t.elapsed().as_secs_f64());
        reply
    }
}

/// Re-arm ranks start above every construction stamp (construction uses
/// one shared counter < 2^20), so first-period ticks keep construction
/// (link) order and re-armed ticks order by draw sequence — exactly the
/// sequential queue's push-sequence tie-break.
const FLUCT_REARM_RANK_BASE: u64 = 1 << 32;

/// Orchestrator-side replay of the sequential engine's single bandwidth-
/// fluctuation stream. The sequential engine draws one uniform per
/// `FluctTick` in event-pop order from the engine RNG; shards own no RNG,
/// so this calendar re-enacts that exact pop order (time, then a rank
/// mirroring the sequential tie-break) and ships each tick's multiplier
/// to the owning shard ahead of the grant that will execute it.
struct FluctCal {
    rng: Rng,
    /// `(Key(tick time, rank), global link)` min-heap.
    heap: BinaryHeap<std::cmp::Reverse<(Key, usize)>>,
    next_rank: u64,
    amp: Vec<f64>,
    period: Vec<f64>,
    /// Global link -> (shard, local link).
    owner: Vec<(usize, u32)>,
    /// Drawn-but-unshipped `(local link, multiplier)` values per shard;
    /// buffers recycle through the `Grant`/`Granted` round trip.
    out: Vec<Vec<(u32, f64)>>,
}

impl FluctCal {
    /// Draw every tick with time <= `t` in sequential pop order.
    /// Time-inclusive on purpose: a grant limit at a tick's exact time may
    /// admit it (stamp tie-break), and overshooting merely buffers values
    /// early — the draw order, hence every multiplier, is unchanged.
    fn draw_until(&mut self, t: SimTime) {
        while let Some(&std::cmp::Reverse((k, g))) = self.heap.peek() {
            if k.0 > t {
                break;
            }
            self.heap.pop();
            let a = self.amp[g];
            let m = self.rng.uniform(1.0 - a, 1.0 + a);
            let (s, local) = self.owner[g];
            self.out[s].push((local, m));
            self.heap
                .push(std::cmp::Reverse((Key(k.0 + self.period[g], self.next_rank), g)));
            self.next_rank += 1;
        }
    }
}

/// The conservative-lookahead orchestrator: drives N shard workers from
/// the calling thread, interleaving local grants with merge barriers so
/// that the merged run is bit-identical to the sequential engine on the
/// same inputs (tests/sharded_identity.rs pins it at every shard count).
struct ShardedEngine<'a> {
    cfg: &'a ClusterConfig,
    shards: Vec<ShardHandle>,
    /// Latest status per shard; refreshed by every queue-changing reply.
    statuses: Vec<ShardStatus>,
    global: EventQueue<GlobalEv>,
    source: &'a mut dyn ArrivalSource,
    scheduler: &'a mut dyn Scheduler,
    fluct: FluctCal,
    svc: Vec<GSvc>,
    pending_arrival: Option<ServiceRequest>,
    outcomes: Vec<ServiceOutcome>,
    in_flight: usize,
    first_arrival: Option<SimTime>,
    last_arrival: SimTime,
    horizon: SimTime,
    shed: usize,
    policy_shed: usize,
    bad_actions: u64,
    /// Scratch global snapshot assembled from per-shard slices.
    view: ClusterView,
    /// Mirror of the sequential `ClusterSim`'s view-epoch counter: bumped
    /// exactly once per snapshot fill (same call sites), so schedulers
    /// observe identical version numbers under both substrates.
    view_epoch: u64,
    /// Recycled per-shard (views, admissibility) buffers.
    view_bufs: Vec<(Vec<ServerView>, Vec<bool>)>,
    health: Option<HealthMonitor>,
    health_period: Option<f64>,
    health_snap: Vec<f64>,
    health_bufs: Vec<Vec<f64>>,
    obs_bufs: Vec<Vec<f64>>,
    crash_policy: CrashPolicy,
    inc: IncidentCounters,
    /// Merge-barrier epoch: bumped before every barrier execution. Every
    /// runtime stamp is `(epoch << 32) | counter` (see sim/shard.rs), so
    /// events pushed at barrier N sort after everything epoch N-1 pushed
    /// at the same float time — the sequential push-order tie-break.
    epoch: u64,
    /// Orchestrator stamp counter within the current epoch. Starts at the
    /// construction counter (epoch 0 continues the seeding sequence) and
    /// resets to 0 at each barrier.
    orch_k: u64,
    /// Time of the last executed barrier — the sharded equivalent of the
    /// sequential queue clock for snapshot stamps.
    clock: SimTime,
    /// Set when the next event sits past the horizon: the sequential
    /// engine pops that event (advancing its clock) before breaking, so
    /// its time is the run-end clock.
    past_horizon: Option<SimTime>,
}

impl<'a> ShardedEngine<'a> {
    fn next_stamp(&mut self) -> u64 {
        let s = orch_stamp(self.epoch, self.orch_k);
        self.orch_k += 1;
        s
    }

    /// Sequential `prefetch_arrival`, stamped.
    fn prefetch_arrival(&mut self) {
        match self.source.next_arrival() {
            Some(r) => {
                debug_assert!(
                    r.arrival >= self.last_arrival,
                    "ArrivalSource yielded out-of-order arrival {} after {}",
                    r.arrival,
                    self.last_arrival
                );
                let stamp = self.next_stamp();
                self.global.push_at_stamped(r.arrival, stamp, GlobalEv::Arrival);
                self.pending_arrival = Some(r);
            }
            None => {
                self.horizon = self.last_arrival + HORIZON_SLACK_S;
            }
        }
    }

    fn shard_of(&self, server: usize) -> usize {
        self.shards
            .iter()
            .position(|h| h.lo <= server && server < h.hi)
            // lint: allow(p1) shard ranges partition [0, n_servers) by construction
            .expect("server inside the shard plan")
    }

    fn run(mut self, t0: Instant) -> RunReport {
        while self.in_flight > 0 || self.pending_arrival.is_some() {
            self.settle();
            // The globally minimal revealed event: the next merge barrier.
            let mut min: Option<(Key, Option<usize>)> =
                self.global.peek().map(|(t, s, _)| (Key(t, s), None));
            for (s, st) in self.statuses.iter().enumerate() {
                if let Some((k, _)) = st.head {
                    if min.map_or(true, |(m, _)| k < m) {
                        min = Some((k, Some(s)));
                    }
                }
            }
            let Some((key, owner)) = min else {
                // Every queue drained with work notionally in flight: the
                // sequential engine breaks the same way (pop fails).
                break;
            };
            if key.0 > self.horizon {
                self.past_horizon = Some(key.0);
                break;
            }
            self.epoch += 1;
            self.orch_k = 0;
            self.clock = key.0;
            match owner {
                None => {
                    if let Some((now, ev)) = self.global.pop() {
                        self.handle_global(now, ev);
                    }
                }
                Some(s) => {
                    if self.statuses[s].head.is_some_and(|(_, b)| b) {
                        self.exec_boundary(s, key.0);
                    } else {
                        // Settle only stops at boundaries, so a stranded
                        // non-boundary head here means a zero-lookahead
                        // time tie pinned it at another shard's bound:
                        // push exactly that one event through.
                        self.grant_one(s, key);
                    }
                }
            }
        }
        self.finish(t0)
    }

    /// Conservative-lookahead settle loop: repeatedly grant every shard
    /// the window strictly below the other shards' barrier bounds (and
    /// the global calendar head, and the horizon) until no shard can
    /// reveal anything earlier — at which point the minimal revealed
    /// event is provably the global next barrier.
    fn settle(&mut self) {
        let horizon_cap = Key(self.horizon, u64::MAX);
        let mut granted: Vec<(usize, Key)> = Vec::new();
        loop {
            let gkey = self.global.peek().map(|(t, s, _)| Key(t, s));
            granted.clear();
            for s in 0..self.shards.len() {
                let Some((hk, boundary)) = self.statuses[s].head else {
                    continue;
                };
                if boundary {
                    continue;
                }
                let mut limit = horizon_cap;
                if let Some(g) = gkey {
                    limit = limit.min(g);
                }
                for (j, st) in self.statuses.iter().enumerate() {
                    if j != s {
                        if let Some(b) = st.bound {
                            limit = limit.min(b);
                        }
                    }
                }
                if hk < limit {
                    granted.push((s, limit));
                }
            }
            if granted.is_empty() {
                return;
            }
            // Pre-draw fluctuation multipliers up to the furthest grant so
            // every tick inside any window ships with its grant.
            let max_t = granted
                .iter()
                .fold(f64::NEG_INFINITY, |m, &(_, l)| m.max(l.0));
            self.fluct.draw_until(max_t);
            for &(s, limit) in granted.iter() {
                let fluct = std::mem::take(&mut self.fluct.out[s]);
                self.shards[s].send(Cmd::Grant {
                    limit,
                    epoch: self.epoch,
                    fluct,
                });
            }
            for &(s, _) in granted.iter() {
                match self.shards[s].recv() {
                    Reply::Granted { status, fluct } => {
                        self.statuses[s] = status;
                        self.fluct.out[s] = fluct;
                    }
                    // lint: allow(p1) protocol violation is unrecoverable
                    other => panic!("expected Granted, got {other:?}"),
                }
            }
        }
    }

    /// Push exactly one stranded head event through shard `s` (the
    /// zero-lookahead corner: a non-boundary head tied with another
    /// shard's bound at the same instant, which settle will never grant).
    fn grant_one(&mut self, s: usize, key: Key) {
        self.fluct.draw_until(key.0);
        let fluct = std::mem::take(&mut self.fluct.out[s]);
        self.shards[s].send(Cmd::Grant {
            limit: Key(key.0, key.1.saturating_add(1)),
            epoch: self.epoch,
            fluct,
        });
        match self.shards[s].recv() {
            Reply::Granted { status, fluct } => {
                self.statuses[s] = status;
                self.fluct.out[s] = fluct;
            }
            // lint: allow(p1) protocol violation is unrecoverable
            other => panic!("expected Granted, got {other:?}"),
        }
    }

    /// Sequential `ClusterSim::advance_all`, broadcast. The shard side
    /// early-outs on a same-instant repeat exactly like the sequential
    /// cluster, so back-to-back barrier calls stay cheap.
    fn advance_all(&mut self, now: SimTime) {
        for h in &self.shards {
            h.send(Cmd::AdvanceTo { now });
        }
        for h in &self.shards {
            match h.recv() {
                Reply::Advanced => {}
                // lint: allow(p1) protocol violation is unrecoverable
                other => panic!("expected Advanced, got {other:?}"),
            }
        }
    }

    /// Rebuild the global scheduler snapshot from per-shard slices — the
    /// merge-barrier `view_into`. Shards fill their slices concurrently;
    /// the merge is in shard (= global server) order and the epoch stamp
    /// advances exactly once per fill, preserving the sequential
    /// versioned-view contract.
    fn fill_view(&mut self, req: &ServiceRequest) {
        for s in 0..self.shards.len() {
            let (views, admissible) = std::mem::take(&mut self.view_bufs[s]);
            self.shards[s].send(Cmd::FillView {
                req: req.clone(),
                views,
                admissible,
            });
        }
        self.view.now = self.clock;
        self.view_epoch += 1;
        self.view.epoch = self.view_epoch;
        self.view.weights = self.cfg.weights;
        self.view.servers.clear();
        self.view.candidates.clear();
        let mut total_admissible = 0usize;
        for s in 0..self.shards.len() {
            match self.shards[s].recv() {
                Reply::View {
                    mut views,
                    admissible,
                    n_admissible,
                } => {
                    self.view.servers.append(&mut views);
                    total_admissible += n_admissible;
                    self.view_bufs[s] = (views, admissible);
                }
                // lint: allow(p1) protocol violation is unrecoverable
                other => panic!("expected View, got {other:?}"),
            }
        }
        // Same sparsity rule as the sequential fill: materialize the
        // candidate list only when someone is inadmissible.
        if total_admissible < self.view.servers.len() {
            for s in 0..self.shards.len() {
                let lo = self.shards[s].lo;
                for (i, &ok) in self.view_bufs[s].1.iter().enumerate() {
                    if ok {
                        self.view.candidates.push((lo + i) as u32);
                    }
                }
            }
        }
    }

    fn handle_global(&mut self, now: SimTime, ev: GlobalEv) {
        match ev {
            GlobalEv::Arrival => {
                let Some(req) = self.pending_arrival.take() else {
                    log::error!("Arrival event with no pending request; dropping event");
                    return;
                };
                if self.first_arrival.is_none() {
                    self.first_arrival = Some(req.arrival);
                }
                self.last_arrival = req.arrival;
                self.in_flight += 1;
                self.prefetch_arrival();
                self.advance_all(now);
                self.fill_view(&req);
                let action = self.scheduler.decide(&req, &self.view);
                let idx = self.svc.len();
                self.svc.push(GSvc {
                    req,
                    server: usize::MAX,
                    phase: Phase::Pending,
                    tx_energy_j: 0.0,
                });
                self.act_on(now, idx, action);
            }
            GlobalEv::Dispatch { svc, server } => self.dispatch(now, svc, server),
            GlobalEv::OutageStart { server } => {
                self.apply_fault(now, FaultAction::Down { server, crash: false })
            }
            GlobalEv::OutageEnd { server } => {
                self.apply_fault(now, FaultAction::Up { server, crash: false })
            }
            GlobalEv::Fault { action } => self.apply_fault(now, action),
            GlobalEv::HealthProbe => self.health_probe(now),
        }
    }

    /// Sequential `act_on`, with deferred dispatches stamped into the
    /// global calendar.
    fn act_on(&mut self, now: SimTime, idx: usize, action: Action) {
        match action {
            Action::Assign { server } => {
                let server = self.checked_server(idx, server);
                self.svc[idx].server = server;
                self.stamp_kv_transfer(idx, server);
                self.dispatch(now, idx, server);
            }
            Action::Defer { server, delay_s } => {
                let server = self.checked_server(idx, server);
                self.svc[idx].server = server;
                self.stamp_kv_transfer(idx, server);
                if delay_s.is_finite() && delay_s > 0.0 {
                    let stamp = self.next_stamp();
                    self.global
                        .push_at_stamped(now + delay_s, stamp, GlobalEv::Dispatch { svc: idx, server });
                } else {
                    self.dispatch(now, idx, server);
                }
            }
            Action::Shed { reason } => self.shed_at_decision(now, idx, reason),
        }
    }

    /// Sequential `stamp_kv_transfer` verbatim, sourcing the static rates
    /// from the config specs: the decision-time view (assembled from the
    /// same per-shard `fill_server_view` slices) carries identical
    /// `prefix_hit_tokens`, and `LinkSpec::solo_time`/`prefill_rate` are
    /// pure functions of the specs — so both substrates take the same
    /// ship/no-ship decision on the same inputs, bit for bit.
    fn stamp_kv_transfer(&mut self, idx: usize, server: usize) {
        let Some(sess) = self.svc[idx].req.session else {
            return;
        };
        if sess.prefix_tokens == 0 {
            return;
        }
        let local = self.view.servers[server].prefix_hit_tokens;
        let mut remote = 0.0f64;
        for (j, sv) in self.view.servers.iter().enumerate() {
            if j != server && sv.prefix_hit_tokens > remote {
                remote = sv.prefix_hit_tokens;
            }
        }
        let ship = remote - local;
        if ship < 1.0 {
            return;
        }
        let ship_tokens = ship as u32;
        let xfer_s = self.cfg.links[server].solo_time(SessionRef::kv_bytes(ship_tokens));
        let saved_s = ship_tokens as f64 / self.cfg.servers[server].prefill_rate;
        if xfer_s < saved_s {
            if let Some(s) = self.svc[idx].req.session.as_mut() {
                s.xfer_tokens = ship_tokens;
            }
        }
    }

    fn checked_server(&mut self, idx: usize, server: usize) -> usize {
        if server < self.cfg.servers.len() {
            return server;
        }
        self.bad_actions += 1;
        log::warn!(
            "scheduler {:?} chose out-of-range server {server} (cluster has {}); \
             falling back to least-violating",
            self.scheduler.name(),
            self.cfg.servers.len()
        );
        self.view.least_violating(&self.svc[idx].req)
    }

    /// Sequential `dispatch`: the upload itself starts shard-side; the
    /// orchestrator mirrors the phase flip and the (pure-function) upload
    /// energy stamp for the horizon-stranded outcome pass.
    fn dispatch(&mut self, now: SimTime, i: usize, server: usize) {
        let s = self.shard_of(server);
        let local = server - self.shards[s].lo;
        let req = self.svc[i].req.clone();
        self.shards[s].send(Cmd::Dispatch {
            svc: i as u64,
            req,
            server: local,
            now,
            epoch: self.epoch,
        });
        match self.shards[s].recv() {
            Reply::Dispatched { status } => self.statuses[s] = status,
            // lint: allow(p1) protocol violation is unrecoverable
            other => panic!("expected Dispatched, got {other:?}"),
        }
        let st = &mut self.svc[i];
        st.phase = Phase::Uploading;
        // Same payload as the shard-side upload: stamped KV-transfer
        // bytes ride along and cost tx energy.
        let payload = st.req.payload_bytes
            + match st.req.session {
                Some(s) => SessionRef::kv_bytes(s.xfer_tokens),
                None => 0,
            };
        st.tx_energy_j = self.cfg.links[server].tx_energy(payload);
    }

    /// Sequential `apply_fault` + `fault_down`/`fault_up` incident logic:
    /// the physics applies shard-side; crash casualties and incident
    /// transitions merge back here in the sequential order (victims
    /// first, then the down/up transition, then membership counters).
    fn apply_fault(&mut self, now: SimTime, action: FaultAction) {
        let target = action.target_index();
        let s = self.shard_of(target);
        let local = localize_action(action, self.shards[s].lo);
        self.shards[s].send(Cmd::ApplyFault {
            action: local,
            now,
            epoch: self.epoch,
        });
        let out = match self.shards[s].recv() {
            Reply::Fault { out, status } => {
                self.statuses[s] = status;
                out
            }
            // lint: allow(p1) protocol violation is unrecoverable
            other => panic!("expected Fault, got {other:?}"),
        };
        for rec in out.victims {
            match self.crash_policy {
                CrashPolicy::Fail => {
                    self.inc.failed_in_flight += 1;
                    self.fail(now, rec, target);
                }
                CrashPolicy::Requeue => {
                    self.inc.requeued_in_flight += 1;
                    self.requeue(now, rec.svc as usize);
                }
            }
        }
        if out.newly_down {
            self.inc.incidents += 1;
            if self.inc.down_servers == 0 && self.inc.incident_first_at.is_none() {
                self.inc.incident_first_at = Some(now);
                self.inc.gate_sheds_at_incident = self.current_gate_sheds();
            }
            self.inc.down_servers += 1;
            self.scheduler.fleet_event(&FleetEvent::Down { server: target }, now);
        }
        if out.recovered {
            self.inc.down_servers = self.inc.down_servers.saturating_sub(1);
            if self.inc.down_servers == 0 {
                self.inc.incident_last_end = Some(now);
                self.inc.gate_sheds_at_recovery = Some(self.current_gate_sheds());
            }
            self.scheduler.fleet_event(&FleetEvent::Up { server: target }, now);
        }
        match action {
            FaultAction::Leave { server } => {
                self.inc.leaves += 1;
                self.scheduler.fleet_event(&FleetEvent::Left { server }, now);
            }
            FaultAction::Join { server } => {
                self.inc.joins += 1;
                self.scheduler.fleet_event(&FleetEvent::Joined { server }, now);
            }
            _ => {}
        }
    }

    /// Execute the boundary event at shard `s`'s queue head and merge its
    /// outcome exactly as the sequential arm would have.
    fn exec_boundary(&mut self, s: usize, now: SimTime) {
        self.shards[s].send(Cmd::ExecuteBoundary {
            now,
            epoch: self.epoch,
        });
        let out = match self.shards[s].recv() {
            Reply::Boundary { out, status } => {
                self.statuses[s] = status;
                out
            }
            // lint: allow(p1) protocol violation is unrecoverable
            other => panic!("expected Boundary, got {other:?}"),
        };
        let lo = self.shards[s].lo;
        match out {
            BoundaryOut::None => {}
            BoundaryOut::Completions { server, recs } => {
                for rec in recs {
                    self.complete(now, rec, lo + server);
                }
            }
            BoundaryOut::Landed { server, kind, rec } => match kind {
                LandKind::Crashed => match self.crash_policy {
                    CrashPolicy::Fail => {
                        self.inc.failed_in_flight += 1;
                        self.fail(now, rec, lo + server);
                    }
                    CrashPolicy::Requeue => {
                        self.inc.requeued_in_flight += 1;
                        self.requeue(now, rec.svc as usize);
                    }
                },
                LandKind::Departed => {
                    self.inc.failed_in_flight += 1;
                    self.fail(now, rec, lo + server);
                }
                LandKind::Dropped => self.fail(now, rec, lo + server),
            },
        }
    }

    /// Sequential `health_probe`: snapshot ground truth across shards in
    /// global order, feed the lagged monitor, publish the (possibly
    /// updated) observations back so shard-side view slices price servers
    /// exactly like the sequential monitor-backed snapshot, then re-arm.
    fn health_probe(&mut self, now: SimTime) {
        let Some(period) = self.health_period else {
            return;
        };
        for s in 0..self.shards.len() {
            let buf = std::mem::take(&mut self.health_bufs[s]);
            self.shards[s].send(Cmd::ProbeHealth { buf });
        }
        self.health_snap.clear();
        for s in 0..self.shards.len() {
            match self.shards[s].recv() {
                Reply::Health { buf } => {
                    self.health_snap.extend_from_slice(&buf);
                    self.health_bufs[s] = buf;
                }
                // lint: allow(p1) protocol violation is unrecoverable
                other => panic!("expected Health, got {other:?}"),
            }
        }
        if let Some(h) = self.health.as_mut() {
            h.probe(now, &self.health_snap);
        }
        if self.health.is_some() {
            for s in 0..self.shards.len() {
                let mut obs = std::mem::take(&mut self.obs_bufs[s]);
                obs.clear();
                let (lo, hi) = (self.shards[s].lo, self.shards[s].hi);
                if let Some(h) = self.health.as_ref() {
                    for g in lo..hi {
                        obs.push(h.observed(g));
                    }
                }
                self.shards[s].send(Cmd::PublishObserved { observed: obs });
            }
            for s in 0..self.shards.len() {
                match self.shards[s].recv() {
                    Reply::Published { observed } => self.obs_bufs[s] = observed,
                    // lint: allow(p1) protocol violation is unrecoverable
                    other => panic!("expected Published, got {other:?}"),
                }
            }
        }
        let stamp = self.next_stamp();
        self.global
            .push_at_stamped(now + period, stamp, GlobalEv::HealthProbe);
    }

    fn current_gate_sheds(&self) -> u64 {
        self.scheduler
            .diagnostics()
            .iter()
            .find_map(|(k, v)| (k == "gate_sheds").then_some(*v as u64))
            .unwrap_or(0)
    }

    /// Sequential `shed_at_decision` verbatim (the decision-time view is
    /// still current — no cluster state changed since `decide`).
    fn shed_at_decision(&mut self, now: SimTime, i: usize, _reason: ShedReason) {
        self.svc[i].phase = Phase::Failed;
        self.shed += 1;
        self.policy_shed += 1;
        let outcome = ServiceOutcome::shed(&self.svc[i].req, now);
        self.in_flight -= 1;
        self.scheduler.feedback(&outcome, &self.view);
        self.outcomes.push(outcome);
    }

    /// Sequential `fail`, reconstructed from the shard's flow record.
    fn fail(&mut self, now: SimTime, rec: FailRec, server: usize) {
        self.shed += 1;
        let i = rec.svc as usize;
        let st = &mut self.svc[i];
        st.phase = Phase::Failed;
        let outcome = ServiceOutcome {
            id: st.req.id,
            class: st.req.class,
            server,
            tx_time: rec.upload_done_at - rec.dispatched_at,
            infer_time: 0.0,
            processing_time: f64::INFINITY,
            ttft_time: f64::INFINITY,
            slo: st.req.slo,
            energy_j: rec.tx_energy_j,
            tokens: 0,
            completed_at: now,
        };
        self.in_flight -= 1;
        self.advance_all(now);
        let req = self.svc[i].req.clone();
        self.fill_view(&req);
        self.scheduler.feedback(&outcome, &self.view);
        self.outcomes.push(outcome);
    }

    /// Sequential `complete`, reconstructed from the shard's flow record
    /// (the shard already bumped its server's `tokens_served`).
    fn complete(&mut self, now: SimTime, rec: CompletionRec, server: usize) {
        let i = rec.svc as usize;
        let st = &mut self.svc[i];
        st.phase = Phase::Done;
        let tokens = st.req.total_tokens();
        let outcome = ServiceOutcome {
            id: st.req.id,
            class: st.req.class,
            server,
            tx_time: rec.upload_done_at - rec.dispatched_at,
            infer_time: now - rec.compute_started_at,
            processing_time: now - st.req.arrival,
            ttft_time: rec.first_token_at.min(now) - st.req.arrival,
            slo: st.req.slo,
            energy_j: rec.tx_energy_j + rec.infer_energy_j,
            tokens,
            completed_at: now,
        };
        self.in_flight -= 1;
        self.advance_all(now);
        let req = self.svc[i].req.clone();
        self.fill_view(&req);
        self.scheduler.feedback(&outcome, &self.view);
        self.outcomes.push(outcome);
    }

    /// Sequential `requeue`: bounce a crash casualty back through the
    /// scheduler with its identity and arrival clock intact.
    fn requeue(&mut self, now: SimTime, i: usize) {
        self.svc[i].phase = Phase::Pending;
        self.svc[i].server = usize::MAX;
        // Sequential requeue: the stamped transfer died with the crashed
        // placement; the fresh decision re-derives it.
        if let Some(s) = self.svc[i].req.session.as_mut() {
            s.xfer_tokens = 0;
        }
        self.advance_all(now);
        let req = self.svc[i].req.clone();
        self.fill_view(&req);
        let action = self.scheduler.decide(&req, &self.view);
        self.act_on(now, i, action);
    }

    /// Run-end: compute the end clock, sweep per-shard accounting, fold
    /// energy/tokens in global order, reconstruct horizon-stranded
    /// outcomes, and assemble the report through the shared tail.
    fn finish(mut self, t0: Instant) -> RunReport {
        let end = match self.past_horizon {
            Some(t) => t,
            None => {
                // Queues drained (or all work resolved): the sequential
                // clock is the last popped event's time, wherever it was.
                let mut end = self.clock.max(self.global.now());
                for st in &self.statuses {
                    end = end.max(st.now);
                }
                end
            }
        };
        for h in &self.shards {
            h.send(Cmd::Finish { now: end });
        }
        let mut fins: Vec<ShardFinish> = Vec::with_capacity(self.shards.len());
        for h in &self.shards {
            match h.recv() {
                Reply::Finished(f) => fins.push(*f),
                // lint: allow(p1) protocol violation is unrecoverable
                other => panic!("expected Finished, got {other:?}"),
            }
        }
        // Per-resource energy folds in global order: the same per-field
        // float-sum sequences as `ClusterSim::energy`.
        let mut energy = EnergyBreakdown::default();
        for fin in &fins {
            for (&a, &b) in fin.infer_j.iter().zip(fin.idle_j.iter()) {
                energy.infer_j += a;
                energy.idle_j += b;
            }
        }
        let mut g = 0usize;
        for fin in &fins {
            for &bytes in &fin.bytes_moved {
                energy.tran_j += bytes * 8.0 / 1.0e6 * self.cfg.links[g].energy_j_per_mbit;
                g += 1;
            }
        }
        let tokens: u64 = fins.iter().map(|f| f.tokens).sum();
        // Prefix-cache counters fold in global server order (shards are
        // ordered by range) — same fold as the sequential tail.
        let mut cache = CacheCounters::default();
        for fin in &fins {
            for c in &fin.cache {
                cache.absorb(c);
            }
        }
        // First-token instants for flows still resident at run end.
        let mut ftk = vec![f64::INFINITY; self.svc.len()];
        for fin in &fins {
            for &(svc, first_token_at, _tx) in &fin.live_flows {
                ftk[svc as usize] = first_token_at;
            }
        }
        // Anything still in flight failed the horizon (same pass as the
        // sequential tail, fed from the mirrored orchestrator state).
        let n_servers = self.cfg.servers.len();
        let mut unfinished = 0;
        for (i, st) in self.svc.iter().enumerate() {
            if st.phase != Phase::Done && st.phase != Phase::Failed {
                unfinished += 1;
                self.outcomes.push(ServiceOutcome {
                    id: st.req.id,
                    class: st.req.class,
                    server: st.server.min(n_servers.saturating_sub(1)),
                    tx_time: 0.0,
                    infer_time: 0.0,
                    processing_time: f64::INFINITY,
                    ttft_time: if ftk[i] <= end {
                        ftk[i] - st.req.arrival
                    } else {
                        f64::INFINITY
                    },
                    slo: st.req.slo,
                    energy_j: st.tx_energy_j,
                    tokens: 0,
                    completed_at: end,
                });
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut processed = self.global.processed();
        let mut stale = 0u64;
        let mut peak = self.global.peak_len();
        for st in &self.statuses {
            processed += st.processed;
            stale += st.stale;
            peak = peak.max(st.peak);
        }
        let diagnostics = self.scheduler.diagnostics();
        let q = QueueStats {
            processed,
            stale,
            stale_ratio: stale as f64 / processed.max(1) as f64,
            peak,
        };
        // Shard telemetry from the final statuses + handle counters.
        // Pure perf instrumentation: excluded from the identity surface
        // like the other substrate-specific counters.
        let parts: Vec<ShardPerf> = self
            .shards
            .iter()
            .zip(&self.statuses)
            .map(|(h, st)| {
                let grants = h.grants.get();
                ShardPerf {
                    range: (h.lo, h.hi),
                    events: st.processed,
                    grants,
                    events_per_grant: st.processed as f64 / grants.max(1) as f64,
                    stall_wall_s: h.stall_s.get(),
                    round_trips: h.round_trips.get(),
                }
            })
            .collect();
        let mut rep = assemble_report(
            self.scheduler.name(),
            self.outcomes,
            energy,
            end,
            self.first_arrival.unwrap_or(0.0),
            tokens,
            unfinished,
            self.shed,
            self.policy_shed,
            self.bad_actions,
            diagnostics,
            &self.inc,
            wall,
            q,
            cache,
        );
        rep.shard_perf = Some(ShardPerfReport::from_parts(parts));
        rep
    }
}

/// Re-index a fault action into a shard's local server/link space (links
/// share server indexing: one uplink per server).
fn localize_action(action: FaultAction, lo: usize) -> FaultAction {
    match action {
        FaultAction::Down { server, crash } => FaultAction::Down { server: server - lo, crash },
        FaultAction::Up { server, crash } => FaultAction::Up { server: server - lo, crash },
        FaultAction::DegradeStart { server, factor } => {
            FaultAction::DegradeStart { server: server - lo, factor }
        }
        FaultAction::DegradeEnd { server, factor } => {
            FaultAction::DegradeEnd { server: server - lo, factor }
        }
        FaultAction::FlapStart { link, factor } => {
            FaultAction::FlapStart { link: link - lo, factor }
        }
        FaultAction::FlapEnd { link } => FaultAction::FlapEnd { link: link - lo },
        FaultAction::Leave { server } => FaultAction::Leave { server: server - lo },
        FaultAction::Join { server } => FaultAction::Join { server: server - lo },
    }
}

/// Core sharded runner: replay `Engine::new_with_faults`'s construction
/// push order with explicit epoch-0 stamps (so every same-instant tie
/// among seeded events resolves exactly as in the sequential engine),
/// spawn one worker thread per shard, and drive the merge-barrier
/// protocol from the calling thread.
fn run_sharded(
    cfg: &ClusterConfig,
    plan: &FaultPlan,
    splan: &ShardPlan,
    source: &mut dyn ArrivalSource,
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    let t0 = Instant::now(); // lint: allow(wall-clock) measures simulator throughput only; no sim behavior reads it
    let n_shards = splan.n_shards();
    let n_servers = cfg.servers.len();
    let n_links = cfg.links.len();
    let mut k = 0u64;
    let mut init_ticks: Vec<Vec<(SimTime, u64, usize)>> = vec![Vec::new(); n_shards];
    let mut fluct_heap = BinaryHeap::new();
    let mut owner = Vec::with_capacity(n_links);
    for (li, link) in cfg.links.iter().enumerate() {
        let s = splan.shard_of(li);
        let local = li - splan.ranges[s].0;
        owner.push((s, local as u32));
        if link.fluctuation > 0.0 {
            let stamp = orch_stamp(0, k);
            k += 1;
            init_ticks[s].push((link.fluct_period, stamp, local));
            fluct_heap.push(std::cmp::Reverse((Key(link.fluct_period, stamp), li)));
        }
    }
    let mut global: EventQueue<GlobalEv> = EventQueue::new();
    for Outage { server, start, end } in &cfg.outages {
        global.push_at_stamped(*start, orch_stamp(0, k), GlobalEv::OutageStart { server: *server });
        k += 1;
        global.push_at_stamped(*end, orch_stamp(0, k), GlobalEv::OutageEnd { server: *server });
        k += 1;
    }
    for (at, action) in plan.materialize(n_servers, n_links, cfg.seed) {
        global.push_at_stamped(at, orch_stamp(0, k), GlobalEv::Fault { action });
        k += 1;
    }
    let mut health = None;
    let health_period = plan.health.map(|hc| {
        health = Some(HealthMonitor::new(hc, n_servers));
        global.push_at_stamped(hc.period_s, orch_stamp(0, k), GlobalEv::HealthProbe);
        k += 1;
        hc.period_s
    });
    let mut sims = Vec::with_capacity(n_shards);
    let mut statuses = Vec::with_capacity(n_shards);
    for (s, &(lo, hi)) in splan.ranges.iter().enumerate() {
        let sub = ClusterConfig {
            servers: cfg.servers[lo..hi].to_vec(),
            links: cfg.links[lo..hi].to_vec(),
            bandwidth: cfg.bandwidth,
            weights: cfg.weights,
            // Outage physics replays through the orchestrator's global
            // calendar; sub-clusters never see the raw windows.
            outages: Vec::new(),
            seed: cfg.seed,
            churn_guard: cfg.churn_guard,
        };
        let sim = ShardSim::new(
            &sub,
            s,
            splan.lookahead_classes(&cfg.links, s),
            &init_ticks[s],
            plan.health.is_some(),
        );
        statuses.push(sim.status());
        sims.push(sim);
    }
    let fluct = FluctCal {
        rng: Rng::new(cfg.seed), // lint: allow(raw-seed) replays the sequential engine's primary stream verbatim
        heap: fluct_heap,
        next_rank: FLUCT_REARM_RANK_BASE,
        amp: cfg.links.iter().map(|l| l.fluctuation).collect(),
        period: cfg.links.iter().map(|l| l.fluct_period).collect(),
        owner,
        out: vec![Vec::new(); n_shards],
    };
    let hint = source.len_hint().unwrap_or(0).min(1 << 20);
    std::thread::scope(|scope| {
        let mut shards = Vec::with_capacity(n_shards);
        for (s, sim) in sims.into_iter().enumerate() {
            let (lo, hi) = splan.ranges[s];
            // Capacity 4 keeps both directions non-blocking for the
            // strict 1-in-flight request/reply protocol while bounding
            // the mailboxes (the bounded-mailbox part of the contract).
            let (ctx, crx) = sync_channel::<Cmd>(4);
            let (rtx, rrx) = sync_channel::<Reply>(4);
            scope.spawn(move || worker(sim, crx, rtx));
            shards.push(ShardHandle {
                tx: ctx,
                rx: rrx,
                lo,
                hi,
                grants: std::cell::Cell::new(0),
                round_trips: std::cell::Cell::new(0),
                stall_s: std::cell::Cell::new(0.0),
            });
        }
        let mut eng = ShardedEngine {
            cfg,
            shards,
            statuses,
            global,
            source,
            scheduler,
            fluct,
            svc: Vec::with_capacity(hint),
            pending_arrival: None,
            outcomes: Vec::with_capacity(hint),
            in_flight: 0,
            first_arrival: None,
            last_arrival: 0.0,
            horizon: f64::INFINITY,
            shed: 0,
            policy_shed: 0,
            bad_actions: 0,
            view: ClusterView::with_capacity(n_servers, cfg.weights),
            view_epoch: 0,
            view_bufs: vec![(Vec::new(), Vec::new()); n_shards],
            health,
            health_period,
            health_snap: Vec::with_capacity(n_servers),
            health_bufs: vec![Vec::new(); n_shards],
            obs_bufs: vec![Vec::new(); n_shards],
            crash_policy: plan.crash_policy,
            inc: IncidentCounters::default(),
            epoch: 0,
            orch_k: k,
            clock: 0.0,
            past_horizon: None,
        };
        eng.prefetch_arrival();
        eng.run(t0)
    })
}

/// [`simulate`] on the sharded engine: same inputs plus a [`ShardPlan`].
/// Fixed seed => bit-identical [`RunReport`] outcomes/energy/diagnostics
/// at every shard count, pinned against the sequential engine by
/// tests/sharded_identity.rs (perf counters like `events_processed` and
/// `peak_event_queue_len` are substrate-specific and out of scope).
pub fn simulate_sharded(
    cfg: &ClusterConfig,
    splan: &ShardPlan,
    trace: &[ServiceRequest],
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    let mut source = TraceSource::new(trace);
    run_sharded(cfg, &FaultPlan::default(), splan, &mut source, scheduler)
}

/// [`simulate_stream`] on the sharded engine.
pub fn simulate_stream_sharded(
    cfg: &ClusterConfig,
    splan: &ShardPlan,
    source: &mut dyn ArrivalSource,
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    run_sharded(cfg, &FaultPlan::default(), splan, source, scheduler)
}

/// [`simulate_faulted`] on the sharded engine: chaos plan + shard plan.
pub fn simulate_faulted_sharded(
    cfg: &ClusterConfig,
    plan: &FaultPlan,
    splan: &ShardPlan,
    trace: &[ServiceRequest],
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    let mut source = TraceSource::new(trace);
    run_sharded(cfg, plan, splan, &mut source, scheduler)
}

/// [`simulate_stream_faulted`] on the sharded engine — the entry point
/// `paper_scale_sim --shards N` uses.
pub fn simulate_stream_faulted_sharded(
    cfg: &ClusterConfig,
    plan: &FaultPlan,
    splan: &ShardPlan,
    source: &mut dyn ArrivalSource,
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    run_sharded(cfg, plan, splan, source, scheduler)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Action, ClusterView};
    use crate::sim::cluster::BandwidthMode;
    use crate::workload::generator::{generate, ArrivalProcess, WorkloadConfig, WorkloadGen};

    /// Fixed-target scheduler for engine unit tests.
    struct Fixed(usize);
    impl Scheduler for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
            Action::assign(self.0)
        }
    }

    /// Sheds everything and counts the feedback it receives.
    #[derive(Default)]
    struct ShedAll {
        feedbacks: usize,
        shed_feedbacks: usize,
    }
    impl Scheduler for ShedAll {
        fn name(&self) -> &'static str {
            "shed-all"
        }
        fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
            Action::shed(ShedReason::Overloaded)
        }
        fn feedback(&mut self, o: &ServiceOutcome, _v: &ClusterView) {
            self.feedbacks += 1;
            if o.was_shed() {
                self.shed_feedbacks += 1;
            }
        }
    }

    fn small_trace(n: usize, rate: f64) -> Vec<ServiceRequest> {
        generate(
            &WorkloadConfig::default()
                .with_requests(n)
                .with_arrivals(ArrivalProcess::Poisson { rate })
                .with_seed(7),
        )
    }

    #[test]
    fn all_requests_complete_under_light_load() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = small_trace(50, 2.0);
        let mut s = Fixed(5); // cloud
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 50);
        assert_eq!(rep.unfinished, 0);
        assert!(rep.success_rate > 0.9, "success={}", rep.success_rate);
        assert!(rep.throughput_tok_s > 0.0);
        assert!(rep.energy.total_j() > 0.0);
    }

    #[test]
    fn outcome_times_are_consistent() {
        let cfg = ClusterConfig::paper("yi-6b", BandwidthMode::Stable);
        let trace = small_trace(20, 1.0);
        let mut s = Fixed(0); // one edge
        let rep = simulate(&cfg, &trace, &mut s);
        for o in &rep.outcomes {
            assert!(o.tx_time > 0.0, "tx {}", o.tx_time);
            assert!(o.infer_time > 0.0);
            // processing >= tx + infer (queueing in between).
            assert!(o.processing_time >= o.tx_time + o.infer_time - 1e-9);
            assert!(o.energy_j > 0.0);
        }
    }

    #[test]
    fn edge_tx_shorter_cloud_infer_shorter() {
        // The Figure-2 motivation shape on a single request.
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = small_trace(1, 1.0);
        let mut cloud = Fixed(5);
        let mut edge = Fixed(0);
        let rc = simulate(&cfg, &trace, &mut cloud);
        let re = simulate(&cfg, &trace, &mut edge);
        assert!(re.outcomes[0].tx_time < rc.outcomes[0].tx_time);
        assert!(rc.outcomes[0].infer_time < re.outcomes[0].infer_time);
    }

    #[test]
    fn cloud_congestion_collapses_under_simultaneous_load() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_seed(3),
        );
        let mut s = Fixed(5);
        let rep = simulate(&cfg, &trace, &mut s);
        // Fair-share collapse: mean processing far above solo time.
        assert!(rep.mean_processing_s > 5.0, "mean={}", rep.mean_processing_s);
        assert!(rep.success_rate < 0.5, "success={}", rep.success_rate);
    }

    #[test]
    fn outage_fails_or_delays_requests() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable)
            .with_outages(vec![Outage {
                server: 0,
                start: 0.0,
                end: 1.0e9, // forever
            }]);
        let trace = small_trace(5, 1.0);
        let mut s = Fixed(0);
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.unfinished, 5);
        assert_eq!(rep.success_rate, 0.0);
    }

    /// Regression: horizon-unfinished requests carry the same outcome shape
    /// as shed requests (tokens 0, infer 0, infinite processing time) and
    /// used to be double-counted as `dropped`. Classification now happens
    /// at shed time, so a forever-outage run reports 5 unfinished, 0
    /// dropped.
    #[test]
    fn unfinished_not_double_counted_as_dropped() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable)
            .with_outages(vec![Outage {
                server: 0,
                start: 0.0,
                end: 1.0e9, // forever
            }]);
        let trace = small_trace(5, 1.0);
        let mut s = Fixed(0);
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.unfinished, 5);
        assert_eq!(rep.dropped, 0, "unfinished leaked into dropped");
        // And a genuinely-shedding overload run counts drops, not
        // unfinished: 400 simultaneous uploads swamp one edge server's
        // 8 slots + 2 waiting places. These are queue-admission drops, not
        // policy sheds.
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_seed(3),
        );
        let mut s = Fixed(0);
        let rep = simulate(&cfg, &trace, &mut s);
        assert!(rep.dropped > 0, "overload must shed");
        assert_eq!(rep.dropped_by_policy, 0, "no policy sheds from Fixed");
        assert_eq!(rep.outcomes.len(), 400);
    }

    /// Scheduler `Shed` actions resolve the request immediately: counted
    /// once in `dropped` (and `dropped_by_policy`), outcome emitted, bandit
    /// feedback delivered, and no upload energy spent.
    #[test]
    fn policy_shed_counted_once_with_feedback() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = small_trace(40, 5.0);
        let mut s = ShedAll::default();
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 40);
        assert_eq!(rep.dropped, 40);
        assert_eq!(rep.dropped_by_policy, 40);
        assert_eq!(rep.unfinished, 0);
        assert_eq!(rep.success_rate, 0.0);
        assert_eq!(s.feedbacks, 40, "feedback delivered per shed");
        assert_eq!(s.shed_feedbacks, 40, "shed outcomes marked as such");
        assert!(rep.outcomes.iter().all(|o| o.was_shed()));
        assert_eq!(rep.energy.tran_j, 0.0, "sheds must not spend upload energy");
    }

    /// An explicit `Defer` holds the request before dispatching it.
    #[test]
    fn defer_action_delays_dispatch() {
        struct DeferAll;
        impl Scheduler for DeferAll {
            fn name(&self) -> &'static str {
                "defer-all"
            }
            fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
                Action::defer(5, 0.5)
            }
        }
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = small_trace(5, 1.0);
        let rep = simulate(&cfg, &trace, &mut DeferAll);
        assert_eq!(rep.unfinished, 0);
        for o in &rep.outcomes {
            assert!(
                o.processing_time >= 0.5,
                "deferred request finished too fast: {}",
                o.processing_time
            );
        }
    }

    /// An out-of-range `Assign` is recovered via the least-violating
    /// fallback instead of panicking (or being silently clamped).
    #[test]
    fn out_of_range_assign_falls_back() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = small_trace(10, 2.0);
        let mut s = Fixed(99);
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), 10);
        assert_eq!(rep.unfinished, 0);
        assert!(rep.success_rate > 0.5, "fallback placed requests badly");
    }

    /// Regression (stale feedback views): `Engine::fail` / `Engine::complete`
    /// used to fill the feedback `ClusterView` without advancing the
    /// cluster first, so any server the completion handler itself did not
    /// touch showed the bandit a backlog frozen at its last-touched time.
    /// Setup: long jobs saturate edges 0 and 1; one probe is then dropped
    /// at edge 1's full queue (fail path) and one completes on the idle
    /// cloud (complete path). Both feedback snapshots read *edge 0* — a
    /// server untouched between each probe's decision and its feedback —
    /// so a frozen view reproduces the decision-time prediction exactly,
    /// while a freshly advanced one shows the strictly smaller backlog at
    /// feedback time.
    #[test]
    fn feedback_views_are_freshly_advanced() {
        #[derive(Default)]
        struct Capture {
            drop_decide: f64,
            drop_feedback: f64,
            cloud_decide: f64,
            cloud_feedback: f64,
        }
        impl Scheduler for Capture {
            fn name(&self) -> &'static str {
                "capture"
            }
            fn decide(&mut self, r: &ServiceRequest, v: &ClusterView) -> Action {
                match r.id {
                    0..=9 => Action::assign(0),
                    10..=19 => Action::assign(1),
                    20 => {
                        self.drop_decide = v.servers[0].predicted_time;
                        Action::assign(1) // full queue: dropped on landing
                    }
                    _ => {
                        self.cloud_decide = v.servers[0].predicted_time;
                        Action::assign(5)
                    }
                }
            }
            fn feedback(&mut self, o: &ServiceOutcome, v: &ClusterView) {
                if o.id == 20 {
                    self.drop_feedback = v.servers[0].predicted_time;
                } else if o.id == 21 {
                    self.cloud_feedback = v.servers[0].predicted_time;
                }
            }
        }
        let mk = |id: u64, arrival: f64, output: u32| ServiceRequest {
            id,
            class: crate::workload::service::ServiceClass::Chat,
            arrival,
            prompt_tokens: 100,
            output_tokens: output,
            slo: crate::workload::service::SloSpec::completion_only(100.0),
            payload_bytes: 100_000,
            session: None,
        };
        // Ten ~8s-solo jobs each at t=0 saturate edges 0 and 1 (8 slots +
        // 2 waiting) well past the capture points; the probes arrive once
        // everything has landed and is computing.
        let mut trace: Vec<ServiceRequest> = (0..20)
            .map(|i| mk(i, 0.0, 400))
            .collect();
        trace.push(mk(20, 1.0, 400)); // dropped at edge 1 (fail path)
        trace.push(mk(21, 2.0, 20)); // completes on the cloud (complete path)
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut s = Capture::default();
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.dropped, 1, "probe 20 must hit the full queue");
        assert!(s.drop_decide > 0.0 && s.cloud_decide > 0.0);
        // Edge 0 receives no event between each probe's decision (which
        // advances everything) and its feedback, so a stale feedback view
        // reproduces the decision-time number bit for bit; the fix must
        // show edge 0's backlog having drained in the meantime.
        assert!(
            s.drop_feedback < s.drop_decide,
            "fail-path feedback view frozen: {} vs {}",
            s.drop_feedback,
            s.drop_decide
        );
        assert!(
            s.cloud_feedback < s.cloud_decide,
            "complete-path feedback view frozen: {} vs {}",
            s.cloud_feedback,
            s.cloud_decide
        );
    }

    /// Regression (reschedule churn): occupancy touches that provably do
    /// not move the next completion (a full batch queue absorbing waiters,
    /// a capped uplink below its fair-share knee) used to invalidate and
    /// re-push the completion event anyway — 31% of congested-cloud pops
    /// were stale. The guard must cut the stale ratio while leaving every
    /// outcome bit-identical (the guard-off baseline re-pushes at the
    /// *cached* event time, so both runs fire completions at the same
    /// instants; only the stranded duplicates differ).
    #[test]
    fn churn_guard_cuts_stale_without_changing_outcomes() {
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_seed(3),
        );
        let cfg_on = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let cfg_off = cfg_on.clone().with_churn_guard(false);
        assert!(cfg_on.churn_guard && !cfg_off.churn_guard);
        let r_on = simulate(&cfg_on, &trace, &mut Fixed(5));
        let r_off = simulate(&cfg_off, &trace, &mut Fixed(5));
        assert_eq!(r_on.outcomes.len(), r_off.outcomes.len());
        for (a, b) in r_on.outcomes.iter().zip(&r_off.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.server, b.server);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.completed_at.to_bits(), b.completed_at.to_bits());
            assert_eq!(a.processing_time.to_bits(), b.processing_time.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
        assert_eq!(r_on.dropped, r_off.dropped);
        assert_eq!(r_on.unfinished, r_off.unfinished);
        assert_eq!(
            r_on.energy.total_j().to_bits(),
            r_off.energy.total_j().to_bits()
        );
        // The guard's whole point: fewer stranded events, same work. On
        // this scenario the pure-churn class is the ~36 same-instant
        // touches that provably leave the completion unchanged (burst
        // dispatches below the uplink's per-flow-cap knee, full-server
        // waiter admissions); touches that genuinely move the completion
        // (every fair-share rate change) must still reschedule. Sustained
        // saturation skips far more — every waiting-queue admission
        // between reaps — but this burst scenario is the deterministic
        // regression pin.
        assert!(
            r_on.stale_events + 20 <= r_off.stale_events,
            "guard saved too little: {} vs {}",
            r_on.stale_events,
            r_off.stale_events
        );
        assert!(r_on.stale_ratio < r_off.stale_ratio);
        assert!(r_on.events_processed < r_off.events_processed);
    }

    /// Regression (zero-success energy): an all-shed run used to report
    /// the cluster's total (idle) energy as "energy per success".
    #[test]
    fn all_shed_run_reports_infinite_energy_per_success() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = small_trace(10, 5.0);
        let mut s = ShedAll::default();
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.success_rate, 0.0);
        assert!(rep.energy.total_j() > 0.0, "idle energy still accrues");
        assert!(
            rep.energy_per_success_j.is_infinite(),
            "got {}",
            rep.energy_per_success_j
        );
        assert!(
            rep.summary_row().contains("— J/succ"),
            "row: {}",
            rep.summary_row()
        );
    }

    /// Generation-invalidated completion events are counted, not silently
    /// swallowed: simultaneous uploads re-schedule the shared link's
    /// completion on every occupancy change, stranding the superseded
    /// events.
    #[test]
    fn stale_events_are_counted() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(200)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_seed(3),
        );
        let mut s = Fixed(5);
        let rep = simulate(&cfg, &trace, &mut s);
        assert!(rep.stale_events > 0, "congestion must strand events");
        assert!(rep.stale_ratio > 0.0 && rep.stale_ratio < 1.0);
        assert!(rep.stale_events < rep.events_processed);
    }

    /// SLO accounting pin (issue satellite): a request that *completes*
    /// inside its deadline but blows its TTFT bound is a violation — it
    /// lands in `late` and `slo_ttft_violations` — and is NOT counted as
    /// `dropped` (nothing was shed).
    #[test]
    fn ttft_violation_counts_as_violation_not_dropped() {
        use crate::workload::service::{ServiceClass, SloSpec};
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = vec![ServiceRequest {
            id: 0,
            class: ServiceClass::Chat,
            arrival: 0.0,
            prompt_tokens: 100,
            output_tokens: 40,
            // Generous completion, impossible first token: upload alone
            // takes longer than 1 ms.
            slo: SloSpec::completion_only(100.0).with_ttft(0.001),
            payload_bytes: 100_000,
            session: None,
        }];
        let mut s = Fixed(0);
        let rep = simulate(&cfg, &trace, &mut s);
        let o = &rep.outcomes[0];
        assert!(o.processing_time.is_finite(), "request must complete");
        assert_eq!(o.completion_met(), Some(true));
        assert_eq!(o.ttft_met(), Some(false));
        assert!(o.ttft_time > 0.001 && o.ttft_time <= o.processing_time);
        assert!(!o.success(), "TTFT miss fails the contract");
        assert_eq!(rep.late, 1, "counted as a (timing) violation");
        assert_eq!(rep.dropped, 0, "…not as a drop");
        assert_eq!(rep.slo_ttft_violations, 1);
        assert_eq!(rep.slo_completion_violations, 0);
        let chat = ServiceClass::Chat.index();
        assert_eq!(rep.ttft_attainment[chat].total, 1);
        assert_eq!(rep.ttft_attainment[chat].met, 0);
        assert_eq!(rep.completion_attainment[chat].met, 1);
        assert!(rep.slo_summary_row().contains("violations ttft 1"));
    }

    /// Realized TTFT on completed requests is sane: after the upload
    /// begins, at or before completion, and recorded per class.
    #[test]
    fn realized_ttft_between_dispatch_and_completion() {
        use crate::workload::generator::SloSampling;
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(60)
                .with_arrivals(ArrivalProcess::Poisson { rate: 2.0 })
                .with_slo_sampling(SloSampling::PerClass)
                .with_seed(11),
        );
        let mut s = Fixed(5);
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.unfinished, 0);
        for o in &rep.outcomes {
            assert!(o.ttft_time > 0.0, "ttft {}", o.ttft_time);
            assert!(
                o.ttft_time <= o.processing_time + 1e-9,
                "ttft {} > processing {}",
                o.ttft_time,
                o.processing_time
            );
            assert!(o.ttft_time >= o.tx_time - 1e-9, "first token before upload");
        }
        // Interactive classes carry TTFT attainment entries, batch ones
        // don't (per-class contracts).
        use crate::workload::service::ServiceClass;
        assert!(rep.ttft_attainment[ServiceClass::Chat.index()].total > 0);
        assert_eq!(rep.ttft_attainment[ServiceClass::Code.index()].total, 0);
    }

    /// Admission-gate wiring: under the simultaneous-400 overload the
    /// gate turns would-be deadline misses into counted door sheds —
    /// `gate_sheds > 0`, mirrored in `dropped_by_policy`, and no upload
    /// energy is spent on gated requests.
    #[test]
    fn gate_converts_overload_into_counted_door_sheds() {
        use crate::scheduler::admission::{GateParams, TokenBucketGate};
        use crate::scheduler::csucb::CsUcb;
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_seed(3),
        );
        let inner = Box::new(CsUcb::with_defaults(cfg.n_servers()));
        let mut gated = TokenBucketGate::new(inner, GateParams::default());
        let rep = simulate(&cfg, &trace, &mut gated);
        assert!(rep.gate_sheds > 0, "overload must trip the gate");
        assert!(rep.dropped_by_policy as u64 >= rep.gate_sheds);
        assert!(rep.dropped >= rep.dropped_by_policy);
        assert_eq!(rep.outcomes.len(), 400);
        // And without a gate the report's counter stays zero.
        let mut plain = CsUcb::with_defaults(cfg.n_servers());
        let rep_plain = simulate(&cfg, &trace, &mut plain);
        assert_eq!(rep_plain.gate_sheds, 0);
    }

    #[test]
    fn fluctuating_bandwidth_still_completes() {
        let cfg = ClusterConfig::paper("yi-9b", BandwidthMode::Fluctuating);
        let trace = small_trace(80, 4.0);
        let mut s = Fixed(5);
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.unfinished, 0);
    }

    #[test]
    fn deterministic_given_seeds() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let trace = small_trace(60, 3.0);
        let r1 = simulate(&cfg, &trace, &mut Fixed(5));
        let r2 = simulate(&cfg, &trace, &mut Fixed(5));
        assert_eq!(r1.outcomes.len(), r2.outcomes.len());
        assert!((r1.mean_processing_s - r2.mean_processing_s).abs() < 1e-12);
        assert!((r1.energy.total_j() - r2.energy.total_j()).abs() < 1e-9);
    }

    /// Streaming a generator through `simulate_stream` gives the same
    /// results as materializing the trace first: the workload is
    /// byte-identical and the engine logic substrate-independent.
    #[test]
    fn stream_and_trace_paths_agree() {
        let wl = WorkloadConfig::default()
            .with_requests(300)
            .with_arrivals(ArrivalProcess::Poisson { rate: 8.0 })
            .with_seed(21);
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let trace = generate(&wl);
        let r_trace = simulate(&cfg, &trace, &mut Fixed(5));
        let mut stream = WorkloadGen::new(&wl);
        let r_stream = simulate_stream(&cfg, &mut stream, &mut Fixed(5));
        assert_eq!(r_trace.outcomes.len(), r_stream.outcomes.len());
        assert!((r_trace.success_rate - r_stream.success_rate).abs() < 1e-12);
        assert!((r_trace.mean_processing_s - r_stream.mean_processing_s).abs() < 1e-12);
        assert!((r_trace.energy.total_j() - r_stream.energy.total_j()).abs() < 1e-9);
        assert_eq!(r_trace.events_processed, r_stream.events_processed);
    }

    fn long_job(id: u64, arrival: f64, output: u32) -> ServiceRequest {
        ServiceRequest {
            id,
            class: crate::workload::service::ServiceClass::Chat,
            arrival,
            prompt_tokens: 100,
            output_tokens: output,
            slo: crate::workload::service::SloSpec::completion_only(1000.0),
            payload_bytes: 100_000,
            session: None,
        }
    }

    /// Regression (PR 6 bugfix): overlapping outage windows used to end
    /// early — `OutageEnd` blindly restored `rate_mult = 1.0`, so an
    /// inner window's end revived a server still covered by an outer one.
    /// With depth tracking the server stays down until every covering
    /// window has ended.
    #[test]
    fn nested_outage_windows_keep_server_down_until_all_end() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable).with_outages(vec![
            Outage {
                server: 0,
                start: 0.0,
                end: 20.0,
            },
            Outage {
                server: 0,
                start: 5.0,
                end: 6.0, // nested inside the first window
            },
        ]);
        let trace = vec![long_job(0, 0.0, 40)];
        let rep = simulate(&cfg, &trace, &mut Fixed(0));
        assert_eq!(rep.unfinished, 0, "server must come back at 20 s");
        assert!(
            rep.outcomes[0].completed_at >= 20.0,
            "inner window's end revived the server early: completed at {}",
            rep.outcomes[0].completed_at
        );
        let av = rep.availability.expect("outages must produce a report");
        assert_eq!(av.incidents, 1, "nested windows are one incident");
        assert_eq!(av.incident_end_s, 20.0);
    }

    /// An outage starting at t = 0 is in force before the first arrival
    /// (fault events are seeded ahead of the arrival prefetch, so
    /// same-instant ordering favors the outage).
    #[test]
    fn outage_at_time_zero_applies_before_first_arrival() {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable).with_outages(vec![
            Outage {
                server: 0,
                start: 0.0,
                end: 2.0,
            },
        ]);
        let trace = vec![long_job(0, 0.0, 40)];
        let rep = simulate(&cfg, &trace, &mut Fixed(0));
        assert_eq!(rep.unfinished, 0);
        assert!(
            rep.outcomes[0].completed_at >= 2.0,
            "request completed during the outage: {}",
            rep.outcomes[0].completed_at
        );
    }

    /// A hard crash kills the work computing on the server: failed
    /// outcomes with bandit feedback for each, counted as drops and as
    /// `failed_in_flight`, and the incident lands in the availability
    /// report.
    #[test]
    fn crash_fails_in_flight_with_feedback() {
        use crate::sim::faults::{FaultKind, FaultPlan};
        #[derive(Default)]
        struct CountFails {
            fails: usize,
        }
        impl Scheduler for CountFails {
            fn name(&self) -> &'static str {
                "count-fails"
            }
            fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
                Action::assign(0)
            }
            fn feedback(&mut self, o: &ServiceOutcome, _v: &ClusterView) {
                if !o.processing_time.is_finite() {
                    self.fails += 1;
                }
            }
        }
        // Five ~8s-solo jobs at t=0 are all computing on edge 0 at t=5.
        let trace: Vec<ServiceRequest> = (0..5).map(|i| long_job(i, 0.0, 400)).collect();
        let plan = FaultPlan::default().with_event(
            5.0,
            FaultKind::Crash {
                server: 0,
                recover: Some(50.0),
            },
        );
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut s = CountFails::default();
        let rep = simulate_faulted(&cfg, &plan, &trace, &mut s);
        assert_eq!(rep.dropped, 5, "all in-flight work dies with the server");
        assert_eq!(s.fails, 5, "feedback delivered per casualty");
        let av = rep.availability.expect("crash must produce a report");
        assert_eq!(av.failed_in_flight, 5);
        assert_eq!(av.incidents, 1);
        assert_eq!(av.incident_start_s, 5.0);
    }

    /// Under `CrashPolicy::Requeue` crash casualties bounce back through
    /// the scheduler instead of dying: a second decision places them on
    /// the cloud and they still complete.
    #[test]
    fn crash_requeue_bounces_work_through_the_scheduler() {
        use crate::sim::faults::{CrashPolicy, FaultKind, FaultPlan};
        /// Edge 0 for the first decision on each id, cloud afterwards.
        #[derive(Default)]
        struct EdgeThenCloud {
            seen: std::collections::HashSet<u64>,
        }
        impl Scheduler for EdgeThenCloud {
            fn name(&self) -> &'static str {
                "edge-then-cloud"
            }
            fn decide(&mut self, r: &ServiceRequest, _v: &ClusterView) -> Action {
                if self.seen.insert(r.id) {
                    Action::assign(0)
                } else {
                    Action::assign(5)
                }
            }
        }
        let trace: Vec<ServiceRequest> = (0..3).map(|i| long_job(i, 0.0, 400)).collect();
        let plan = FaultPlan::default()
            .with_event(
                5.0,
                FaultKind::Crash {
                    server: 0,
                    recover: None,
                },
            )
            .with_crash_policy(CrashPolicy::Requeue);
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let mut s = EdgeThenCloud::default();
        let rep = simulate_faulted(&cfg, &plan, &trace, &mut s);
        assert_eq!(rep.dropped, 0, "requeued work must not be dropped");
        assert_eq!(rep.unfinished, 0);
        let av = rep.availability.expect("crash must produce a report");
        assert_eq!(av.requeued_in_flight, 3);
        assert_eq!(av.failed_in_flight, 0);
        assert!(av.incident_end_s.is_infinite(), "server 0 never recovers");
        for o in &rep.outcomes {
            assert_eq!(o.server, 5, "casualties must finish on the cloud");
            assert!(o.processing_time.is_finite());
        }
    }
}
