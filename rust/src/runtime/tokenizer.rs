//! Byte-level tokenizer + sampling.
//!
//! The Layer-2 models use a byte vocabulary (V = 256), so tokenization is
//! a codec, not a lookup — no external vocabulary files needed offline,
//! and any UTF-8 prompt round-trips exactly.

use crate::util::rng::Rng;

/// Encode text as i32 byte tokens.
pub fn encode(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

/// Decode tokens back to text (lossy on invalid UTF-8 boundaries).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Greedy argmax sampling.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Temperature + top-k sampling (paper §4.1 serves with temperature 0.8,
/// top-k 200; our byte vocab caps k at 256).
pub fn sample_topk(logits: &[f32], temperature: f32, k: usize, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 || k <= 1 {
        return argmax(logits);
    }
    let k = k.min(logits.len());
    // Partial top-k by index sort (vocab is tiny; simplicity wins).
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k);
    let maxv = logits[idx[0]];
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - maxv) / temperature) as f64).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    let mut u = rng.f64();
    for (i, &p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return idx[i] as i32;
        }
    }
    idx[k - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_utf8() {
        let text = "Hello, edge-cloud! ünïcødé";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn encode_is_bytes() {
        assert_eq!(encode("AB"), vec![65, 66]);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 2.0, -1.0, 1.9]), 1);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0, 5.0, 1.0];
        for _ in 0..10 {
            assert_eq!(sample_topk(&logits, 0.0, 200, &mut rng), 1);
        }
    }

    #[test]
    fn topk_respects_k() {
        let mut rng = Rng::new(2);
        // Only indices 1 and 3 are in the top-2.
        let logits = vec![0.0, 5.0, 1.0, 4.0];
        for _ in 0..200 {
            let t = sample_topk(&logits, 1.0, 2, &mut rng);
            assert!(t == 1 || t == 3, "sampled {t}");
        }
    }

    #[test]
    fn sampling_distribution_follows_logits() {
        let mut rng = Rng::new(3);
        let logits = vec![2.0, 0.0];
        let n = 5000;
        let ones = (0..n)
            .filter(|_| sample_topk(&logits, 1.0, 2, &mut rng) == 0)
            .count();
        let frac = ones as f64 / n as f64;
        // softmax(2,0) ≈ (0.88, 0.12)
        assert!((frac - 0.88).abs() < 0.03, "frac={frac}");
    }
}
