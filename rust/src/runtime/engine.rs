//! Model engine: loads the AOT HLO artifacts through the PJRT CPU client
//! and serves real prefill/decode steps from Rust — Python is never on
//! this path.
//!
//! One `ModelEngine` per deployment size. Weights live as device-resident
//! `PjRtBuffer`s created once at load; each step uploads only the small
//! dynamic inputs (tokens, positions, KV cache) and downloads logits + the
//! updated KV. Decode is compiled per batch bucket (1, 2, 4, 8); the
//! batcher pads the live request set up to the nearest bucket with dead
//! lanes (vLLM-style shape bucketing under AOT constraints).

use anyhow::{anyhow, bail, Result};

use super::artifacts::{Artifacts, ModelMeta};

/// Per-request KV cache: one contiguous `(2, L, S, KD)` f32 block (batch-
/// major layout in the HLO means request caches concatenate directly).
#[derive(Debug, Clone)]
pub struct KvCache {
    pub data: Vec<f32>,
}

impl KvCache {
    pub fn zeroed(meta: &ModelMeta) -> Self {
        KvCache {
            data: vec![0.0; meta.kv_len()],
        }
    }
}

/// A compiled model with resident weights.
pub struct ModelEngine {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    /// (batch, executable), ascending by batch.
    decode_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    /// Device-resident weights, in HLO parameter order.
    param_bufs: Vec<xla::PjRtBuffer>,
    /// Step counters (metrics).
    pub prefill_steps: u64,
    pub decode_steps: u64,
}

impl ModelEngine {
    /// Compile `model` ("edge" | "cloud") from an artifact directory on a
    /// shared PJRT CPU client.
    pub fn load(client: &xla::PjRtClient, arts: &Artifacts, model: &str) -> Result<Self> {
        let meta = arts
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?
            .clone();

        let compile = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = arts.hlo_path(model, kind);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
        };

        let prefill_exe = compile("prefill")?;
        let mut decode_exes = Vec::new();
        for &b in &arts.decode_batches {
            decode_exes.push((b, compile(&format!("decode_b{b}"))?));
        }
        decode_exes.sort_by_key(|(b, _)| *b);

        // Upload weights once; they are arguments to every execution.
        let blob = arts.load_params(model)?;
        let manifest = arts.load_manifest(model)?;
        let mut param_bufs = Vec::with_capacity(manifest.len());
        for e in &manifest {
            let slice = &blob[e.offset..e.offset + e.count];
            let dims = if e.dims.is_empty() { vec![e.count] } else { e.dims.clone() };
            let buf = client
                .buffer_from_host_buffer::<f32>(slice, &dims, None)
                .map_err(|e2| anyhow!("uploading {}: {e2:?}", e.name))?;
            param_bufs.push(buf);
        }

        Ok(ModelEngine {
            meta,
            client: client.clone(),
            prefill_exe,
            decode_exes,
            param_bufs,
            prefill_steps: 0,
            decode_steps: 0,
        })
    }

    /// Available decode batch buckets (ascending).
    pub fn batch_buckets(&self) -> Vec<usize> {
        self.decode_exes.iter().map(|(b, _)| *b).collect()
    }

    /// Smallest compiled bucket >= n (or the largest bucket if n exceeds
    /// them all — the caller must then split the batch).
    pub fn bucket_for(&self, n: usize) -> usize {
        for (b, _) in &self.decode_exes {
            if *b >= n {
                return *b;
            }
        }
        self.decode_exes.last().map(|(b, _)| *b).unwrap_or(1)
    }

    pub fn max_bucket(&self) -> usize {
        self.decode_exes.last().map(|(b, _)| *b).unwrap_or(1)
    }

    /// Run prefill on a prompt (<= max_seq tokens). Returns next-token
    /// logits and the populated KV cache.
    pub fn prefill(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, KvCache)> {
        let s = self.meta.max_seq;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > s {
            bail!("prompt length {} exceeds max_seq {s}", prompt.len());
        }
        let mut tokens = vec![0i32; s];
        tokens[..prompt.len()].copy_from_slice(prompt);

        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&tokens, &[1, s], None)
            .map_err(|e| anyhow!("tokens upload: {e:?}"))?;
        let len_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[prompt.len() as i32], &[], None)
            .map_err(|e| anyhow!("len upload: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);

        let result = self
            .prefill_exe
            .execute_b(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill readback: {e:?}"))?;
        let (logits_l, kv_l) = lit
            .to_tuple2()
            .map_err(|e| anyhow!("prefill tuple: {e:?}"))?;
        let logits = logits_l
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        let kv = kv_l.to_vec::<f32>().map_err(|e| anyhow!("kv: {e:?}"))?;
        debug_assert_eq!(kv.len(), self.meta.kv_len());
        self.prefill_steps += 1;
        Ok((logits, KvCache { data: kv }))
    }

    /// One continuous-batching decode iteration over `lanes` live requests.
    ///
    /// `tokens[i]` is the current token of lane i at absolute position
    /// `pos[i]`; `kvs[i]` is that lane's cache, updated in place. The batch
    /// is padded up to the compiled bucket with dead lanes.
    pub fn decode_batch(
        &mut self,
        tokens: &[i32],
        pos: &[usize],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        let n = tokens.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if pos.len() != n || kvs.len() != n {
            bail!("lane count mismatch: {n} tokens, {} pos, {} kvs", pos.len(), kvs.len());
        }
        if n > self.max_bucket() {
            bail!("batch {n} exceeds largest compiled bucket {}", self.max_bucket());
        }
        for (i, &p) in pos.iter().enumerate() {
            if p >= self.meta.max_seq {
                bail!("lane {i}: position {p} >= max_seq {}", self.meta.max_seq);
            }
        }
        let b = self.bucket_for(n);
        let exe_idx = self
            .decode_exes
            .iter()
            .position(|(bb, _)| *bb == b)
            .expect("bucket exists");

        let kv_len = self.meta.kv_len();
        let mut tok_pad = vec![0i32; b];
        let mut pos_pad = vec![0i32; b];
        let mut kv_pad = vec![0f32; b * kv_len];
        for i in 0..n {
            tok_pad[i] = tokens[i];
            pos_pad[i] = pos[i] as i32;
            kv_pad[i * kv_len..(i + 1) * kv_len].copy_from_slice(&kvs[i].data);
        }

        let meta = &self.meta;
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&tok_pad, &[b], None)
            .map_err(|e| anyhow!("tok upload: {e:?}"))?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&pos_pad, &[b], None)
            .map_err(|e| anyhow!("pos upload: {e:?}"))?;
        let kv_buf = self
            .client
            .buffer_from_host_buffer::<f32>(
                &kv_pad,
                &[b, 2, meta.n_layers, meta.max_seq, meta.kv_dim],
                None,
            )
            .map_err(|e| anyhow!("kv upload: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&kv_buf);

        let result = self.decode_exes[exe_idx]
            .1
            .execute_b(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode readback: {e:?}"))?;
        let (logits_l, kv_l) = lit.to_tuple2().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let logits_flat = logits_l.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let kv_out = kv_l.to_vec::<f32>().map_err(|e| anyhow!("kv out: {e:?}"))?;

        let v = meta.vocab;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(logits_flat[i * v..(i + 1) * v].to_vec());
            kvs[i]
                .data
                .copy_from_slice(&kv_out[i * kv_len..(i + 1) * kv_len]);
        }
        self.decode_steps += 1;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need built artifacts live in rust/tests/runtime_pjrt.rs
    // (integration, so the PJRT client is only spun up once per binary).
}
