//! Runtime layer: loads the build-time AOT artifacts (HLO text + weight
//! blobs) through the PJRT CPU client (`xla` crate) and serves real model
//! steps from the Rust request path. See /opt/xla-example/load_hlo for the
//! interchange pattern; DESIGN.md §3 for why HLO *text* is the format.

pub mod artifacts;
pub mod engine;
pub mod tokenizer;

pub use artifacts::{Artifacts, ModelMeta, ParamEntry};
pub use engine::{KvCache, ModelEngine};

use anyhow::Result;

/// Create the shared PJRT CPU client (one per process).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))
}

/// Default artifact directory: `$PERLLM_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("PERLLM_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}
