//! AOT artifact discovery: parse `artifacts/meta.txt` and the per-model
//! weight manifests emitted by `python/compile/aot.py`.
//!
//! Formats (plain text — no serde offline, and greppable by humans):
//!
//! ```text
//! meta.txt:      decode_batches 1 2 4 8
//!                model edge vocab 256 d_model 64 n_layers 2 n_heads 4 ...
//!                loss_curve edge 5.58 0.36 ...
//! manifest:      <name> f32 <offset> <count> <d0> <d1> ...
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Geometry of one AOT-compiled model size.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub kv_dim: usize,
}

impl ModelMeta {
    /// Floats in one request's KV cache: 2 (K,V) x L x S x KD.
    pub fn kv_len(&self) -> usize {
        2 * self.n_layers * self.max_seq * self.kv_dim
    }
}

/// One weight tensor in the flat parameter blob.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub count: usize,
    pub dims: Vec<usize>,
}

/// Parsed artifact directory.
#[derive(Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub decode_batches: Vec<usize>,
    pub models: HashMap<String, ModelMeta>,
    pub loss_curves: HashMap<String, Vec<f64>>,
}

impl Artifacts {
    pub fn discover(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.txt");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} — run `make artifacts` first"))?;
        let mut decode_batches = Vec::new();
        let mut models = HashMap::new();
        let mut loss_curves = HashMap::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("decode_batches") => {
                    decode_batches = it
                        .map(|s| s.parse::<usize>().context("bad batch"))
                        .collect::<Result<_>>()?;
                }
                Some("model") => {
                    let name = it.next().context("model name")?.to_string();
                    let mut kv: HashMap<&str, usize> = HashMap::new();
                    while let (Some(k), Some(v)) = (it.next(), it.next()) {
                        kv.insert(k, v.parse().with_context(|| format!("bad {k}"))?);
                    }
                    let get = |k: &str| -> Result<usize> {
                        kv.get(k).copied().with_context(|| format!("meta missing {k}"))
                    };
                    models.insert(
                        name.clone(),
                        ModelMeta {
                            name,
                            vocab: get("vocab")?,
                            d_model: get("d_model")?,
                            n_layers: get("n_layers")?,
                            n_heads: get("n_heads")?,
                            max_seq: get("max_seq")?,
                            kv_dim: get("kv_dim")?,
                        },
                    );
                }
                Some("loss_curve") => {
                    let name = it.next().context("curve name")?.to_string();
                    let pts = it.filter_map(|s| s.parse().ok()).collect();
                    loss_curves.insert(name, pts);
                }
                _ => {}
            }
        }
        if decode_batches.is_empty() || models.is_empty() {
            bail!("artifacts/meta.txt incomplete: {meta_path:?}");
        }
        Ok(Artifacts {
            dir,
            decode_batches,
            models,
            loss_curves,
        })
    }

    pub fn hlo_path(&self, model: &str, kind: &str) -> PathBuf {
        self.dir.join(format!("{model}_{kind}.hlo.txt"))
    }

    /// Load the flat little-endian f32 weight blob for a model.
    pub fn load_params(&self, model: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{model}_params.bin"));
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: length {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Parse the weight manifest (tensor order matches the HLO's parameter
    /// order, which is jax tree-leaf order).
    pub fn load_manifest(&self, model: &str) -> Result<Vec<ParamEntry>> {
        let path = self.dir.join(format!("{model}_manifest.txt"));
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let mut out = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let name = it.next().with_context(|| format!("{path:?}:{ln}"))?.to_string();
            let dtype = it.next().context("dtype")?;
            if dtype != "f32" {
                bail!("{path:?}:{ln}: unsupported dtype {dtype}");
            }
            let offset: usize = it.next().context("offset")?.parse()?;
            let count: usize = it.next().context("count")?.parse()?;
            let dims: Vec<usize> = it.map(|d| d.parse().unwrap()).collect();
            let prod: usize = dims.iter().product::<usize>().max(1);
            if prod != count {
                bail!("{path:?}:{ln}: dims {dims:?} != count {count}");
            }
            out.push(ParamEntry {
                name,
                offset,
                count,
                dims,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Artifacts> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Artifacts::discover(dir).ok()
    }

    #[test]
    fn discovers_built_artifacts() {
        let Some(a) = repo_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(a.models.contains_key("edge"));
        assert!(a.models.contains_key("cloud"));
        assert!(!a.decode_batches.is_empty());
        let edge = &a.models["edge"];
        assert_eq!(edge.vocab, 256);
        assert!(edge.kv_len() > 0);
    }

    #[test]
    fn manifest_matches_blob() {
        let Some(a) = repo_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for model in ["edge", "cloud"] {
            let params = a.load_params(model).unwrap();
            let manifest = a.load_manifest(model).unwrap();
            let total: usize = manifest.iter().map(|e| e.count).sum();
            assert_eq!(total, params.len(), "{model}: manifest vs blob");
            // Offsets are contiguous and ordered.
            let mut off = 0;
            for e in &manifest {
                assert_eq!(e.offset, off, "{model}/{}", e.name);
                off += e.count;
            }
        }
    }

    #[test]
    fn loss_curves_show_training() {
        let Some(a) = repo_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for (name, curve) in &a.loss_curves {
            assert!(curve.len() >= 2, "{name}");
            assert!(
                curve.last().unwrap() < &(curve[0] * 0.5),
                "{name}: loss did not drop: {curve:?}"
            );
        }
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Artifacts::discover("/nonexistent/path").is_err());
    }
}
