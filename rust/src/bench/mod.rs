//! Bench harness for `cargo bench` with `harness = false` (no criterion
//! offline): warmup + timed iterations, robust statistics, and the
//! paper-style table renderer the per-figure bench binaries share.

pub mod harness;

pub use harness::{bench_fn, render_json, BenchResult, JsonValue, Table};
