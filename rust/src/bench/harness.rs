//! Timing kit + table renderer for the harness-free benches, plus a tiny
//! JSON emitter so perf baselines (BENCH_perllm.json) are machine-diffable
//! across PRs without a serde dependency.

use std::time::Instant;

use crate::util::stats::Percentiles;

/// A flat JSON value for the bench-baseline emitter.
#[derive(Debug, Clone)]
pub enum JsonValue {
    Num(f64),
    Str(String),
}

impl JsonValue {
    fn render(&self) -> String {
        match self {
            // JSON has no NaN/inf; clamp to null.
            JsonValue::Num(x) if x.is_finite() => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    format!("{}", *x as i64)
                } else {
                    format!("{x}")
                }
            }
            JsonValue::Num(_) => "null".to_string(),
            JsonValue::Str(s) => format!("\"{}\"", json_escape(s)),
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render nested (section → key → value) pairs as a pretty-printed JSON
/// object, sections and keys in the order given.
pub fn render_json(sections: &[(&str, Vec<(&str, JsonValue)>)]) -> String {
    let mut out = String::from("{\n");
    for (si, (section, pairs)) in sections.iter().enumerate() {
        out.push_str(&format!("  \"{}\": {{\n", json_escape(section)));
        for (ki, (k, v)) in pairs.iter().enumerate() {
            let comma = if ki + 1 == pairs.len() { "" } else { "," };
            out.push_str(&format!(
                "    \"{}\": {}{}\n",
                json_escape(k),
                v.render(),
                comma
            ));
        }
        let comma = if si + 1 == sections.len() { "" } else { "," };
        out.push_str(&format!("  }}{}\n", comma));
    }
    out.push_str("}\n");
    out
}

/// Result of timing one closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<36} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Percentiles::new();
    let mut min = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now(); // lint: allow(wall-clock) wall time is the measurement here
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        samples.push(ns);
        total += ns;
        min = min.min(ns);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: total / iters as f64,
        p50_ns: samples.p50(),
        p95_ns: samples.p95(),
        min_ns: min,
    }
}

/// Fixed-width table renderer for paper-figure regenerators.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("{:<w$}  ", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_fn("spin", 2, 20, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.row().contains("spin"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5.0e3).contains("µs"));
        assert!(fmt_ns(5.0e6).contains("ms"));
        assert!(fmt_ns(5.0e9).contains("s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(&["1".into(), "2".into(), "3".into()]);
        t.row(&["xxx".into(), "y".into(), "zzzz".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn json_renders_sections() {
        let s = render_json(&[
            (
                "meta",
                vec![
                    ("name", JsonValue::Str("x \"y\"".into())),
                    ("n", JsonValue::Num(3.0)),
                ],
            ),
            ("perf", vec![("events_per_sec", JsonValue::Num(1234.5))]),
        ]);
        assert!(s.contains("\"meta\""));
        assert!(s.contains("\\\"y\\\""));
        assert!(s.contains("\"n\": 3"));
        assert!(s.contains("1234.5"));
        // Non-finite numbers become null, keeping the file valid JSON.
        let s = render_json(&[("perf", vec![("bad", JsonValue::Num(f64::NAN))])]);
        assert!(s.contains("null"));
    }
}
