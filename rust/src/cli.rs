//! From-scratch CLI argument parser (no `clap` offline) + the perllm
//! binary's subcommand definitions.
//!
//! Supports: subcommands, `--flag value`, `--flag=value`, boolean flags,
//! defaults, and generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declared option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed command line.
#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad number {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Subcommand spec.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        CommandSpec {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse this command's arguments (after the subcommand word).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut out = Parsed::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let Some(opt) = self.opts.iter().find(|o| o.name == name) else {
                    bail!("unknown option --{name} for `{}` (try --help)", self.name);
                };
                if opt.is_flag {
                    if inline_val.is_some() {
                        bail!("--{name} is a flag and takes no value");
                    }
                    out.flags.push(name);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    out.values.insert(name, val);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn help(&self) -> String {
        let mut s = format!("perllm {} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let d = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let kind = if o.is_flag { "" } else { " <value>" };
            s.push_str(&format!("  --{}{kind}\n      {}{d}\n", o.name, o.help));
        }
        s
    }
}

/// The perllm binary's command set.
pub fn commands() -> Vec<CommandSpec> {
    vec![
        CommandSpec::new("serve", "serve real AOT models with CS-UCB routing")
            .opt("artifacts", "artifact directory", None)
            .opt("requests", "number of requests to serve", Some("64"))
            .opt("edge-workers", "edge engine workers", Some("2"))
            .opt("max-new-tokens", "generation length", Some("48"))
            .opt("seed", "rng seed", Some("42"))
            .opt("scheduler", "cs-ucb|rewardless|fineinfer|agod", Some("cs-ucb")),
        CommandSpec::new("sim", "paper-scale DES experiment (Table 1 / Figs 4-6)")
            .opt("requests", "trace length", Some("10000"))
            .opt("model", "edge model deployment", Some("llama2-7b"))
            .opt("rate", "arrival rate req/s", Some("15"))
            .opt("seed", "rng seed", Some("42"))
            .opt("topology", "paper|edgeshard-10x|edgeshard-100x", Some("paper"))
            .opt(
                "shards",
                "DES engine shards: N or auto (omit = sequential engine)",
                None,
            )
            .flag("fluctuating", "±20% bandwidth fluctuation"),
        CommandSpec::new("version", "print version"),
    ]
}

pub fn global_help() -> String {
    let mut s = String::from("perllm — personalized edge-cloud LLM inference scheduling\n\ncommands:\n");
    for c in commands() {
        s.push_str(&format!("  {:<10} {}\n", c.name, c.about));
    }
    s.push_str("\nrun `perllm <command> --help` for command options\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CommandSpec {
        CommandSpec::new("test", "test command")
            .opt("count", "a number", Some("5"))
            .opt("name", "a string", None)
            .flag("verbose", "talk more")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&args(&[])).unwrap();
        assert_eq!(p.usize_or("count", 0).unwrap(), 5);
        assert_eq!(p.get("name"), None);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = spec().parse(&args(&["--count", "9", "--name=zed"])).unwrap();
        assert_eq!(p.usize_or("count", 0).unwrap(), 9);
        assert_eq!(p.get("name"), Some("zed"));
    }

    #[test]
    fn flags_and_positional() {
        let p = spec().parse(&args(&["--verbose", "extra1", "extra2"])).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&args(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse(&args(&["--count"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec().parse(&args(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let p = spec().parse(&args(&["--count", "x"])).unwrap();
        assert!(p.usize_or("count", 0).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = spec().help();
        assert!(h.contains("--count"));
        assert!(h.contains("default: 5"));
        assert!(!global_help().is_empty());
    }
}
