//! Continuous batcher: iteration-level scheduling over a step model.
//!
//! Requests join the decode batch as soon as a lane and KV pages are free
//! (prefill), leave the moment they finish (EOS/max tokens), and the batch
//! re-forms every iteration — Orca-style continuous batching, constrained
//! to the AOT-compiled batch buckets (pad up to the nearest bucket).
//!
//! The batcher is generic over [`StepModel`] so its logic is unit-tested
//! with a fake model; the PJRT-backed [`crate::runtime::ModelEngine`]
//! implements the trait for production.

use std::collections::VecDeque;

use anyhow::Result;

use super::kv::{KvPool, KvPoolConfig};
use crate::runtime::engine::KvCache;
use crate::runtime::tokenizer;
use crate::util::rng::Rng;

/// Minimal model interface the batcher needs.
pub trait StepModel {
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    fn max_batch(&self) -> usize;
    fn kv_len(&self) -> usize;
    fn prefill_step(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, KvCache)>;
    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[usize],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>>;
}

impl StepModel for crate::runtime::ModelEngine {
    fn max_seq(&self) -> usize {
        self.meta.max_seq
    }
    fn vocab(&self) -> usize {
        self.meta.vocab
    }
    fn max_batch(&self) -> usize {
        self.max_bucket()
    }
    fn kv_len(&self) -> usize {
        self.meta.kv_len()
    }
    fn prefill_step(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, KvCache)> {
        self.prefill(prompt)
    }
    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[usize],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.decode_batch(tokens, pos, kvs)
    }
}

/// A generation request submitted to the batcher.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Sampling temperature (0 = greedy) and top-k.
    pub temperature: f32,
    pub top_k: usize,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Iterations this request spent queued before prefill.
    pub queued_iters: u64,
    /// Wall-clock instant the request's first token was sampled (end of
    /// its prefill step) — the honest realized-TTFT anchor; callers
    /// subtract their own submit instant. Measured, not estimated: the
    /// prefill iteration can be much longer than a decode step, which is
    /// exactly the regime TTFT SLOs care about.
    pub first_token_at: std::time::Instant,
}

struct Lane {
    id: u64,
    kv: KvCache,
    last_token: i32,
    pos: usize,
    generated: Vec<i32>,
    max_new: usize,
    temperature: f32,
    top_k: usize,
    /// Iterations the request spent queued before prefill, fixed at
    /// admission — decode-path finishes report this (they used to
    /// hardcode 0, losing queue-wait attribution for every request that
    /// survived past prefill).
    queued_iters: u64,
    /// See [`GenResult::first_token_at`]; stamped at prefill sampling.
    first_token_at: std::time::Instant,
}

/// The continuous batcher over one model.
pub struct Batcher<M: StepModel> {
    pub model: M,
    pending: VecDeque<(GenRequest, u64)>,
    lanes: Vec<Lane>,
    pool: KvPool,
    rng: Rng,
    iter: u64,
    /// Metrics.
    pub iterations: u64,
    pub completed: u64,
    pub tokens_generated: u64,
    /// Running sum of batch occupancy (for mean batch size).
    occupancy_sum: u64,
}

impl<M: StepModel> Batcher<M> {
    pub fn new(model: M, seed: u64) -> Self {
        // Pool sized for the largest compiled bucket's worth of full
        // sequences, plus one queued-behind set.
        let pool_cfg = KvPoolConfig::for_sequences(model.max_batch() * 2, model.max_seq(), 16);
        Batcher {
            pool: KvPool::new(pool_cfg),
            model,
            pending: VecDeque::new(),
            lanes: Vec::new(),
            rng: Rng::new(seed),
            iter: 0,
            iterations: 0,
            completed: 0,
            tokens_generated: 0,
            occupancy_sum: 0,
        }
    }

    /// Queue a request (admission happens at iteration boundaries).
    pub fn submit(&mut self, req: GenRequest) {
        self.pending.push_back((req, self.iter));
    }

    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    pub fn active(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.lanes.is_empty()
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.iterations as f64
        }
    }

    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// One iteration: admit + prefill new lanes, run one decode step, and
    /// return any finished generations.
    pub fn step(&mut self) -> Result<Vec<GenResult>> {
        self.iter += 1;
        let mut finished = Vec::new();

        // Admission: fill free lanes with pending requests (prefill).
        while self.lanes.len() < self.model.max_batch() {
            let Some((req, submitted_iter)) = self.pending.front().cloned() else {
                break;
            };
            let prompt_len = req.prompt.len().min(self.model.max_seq() - 1);
            let budget = prompt_len + req.max_new_tokens.min(self.model.max_seq() - prompt_len);
            if !self.pool.can_admit(budget) {
                break; // KV pressure: retry next iteration.
            }
            self.pending.pop_front();
            self.pool.admit(req.id, budget)?;
            let prompt = &req.prompt[..prompt_len];
            let (logits, kv) = self.model.prefill_step(prompt)?;
            let tok = self.sample(&logits, req.temperature, req.top_k);
            let mut lane = Lane {
                id: req.id,
                kv,
                last_token: tok,
                pos: prompt_len,
                generated: vec![tok],
                max_new: req.max_new_tokens.min(self.model.max_seq() - prompt_len),
                temperature: req.temperature,
                top_k: req.top_k,
                queued_iters: self.iter - 1 - submitted_iter,
                first_token_at: std::time::Instant::now(),
            };
            lane.max_new = lane.max_new.max(1);
            // A 1-token budget finishes immediately after prefill.
            if lane.generated.len() >= lane.max_new || lane.pos + 1 >= self.model.max_seq() {
                self.pool.release(lane.id)?;
                self.completed += 1;
                self.tokens_generated += lane.generated.len() as u64;
                finished.push(GenResult {
                    id: lane.id,
                    tokens: lane.generated,
                    prompt_tokens: prompt_len,
                    queued_iters: lane.queued_iters,
                    first_token_at: lane.first_token_at,
                });
            } else {
                self.lanes.push(lane);
            }
        }

        // Decode step over all live lanes.
        if !self.lanes.is_empty() {
            self.iterations += 1;
            self.occupancy_sum += self.lanes.len() as u64;
            let tokens: Vec<i32> = self.lanes.iter().map(|l| l.last_token).collect();
            let pos: Vec<usize> = self.lanes.iter().map(|l| l.pos).collect();
            let mut kvs: Vec<&mut KvCache> =
                self.lanes.iter_mut().map(|l| &mut l.kv).collect();
            let logits = self.model.decode_step(&tokens, &pos, &mut kvs)?;

            let mut i = 0;
            while i < self.lanes.len() {
                let (temp, top_k) = (self.lanes[i].temperature, self.lanes[i].top_k);
                let tok = {
                    let l = &logits[i];
                    if temp <= 0.0 {
                        tokenizer::argmax(l)
                    } else {
                        tokenizer::sample_topk(l, temp, top_k, &mut self.rng)
                    }
                };
                let lane = &mut self.lanes[i];
                lane.pos += 1;
                lane.last_token = tok;
                lane.generated.push(tok);
                self.pool.extend(lane.id, 1)?;
                let done = lane.generated.len() >= lane.max_new
                    || lane.pos + 1 >= self.model.max_seq();
                if done {
                    let lane = self.lanes.swap_remove(i);
                    self.pool.release(lane.id)?;
                    self.completed += 1;
                    self.tokens_generated += lane.generated.len() as u64;
                    let n_gen = lane.generated.len();
                    finished.push(GenResult {
                        id: lane.id,
                        tokens: lane.generated,
                        prompt_tokens: lane.pos + 1 - n_gen,
                        queued_iters: lane.queued_iters,
                        first_token_at: lane.first_token_at,
                    });
                } else {
                    i += 1;
                }
            }
        }
        Ok(finished)
    }

    fn sample(&mut self, logits: &[f32], temp: f32, top_k: usize) -> i32 {
        if temp <= 0.0 {
            tokenizer::argmax(logits)
        } else {
            tokenizer::sample_topk(logits, temp, top_k, &mut self.rng)
        }
    }

    /// Drive to completion (used by tests and offline evaluation).
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }
}

/// Deterministic fake model for coordinator tests (no PJRT needed).
#[cfg(test)]
pub mod tests_support {
    use super::*;

    /// "Generation" rule: next token = (prev token + position) % vocab.
    pub struct FakeModel {
        pub max_seq: usize,
        pub vocab: usize,
        pub max_batch: usize,
        pub prefills: u64,
        pub decodes: u64,
    }

    impl FakeModel {
        pub fn new() -> Self {
            FakeModel {
                max_seq: 32,
                vocab: 64,
                max_batch: 4,
                prefills: 0,
                decodes: 0,
            }
        }
    }

    impl StepModel for FakeModel {
        fn max_seq(&self) -> usize {
            self.max_seq
        }
        fn vocab(&self) -> usize {
            self.vocab
        }
        fn max_batch(&self) -> usize {
            self.max_batch
        }
        fn kv_len(&self) -> usize {
            8
        }
        fn prefill_step(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, KvCache)> {
            self.prefills += 1;
            let sum: i32 = prompt.iter().sum();
            let mut logits = vec![0.0f32; self.vocab];
            logits[(sum as usize) % self.vocab] = 10.0;
            Ok((
                logits,
                KvCache {
                    data: vec![sum as f32; 8],
                },
            ))
        }
        fn decode_step(
            &mut self,
            tokens: &[i32],
            pos: &[usize],
            kvs: &mut [&mut KvCache],
        ) -> Result<Vec<Vec<f32>>> {
            self.decodes += 1;
            let mut out = Vec::new();
            for i in 0..tokens.len() {
                let mut logits = vec![0.0f32; self.vocab];
                logits[((tokens[i] as usize) + pos[i]) % self.vocab] = 10.0;
                kvs[i].data[0] += 1.0;
                out.push(logits);
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::FakeModel;
    use super::*;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens: max_new,
            temperature: 0.0,
            top_k: 1,
        }
    }

    #[test]
    fn single_request_completes_exact_length() {
        let mut b = Batcher::new(FakeModel::new(), 1);
        b.submit(req(1, vec![1, 2, 3], 5));
        let results = b.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 1);
        assert_eq!(results[0].tokens.len(), 5);
        assert_eq!(results[0].prompt_tokens, 3);
        assert_eq!(b.completed, 1);
        assert_eq!(b.tokens_generated, 5);
    }

    #[test]
    fn deterministic_generation_matches_model_rule() {
        let mut b = Batcher::new(FakeModel::new(), 1);
        b.submit(req(1, vec![1, 2], 3));
        let results = b.run_to_completion().unwrap();
        // prefill: sum=3 -> tok 3 at pos 2; decode: (3+2)=5; decode: (5+3)=8.
        assert_eq!(results[0].tokens, vec![3, 5, 8]);
    }

    #[test]
    fn conservation_every_request_finishes_once() {
        let mut b = Batcher::new(FakeModel::new(), 2);
        for i in 0..20 {
            b.submit(req(i, vec![i as i32 % 7 + 1], 1 + (i as usize % 6)));
        }
        let results = b.run_to_completion().unwrap();
        assert_eq!(results.len(), 20);
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
        // KV pool fully drained.
        assert_eq!(b.kv_pool().n_sequences(), 0);
        b.kv_pool().check_invariants().unwrap();
    }

    #[test]
    fn batch_never_exceeds_bucket() {
        let mut b = Batcher::new(FakeModel::new(), 3);
        for i in 0..12 {
            b.submit(req(i, vec![1, 2, 3], 8));
        }
        while !b.is_idle() {
            b.step().unwrap();
            assert!(b.active() <= 4, "active {} > bucket", b.active());
        }
        assert_eq!(b.completed, 12);
        // Continuous batching actually batched (mean occupancy > 1).
        assert!(b.mean_batch_occupancy() > 1.5, "{}", b.mean_batch_occupancy());
    }

    #[test]
    fn long_prompts_truncated_to_max_seq() {
        let mut b = Batcher::new(FakeModel::new(), 4);
        b.submit(req(1, vec![1; 100], 10)); // prompt longer than max_seq 32
        let results = b.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert!(results[0].prompt_tokens <= 31);
    }

    #[test]
    fn generation_capped_by_max_seq() {
        let mut b = Batcher::new(FakeModel::new(), 5);
        b.submit(req(1, vec![1; 30], 100)); // only ~2 tokens of room
        let results = b.run_to_completion().unwrap();
        assert!(results[0].tokens.len() <= 2 + 1);
    }

    /// Regression: decode-path finishes used to hardcode `queued_iters:
    /// 0`, so any request that generated more than its prefill token lost
    /// its queue-wait attribution. Oversubscribe the bucket (8 requests,
    /// bucket 4, several decode iterations each): the second wave must
    /// report positive queued iterations, and the first wave zero.
    #[test]
    fn decode_path_reports_real_queued_iters() {
        let mut b = Batcher::new(FakeModel::new(), 7);
        for i in 0..8 {
            b.submit(req(i, vec![1, 2], 4)); // 4 decode tokens each
        }
        let results = b.run_to_completion().unwrap();
        assert_eq!(results.len(), 8);
        let by_id = |id: u64| results.iter().find(|r| r.id == id).unwrap();
        for i in 0..4 {
            assert_eq!(by_id(i).queued_iters, 0, "first wave waited: req {i}");
        }
        for i in 4..8 {
            assert!(
                by_id(i).queued_iters > 0,
                "second wave must report its wait: req {i} got {}",
                by_id(i).queued_iters
            );
            // Every result came through the decode path (4 tokens > 1), so
            // a zero here is exactly the old hardcode resurfacing.
            assert_eq!(by_id(i).tokens.len(), 4);
        }
    }

    #[test]
    fn queueing_when_oversubscribed() {
        let mut b = Batcher::new(FakeModel::new(), 6);
        for i in 0..8 {
            b.submit(req(i, vec![1], 4));
        }
        b.step().unwrap();
        // Bucket is 4: the rest remain queued.
        assert!(b.active() <= 4);
        assert!(b.queued() >= 4);
        let results = b.run_to_completion().unwrap();
        assert_eq!(results.len() + 0, 8);
    }
}
