//! Serving metrics: latency/throughput counters shared between the worker
//! threads and the leader, plus paper-style report rendering.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Percentiles;

/// Lock-free counters updated by workers; latencies behind a small mutex.
#[derive(Debug)]
pub struct ServingMetrics {
    start: Instant,
    pub requests_in: AtomicU64,
    pub requests_done: AtomicU64,
    pub tokens_out: AtomicU64,
    pub prefill_steps: AtomicU64,
    pub decode_steps: AtomicU64,
    latencies_ms: Mutex<Percentiles>,
    queue_waits_ms: Mutex<Percentiles>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            start: Instant::now(),
            requests_in: AtomicU64::new(0),
            requests_done: AtomicU64::new(0),
            tokens_out: AtomicU64::new(0),
            prefill_steps: AtomicU64::new(0),
            decode_steps: AtomicU64::new(0),
            latencies_ms: Mutex::new(Percentiles::new()),
            queue_waits_ms: Mutex::new(Percentiles::new()),
        }
    }

    pub fn record_arrival(&self) {
        self.requests_in.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, latency_ms: f64, queue_wait_ms: f64, tokens: u64) {
        self.requests_done.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens, Ordering::Relaxed);
        self.latencies_ms.lock().unwrap().push(latency_ms);
        self.queue_waits_ms.lock().unwrap().push(queue_wait_ms);
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out.load(Ordering::Relaxed) as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests_done.load(Ordering::Relaxed) as f64 / self.elapsed_s().max(1e-9)
    }

    /// Multi-line human report (the serve_model example prints this).
    pub fn report(&self) -> String {
        let mut lat = self.latencies_ms.lock().unwrap();
        let mut qw = self.queue_waits_ms.lock().unwrap();
        format!(
            "requests: {} in / {} done | tokens out: {} | elapsed {:.2}s\n\
             throughput: {:.1} tok/s, {:.2} req/s\n\
             latency ms: mean {:.1} p50 {:.1} p95 {:.1} p99 {:.1}\n\
             queue wait ms: p50 {:.1} p95 {:.1}",
            self.requests_in.load(Ordering::Relaxed),
            self.requests_done.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.elapsed_s(),
            self.throughput_tok_s(),
            self.requests_per_s(),
            lat.mean(),
            lat.p50(),
            lat.p95(),
            lat.p99(),
            qw.p50(),
            qw.p95(),
        )
    }

    pub fn p95_latency_ms(&self) -> f64 {
        self.latencies_ms.lock().unwrap().p95()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latencies_ms.lock().unwrap().mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServingMetrics::new();
        m.record_arrival();
        m.record_arrival();
        m.record_completion(10.0, 1.0, 42);
        assert_eq!(m.requests_in.load(Ordering::Relaxed), 2);
        assert_eq!(m.requests_done.load(Ordering::Relaxed), 1);
        assert_eq!(m.tokens_out.load(Ordering::Relaxed), 42);
        assert!(m.mean_latency_ms() > 9.9);
        let rep = m.report();
        assert!(rep.contains("tokens out: 42"), "{rep}");
    }

    #[test]
    fn percentiles_in_report() {
        let m = ServingMetrics::new();
        for i in 1..=100 {
            m.record_completion(i as f64, 0.5, 1);
        }
        assert!((m.p95_latency_ms() - 95.05).abs() < 0.5);
    }
}
