//! Serving metrics: latency/throughput counters shared between the worker
//! threads and the leader, plus paper-style report rendering.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Percentiles;

/// Lock-free counters updated by workers; latencies behind a small mutex.
#[derive(Debug)]
pub struct ServingMetrics {
    start: Instant,
    pub requests_in: AtomicU64,
    pub requests_done: AtomicU64,
    pub tokens_out: AtomicU64,
    pub prefill_steps: AtomicU64,
    pub decode_steps: AtomicU64,
    /// SLO violations by constraint family (requests whose contract
    /// carried the constraint and missed it).
    slo_ttft_violations: AtomicU64,
    slo_completion_violations: AtomicU64,
    latencies_ms: Mutex<Percentiles>,
    queue_waits_ms: Mutex<Percentiles>,
    ttft_ms: Mutex<Percentiles>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            start: Instant::now(),
            requests_in: AtomicU64::new(0),
            requests_done: AtomicU64::new(0),
            tokens_out: AtomicU64::new(0),
            prefill_steps: AtomicU64::new(0),
            decode_steps: AtomicU64::new(0),
            slo_ttft_violations: AtomicU64::new(0),
            slo_completion_violations: AtomicU64::new(0),
            latencies_ms: Mutex::new(Percentiles::new()),
            queue_waits_ms: Mutex::new(Percentiles::new()),
            ttft_ms: Mutex::new(Percentiles::new()),
        }
    }

    pub fn record_arrival(&self) {
        self.requests_in.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, latency_ms: f64, queue_wait_ms: f64, tokens: u64) {
        self.requests_done.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens, Ordering::Relaxed);
        self.latencies_ms.lock().unwrap().push(latency_ms);
        self.queue_waits_ms.lock().unwrap().push(queue_wait_ms);
    }

    /// Record a completion's SLO verdicts (None = the contract did not
    /// carry that constraint) and its realized TTFT.
    pub fn record_slo(
        &self,
        ttft_met: Option<bool>,
        completion_met: Option<bool>,
        ttft_ms: f64,
    ) {
        self.ttft_ms.lock().unwrap().push(ttft_ms);
        if ttft_met == Some(false) {
            self.slo_ttft_violations.fetch_add(1, Ordering::Relaxed);
        }
        if completion_met == Some(false) {
            self.slo_completion_violations.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn slo_ttft_violations(&self) -> u64 {
        self.slo_ttft_violations.load(Ordering::Relaxed)
    }

    pub fn slo_completion_violations(&self) -> u64 {
        self.slo_completion_violations.load(Ordering::Relaxed)
    }

    pub fn p95_ttft_ms(&self) -> f64 {
        self.ttft_ms.lock().unwrap().p95()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out.load(Ordering::Relaxed) as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests_done.load(Ordering::Relaxed) as f64 / self.elapsed_s().max(1e-9)
    }

    /// Multi-line human report (the serve_model example prints this).
    pub fn report(&self) -> String {
        let mut lat = self.latencies_ms.lock().unwrap();
        let mut qw = self.queue_waits_ms.lock().unwrap();
        let mut tt = self.ttft_ms.lock().unwrap();
        format!(
            "requests: {} in / {} done | tokens out: {} | elapsed {:.2}s\n\
             throughput: {:.1} tok/s, {:.2} req/s\n\
             latency ms: mean {:.1} p50 {:.1} p95 {:.1} p99 {:.1}\n\
             ttft ms: p50 {:.1} p95 {:.1} | queue wait ms: p50 {:.1} p95 {:.1}\n\
             slo violations: ttft {} completion {}",
            self.requests_in.load(Ordering::Relaxed),
            self.requests_done.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.elapsed_s(),
            self.throughput_tok_s(),
            self.requests_per_s(),
            lat.mean(),
            lat.p50(),
            lat.p95(),
            lat.p99(),
            tt.p50(),
            tt.p95(),
            qw.p50(),
            qw.p95(),
            self.slo_ttft_violations.load(Ordering::Relaxed),
            self.slo_completion_violations.load(Ordering::Relaxed),
        )
    }

    pub fn p95_latency_ms(&self) -> f64 {
        self.latencies_ms.lock().unwrap().p95()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latencies_ms.lock().unwrap().mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServingMetrics::new();
        m.record_arrival();
        m.record_arrival();
        m.record_completion(10.0, 1.0, 42);
        assert_eq!(m.requests_in.load(Ordering::Relaxed), 2);
        assert_eq!(m.requests_done.load(Ordering::Relaxed), 1);
        assert_eq!(m.tokens_out.load(Ordering::Relaxed), 42);
        assert!(m.mean_latency_ms() > 9.9);
        let rep = m.report();
        assert!(rep.contains("tokens out: 42"), "{rep}");
    }

    #[test]
    fn slo_counters_split_by_family() {
        let m = ServingMetrics::new();
        m.record_slo(Some(true), Some(true), 5.0);
        m.record_slo(Some(false), Some(true), 50.0);
        m.record_slo(None, Some(false), 8.0);
        m.record_slo(None, None, 2.0);
        assert_eq!(m.slo_ttft_violations(), 1);
        assert_eq!(m.slo_completion_violations(), 1);
        assert!(m.p95_ttft_ms() > 0.0);
        let rep = m.report();
        assert!(rep.contains("slo violations: ttft 1 completion 1"), "{rep}");
    }

    #[test]
    fn percentiles_in_report() {
        let m = ServingMetrics::new();
        for i in 1..=100 {
            m.record_completion(i as f64, 0.5, 1);
        }
        assert!((m.p95_latency_ms() - 95.05).abs() < 0.5);
    }
}
