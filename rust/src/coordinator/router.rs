//! Live request router: builds a CMAB cluster view from real worker
//! telemetry and delegates the placement decision to any [`Scheduler`]
//! (CS-UCB in production, baselines for ablation).
//!
//! This is the serving-path twin of the DES's `ClusterSim::view`: the same
//! decision interface fed by measured statistics (queue depths, EMA step
//! times) instead of simulated state, so the paper's scheduler runs
//! unchanged on both substrates.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::scheduler::{ClusterView, Scheduler, ServerView};
use crate::sim::energy::EnergyWeights;
use crate::sim::server::ServerKind;
use crate::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest};

/// Telemetry one worker exposes to the router (all lock-free). Capacity
/// fields are atomics because the engine loads inside the worker thread
/// (PJRT handles are not Send) and publishes its real bucket size then.
#[derive(Debug)]
pub struct WorkerTelemetry {
    pub kind: ServerKind,
    /// Engine capacity: largest compiled decode bucket.
    pub max_batch: AtomicUsize,
    /// Bounded admission queue length target.
    pub queue_cap: AtomicUsize,
    pub queued: AtomicUsize,
    pub active: AtomicUsize,
    /// EMA of per-token decode wall time, microseconds (f64 bits).
    ema_us_per_token: AtomicU64,
    /// Energy proxy: joules per generated token (configured, not measured —
    /// the CPU testbed has no RAPL access; DESIGN.md §2).
    pub j_per_token: f64,
    pub tx_j_per_request: f64,
}

impl WorkerTelemetry {
    pub fn new(kind: ServerKind, max_batch: usize, queue_cap: usize) -> Self {
        let (j_tok, tx_j) = match kind {
            ServerKind::Edge => (0.9, 0.4),
            ServerKind::Cloud => (4.5, 1.6),
        };
        WorkerTelemetry {
            kind,
            max_batch: AtomicUsize::new(max_batch),
            queue_cap: AtomicUsize::new(queue_cap),
            queued: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            ema_us_per_token: AtomicU64::new(f64::to_bits(2000.0)),
            j_per_token: j_tok,
            tx_j_per_request: tx_j,
        }
    }

    pub fn record_step_time(&self, us_per_token: f64) {
        // EMA with alpha 0.2; CAS loop keeps it lock-free.
        loop {
            let cur = self.ema_us_per_token.load(Ordering::Relaxed);
            let cur_f = f64::from_bits(cur);
            let new_f = 0.8 * cur_f + 0.2 * us_per_token;
            if self
                .ema_us_per_token
                .compare_exchange_weak(cur, f64::to_bits(new_f), Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
        }
    }

    pub fn us_per_token(&self) -> f64 {
        f64::from_bits(self.ema_us_per_token.load(Ordering::Relaxed))
    }
}

/// The leader's router: scheduler + live telemetry.
pub struct Router {
    scheduler: Box<dyn Scheduler>,
    pub workers: Vec<Arc<WorkerTelemetry>>,
    weights: EnergyWeights,
    decisions: u64,
    /// Requests routed to each worker and not yet completed — the router's
    /// own in-flight bookkeeping (worker telemetry lags behind the mailbox,
    /// exactly the thundering-herd hazard the DES engine also guards
    /// against; see sim/cluster.rs InFlight).
    outstanding: Vec<usize>,
}

impl Router {
    pub fn new(scheduler: Box<dyn Scheduler>, workers: Vec<Arc<WorkerTelemetry>>) -> Self {
        Router {
            outstanding: vec![0; workers.len()],
            scheduler,
            workers,
            weights: EnergyWeights::default(),
            decisions: 0,
        }
    }

    /// Snapshot telemetry into the scheduler-facing view for one request.
    pub fn view(&self, expected_tokens: usize) -> ClusterView {
        let servers = self
            .workers
            .iter()
            .zip(&self.outstanding)
            .map(|(w, &outst)| {
                // Whichever is larger: what the worker has observed, or what
                // we know we have sent it (telemetry lags the mailbox).
                let queued = w.queued.load(Ordering::Relaxed);
                let active = w.active.load(Ordering::Relaxed);
                let queued = queued.max(outst.saturating_sub(active));
                let us_tok = w.us_per_token();
                // Everyone ahead of us plus ourselves, times per-token time.
                let inflight_tokens = (queued + active + 1) * expected_tokens;
                let predicted = inflight_tokens as f64 * us_tok / 1.0e6;
                let cap = (w.max_batch.load(Ordering::Relaxed)
                    + w.queue_cap.load(Ordering::Relaxed)) as f64;
                let used = (queued + active) as f64;
                ServerView {
                    kind: w.kind,
                    predicted_time: predicted,
                    compute_headroom: (cap - used).max(0.0),
                    compute_demand: 1.0,
                    bandwidth_headroom: 1.0e9,
                    bandwidth_demand: 1.0e6,
                    tx_energy_est: w.tx_j_per_request,
                    infer_energy_est: w.j_per_token * expected_tokens as f64,
                    n_active: active,
                    n_waiting: queued,
                    solo_time_est: expected_tokens as f64 * us_tok / 1.0e6,
                    occupancy: used / cap,
                }
            })
            .collect();
        ClusterView {
            now: 0.0,
            servers,
            weights: self.weights,
        }
    }

    /// Route one request; returns the worker index.
    pub fn route(&mut self, req: &ServiceRequest) -> usize {
        self.decisions += 1;
        let view = self.view((req.prompt_tokens + req.output_tokens) as usize);
        let d = self.scheduler.decide(req, &view);
        let w = d.server.min(self.workers.len() - 1);
        self.outstanding[w] += 1;
        w
    }

    /// Feed the realized outcome back to the bandit.
    pub fn complete(&mut self, outcome: &ServiceOutcome) {
        if let Some(o) = self.outstanding.get_mut(outcome.server) {
            *o = o.saturating_sub(1);
        }
        let view = self.view(outcome.tokens.max(1) as usize);
        self.scheduler.feedback(outcome, &view);
    }

    pub fn diagnostics(&self) -> Vec<(String, f64)> {
        self.scheduler.diagnostics()
    }

    /// Helper to build the ServiceRequest the scheduler expects from a raw
    /// serving request.
    pub fn service_request(
        id: u64,
        class: ServiceClass,
        prompt_tokens: usize,
        output_tokens: usize,
        deadline_s: f64,
    ) -> ServiceRequest {
        ServiceRequest {
            id,
            class,
            arrival: 0.0,
            prompt_tokens: prompt_tokens as u32,
            output_tokens: output_tokens as u32,
            deadline: deadline_s,
            payload_bytes: 4096 + prompt_tokens as u64 * 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::csucb::CsUcb;

    fn telemetry(kind: ServerKind) -> Arc<WorkerTelemetry> {
        Arc::new(WorkerTelemetry::new(kind, 4, 8))
    }

    #[test]
    fn routes_within_bounds_and_learns() {
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        let mut router = Router::new(Box::new(CsUcb::with_defaults(2)), workers);
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 5.0);
        for _ in 0..50 {
            let w = router.route(&req);
            assert!(w < 2);
        }
    }

    #[test]
    fn view_reflects_telemetry() {
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        workers[0].queued.store(6, Ordering::Relaxed);
        workers[0].active.store(4, Ordering::Relaxed);
        workers[0].record_step_time(5000.0);
        let router = Router::new(Box::new(CsUcb::with_defaults(2)), workers);
        let view = router.view(32);
        assert!(view.servers[0].predicted_time > view.servers[1].predicted_time);
        assert!(view.servers[0].occupancy > view.servers[1].occupancy);
        assert!(view.servers[0].compute_headroom < view.servers[1].compute_headroom);
    }

    #[test]
    fn ema_converges() {
        let w = telemetry(ServerKind::Edge);
        for _ in 0..100 {
            w.record_step_time(1000.0);
        }
        assert!((w.us_per_token() - 1000.0).abs() < 50.0);
    }

    #[test]
    fn loaded_worker_avoided_under_deadline() {
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Edge)];
        // Worker 0 heavily loaded and slow.
        workers[0].queued.store(12, Ordering::Relaxed);
        workers[0].record_step_time(50_000.0);
        let mut router = Router::new(Box::new(CsUcb::with_defaults(2)), workers);
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 2.0);
        let mut to_1 = 0;
        for _ in 0..20 {
            if router.route(&req) == 1 {
                to_1 += 1;
            }
        }
        assert!(to_1 >= 18, "routed to loaded worker too often: {to_1}");
    }
}
