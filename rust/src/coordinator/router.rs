//! Live request router: builds a CMAB cluster view from real worker
//! telemetry and delegates the placement decision to any [`Scheduler`]
//! (CS-UCB in production, baselines for ablation).
//!
//! This is the serving-path twin of the DES cluster: it implements the
//! same [`ViewSource`] trait (one `view_into` filling a caller-owned
//! snapshot) and consumes the same [`Action`] decisions, so the paper's
//! scheduler runs unchanged on both substrates. The router keeps one
//! scratch `ClusterView` and refills it per `route()`/`complete()` — the
//! per-request heap allocations the PR-1 router still performed are gone
//! (verified by the allocation-counting test in `tests/router_alloc.rs`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::kv::PrefixRegistry;
use crate::scheduler::{Action, ClusterView, Scheduler, ServerView, ShedReason, ViewSource};
use crate::sim::energy::EnergyWeights;
use crate::sim::server::ServerKind;
use crate::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest, SessionRef, SloSpec};

/// Telemetry one worker exposes to the router (all lock-free). Capacity
/// fields are atomics because the engine loads inside the worker thread
/// (PJRT handles are not Send) and publishes its real bucket size then.
#[derive(Debug)]
pub struct WorkerTelemetry {
    pub kind: ServerKind,
    /// Engine capacity: largest compiled decode bucket.
    pub max_batch: AtomicUsize,
    /// Bounded admission queue length target.
    pub queue_cap: AtomicUsize,
    pub queued: AtomicUsize,
    pub active: AtomicUsize,
    /// EMA of per-token decode wall time, microseconds (f64 bits).
    ema_us_per_token: AtomicU64,
    /// Energy proxy: joules per generated token (configured, not measured —
    /// the CPU testbed has no RAPL access; DESIGN.md §2).
    pub j_per_token: f64,
    pub tx_j_per_request: f64,
}

impl WorkerTelemetry {
    pub fn new(kind: ServerKind, max_batch: usize, queue_cap: usize) -> Self {
        let (j_tok, tx_j) = match kind {
            ServerKind::Edge => (0.9, 0.4),
            ServerKind::Cloud => (4.5, 1.6),
        };
        WorkerTelemetry {
            kind,
            max_batch: AtomicUsize::new(max_batch),
            queue_cap: AtomicUsize::new(queue_cap),
            queued: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            ema_us_per_token: AtomicU64::new(f64::to_bits(2000.0)),
            j_per_token: j_tok,
            tx_j_per_request: tx_j,
        }
    }

    pub fn record_step_time(&self, us_per_token: f64) {
        // EMA with alpha 0.2; CAS loop keeps it lock-free.
        loop {
            let cur = self.ema_us_per_token.load(Ordering::Relaxed);
            let cur_f = f64::from_bits(cur);
            let new_f = 0.8 * cur_f + 0.2 * us_per_token;
            if self
                .ema_us_per_token
                .compare_exchange_weak(cur, f64::to_bits(new_f), Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
        }
    }

    pub fn us_per_token(&self) -> f64 {
        f64::from_bits(self.ema_us_per_token.load(Ordering::Relaxed))
    }
}

/// What the router did with one request — the serving-side projection of
/// the scheduler's [`Action`]. The live substrate has no timer wheel, so
/// `Defer` reports the requested delay and lets the caller decide (the
/// serving cluster dispatches immediately: its workers batch
/// continuously, which is what a deferred-batching window approximates).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Routed {
    /// Dispatch to this worker now.
    Assign { worker: usize },
    /// The policy asked to hold the request `delay_s` before dispatching.
    Defer { worker: usize, delay_s: f64 },
    /// Rejected by policy. Bandit feedback was already delivered; no
    /// completion will ever arrive for this request.
    Shed { reason: ShedReason },
}

impl Routed {
    /// Target worker, if the request was placed anywhere.
    pub fn worker(&self) -> Option<usize> {
        match *self {
            Routed::Assign { worker } | Routed::Defer { worker, .. } => Some(worker),
            Routed::Shed { .. } => None,
        }
    }
}

/// The leader's router: scheduler + live telemetry.
pub struct Router {
    scheduler: Box<dyn Scheduler>,
    pub workers: Vec<Arc<WorkerTelemetry>>,
    weights: EnergyWeights,
    decisions: u64,
    /// Requests routed to each worker and not yet completed — the router's
    /// own in-flight bookkeeping (worker telemetry lags behind the mailbox,
    /// exactly the thundering-herd hazard the DES engine also guards
    /// against; see sim/cluster.rs InFlight).
    outstanding: Vec<usize>,
    /// Scratch snapshot refilled per route()/complete(): the live decision
    /// path performs zero per-request heap allocations once the buffer has
    /// grown to cluster size.
    scratch: ClusterView,
    /// Requests rejected by the policy (`Action::Shed`).
    sheds: u64,
    /// Out-of-range scheduler targets recovered via least-violating — a
    /// scheduler bug, logged rather than silently clamped.
    bad_assignments: u64,
    /// Observation clock stamped into every view (`ClusterView::now`).
    /// Defaults to 0.0 (frozen — the historical behavior); owners that
    /// host time-dependent policies (deferred batching windows, the
    /// admission gate's token refill) advance it via [`Self::set_now`],
    /// e.g. from an `Instant` at the serving front door.
    now_s: f64,
    /// Session→worker KV residency mirror (`None` until enabled via
    /// [`Self::with_prefix_registry`]). When present, `route()` records
    /// every session placement and the view fill prices
    /// `prefix_hit_tokens`/`prefix_pressure` from it — the live-substrate
    /// twin of the DES `PrefixCache` signal.
    prefix: Option<PrefixRegistry>,
}

impl Router {
    pub fn new(scheduler: Box<dyn Scheduler>, workers: Vec<Arc<WorkerTelemetry>>) -> Self {
        let weights = EnergyWeights::default();
        Router {
            outstanding: vec![0; workers.len()],
            scratch: ClusterView::with_capacity(workers.len(), weights),
            scheduler,
            workers,
            weights,
            decisions: 0,
            sheds: 0,
            bad_assignments: 0,
            now_s: 0.0,
            prefix: None,
        }
    }

    /// Enable session KV-residency tracking: one [`PrefixRegistry`] slot
    /// per worker, `capacity_tokens` of nominal KV-cache per worker (the
    /// pressure denominator). Sessionless routers skip this and every
    /// view reports cold caches — bit-identical to the pre-session
    /// router.
    pub fn with_prefix_registry(mut self, capacity_tokens: u64) -> Self {
        self.prefix = Some(PrefixRegistry::new(self.workers.len(), capacity_tokens));
        self
    }

    /// The residency mirror, if enabled (inspection/metrics).
    pub fn prefix_registry(&self) -> Option<&PrefixRegistry> {
        self.prefix.as_ref()
    }

    /// Drop a finished conversation's residency so its tokens stop
    /// counting toward cache pressure. No-op when tracking is off or the
    /// session is unknown.
    pub fn end_session(&mut self, session_id: u64) {
        if let Some(reg) = self.prefix.as_mut() {
            reg.release(session_id);
        }
    }

    /// Advance the router's observation clock (monotone; earlier stamps
    /// are ignored). Views filled afterwards carry it as
    /// `ClusterView::now`, which is what drives time-dependent policies —
    /// the admission gate's token refill, FineInfer's batch windows — on
    /// the live substrate.
    pub fn set_now(&mut self, now_s: f64) {
        if now_s > self.now_s {
            self.now_s = now_s;
        }
    }

    /// Build a router fleet from a simulated topology description: one
    /// telemetry slot per topology server, capacity fields seeded from the
    /// server spec (batch slots / bounded queue). This is how a
    /// multi-tier `TopologyConfig` (EdgeShard-style presets included)
    /// projects onto the live serving substrate — the same scheduler then
    /// runs unchanged against either.
    pub fn from_topology(
        scheduler: Box<dyn Scheduler>,
        topo: &crate::sim::topology::TopologyConfig,
    ) -> Self {
        let workers = topo
            .build()
            .servers
            .iter()
            .map(|s| Arc::new(WorkerTelemetry::new(s.kind, s.slots, s.queue_limit)))
            .collect();
        Router::new(scheduler, workers)
    }

    /// Fill `out` with the telemetry snapshot for a request expected to
    /// move `expected_tokens` tokens. This is the single fill routine
    /// behind both the [`ViewSource`] impl and `complete()`. `session`
    /// carries the request's conversation identity so per-worker
    /// residency can be priced into the view (`None` for sessionless
    /// requests and completion-side refills — cold caches everywhere).
    fn fill_view(&self, expected_tokens: usize, session: Option<&SessionRef>, out: &mut ClusterView) {
        // lint: no-alloc per-request snapshot refill; `out` buffers amortize to fleet size
        out.now = self.now_s;
        out.weights = self.weights;
        // No admissibility index on the live substrate (telemetry is
        // already O(workers) to read): empty = full-scan sentinel.
        out.candidates.clear();
        out.servers.clear();
        out.servers.extend(
            self.workers
                .iter()
                .zip(&self.outstanding)
                .enumerate()
                .map(|(j, (w, &outst))| {
                // Whichever is larger: what the worker has observed, or what
                // we know we have sent it (telemetry lags the mailbox).
                let queued = w.queued.load(Ordering::Relaxed);
                let active = w.active.load(Ordering::Relaxed);
                let queued = queued.max(outst.saturating_sub(active));
                let us_tok = w.us_per_token();
                // Everyone ahead of us plus ourselves, times per-token time.
                let inflight_tokens = (queued + active + 1) * expected_tokens;
                let predicted = inflight_tokens as f64 * us_tok / 1.0e6;
                let cap = (w.max_batch.load(Ordering::Relaxed)
                    + w.queue_cap.load(Ordering::Relaxed)) as f64;
                let used = (queued + active) as f64;
                ServerView {
                    kind: w.kind,
                    predicted_time: predicted,
                    // First token lands once everyone ahead has drained
                    // plus one step of our own — telemetry has no
                    // prefill/decode split, so one EMA token-time stands
                    // in for our prefill; an idle worker then reports its
                    // speed (never a flat 0.0), keeping the field's
                    // contract consistent with the DES fill.
                    predicted_ttft: ((queued + active) * expected_tokens + 1) as f64 * us_tok
                        / 1.0e6,
                    compute_headroom: (cap - used).max(0.0),
                    compute_demand: 1.0,
                    bandwidth_headroom: 1.0e9,
                    bandwidth_demand: 1.0e6,
                    tx_energy_est: w.tx_j_per_request,
                    infer_energy_est: w.j_per_token * expected_tokens as f64,
                    n_active: active,
                    n_waiting: queued,
                    solo_time_est: expected_tokens as f64 * us_tok / 1.0e6,
                    occupancy: used / cap,
                    // The live substrate has no probe pipeline yet: a
                    // worker in the telemetry list is presumed healthy.
                    observed_health: 1.0,
                    // Residency priced through the same `usable_prefix`
                    // composition the DES uses; cold (0.0) whenever the
                    // registry is off or the request is sessionless.
                    prefix_hit_tokens: match (session, self.prefix.as_ref()) {
                        (Some(s), Some(reg)) => {
                            s.usable_prefix(reg.resident_on(s.session_id, j)) as f64
                        }
                        _ => 0.0,
                    },
                    prefix_pressure: match self.prefix.as_ref() {
                        Some(reg) => reg.pressure(j),
                        None => 0.0,
                    },
                }
            }),
        );
        // lint: end-no-alloc
    }

    /// Snapshot telemetry into a freshly allocated scheduler-facing view.
    /// Allocating wrapper kept for inspection/tests; the request path uses
    /// the scratch buffer via [`ViewSource::view_into`]/`fill_view`.
    pub fn view(&self, expected_tokens: usize) -> ClusterView {
        let mut out = ClusterView::with_capacity(self.workers.len(), self.weights);
        self.fill_view(expected_tokens, None, &mut out);
        out
    }

    /// Route one request through the scheduler's [`Action`] interface.
    pub fn route(&mut self, req: &ServiceRequest) -> Routed {
        self.decisions += 1;
        // Take/put-back keeps the scratch view out of `self` while the
        // scheduler borrows it (no allocation: the buffer is reused).
        let mut view = std::mem::take(&mut self.scratch);
        self.fill_view(
            (req.prompt_tokens + req.output_tokens) as usize,
            req.session.as_ref(),
            &mut view,
        );
        let action = self.scheduler.decide(req, &view);
        let routed = match action {
            Action::Assign { server } => Routed::Assign {
                worker: self.checked_worker(server, req, &view),
            },
            Action::Defer { server, delay_s } => Routed::Defer {
                worker: self.checked_worker(server, req, &view),
                delay_s,
            },
            Action::Shed { reason } => {
                // The request is resolved here and now: account it and
                // deliver bandit feedback immediately (no completion will
                // come back through the workers).
                self.sheds += 1;
                let outcome = ServiceOutcome::shed(req, 0.0);
                self.scheduler.feedback(&outcome, &view);
                Routed::Shed { reason }
            }
        };
        if let Some(w) = routed.worker() {
            self.outstanding[w] += 1;
            // Record where the conversation's KV now lives: after this
            // turn the worker holds the full context (reused prefix plus
            // this turn's prompt and generated tokens) — the same
            // post-turn residency `PrefixCache::admit_turn` installs on
            // the DES side.
            if let (Some(s), Some(reg)) = (req.session.as_ref(), self.prefix.as_mut()) {
                let context = s.prefix_tokens as u64
                    + req.prompt_tokens as u64
                    + req.output_tokens as u64;
                reg.record(s.session_id, w, context);
            }
        }
        self.scratch = view;
        routed
    }

    /// Validate a scheduler-chosen worker index. An out-of-range target is
    /// a scheduler bug: log it loudly and recover with the least-violating
    /// worker instead of masking the bug with a clamp (the pre-Action
    /// router silently did `server.min(len - 1)`).
    fn checked_worker(&mut self, server: usize, req: &ServiceRequest, view: &ClusterView) -> usize {
        if server < self.workers.len() {
            return server;
        }
        self.bad_assignments += 1;
        log::error!(
            "scheduler {:?} chose out-of-range worker {server} (cluster has {}); \
             falling back to least-violating",
            self.scheduler.name(),
            self.workers.len()
        );
        view.least_violating(req)
    }

    /// Feed the realized outcome back to the bandit.
    pub fn complete(&mut self, outcome: &ServiceOutcome) {
        if let Some(o) = self.outstanding.get_mut(outcome.server) {
            *o = o.saturating_sub(1);
        }
        let mut view = std::mem::take(&mut self.scratch);
        self.fill_view(outcome.tokens.max(1) as usize, None, &mut view);
        self.scheduler.feedback(outcome, &view);
        self.scratch = view;
    }

    pub fn diagnostics(&self) -> Vec<(String, f64)> {
        let mut d = self.scheduler.diagnostics();
        d.push(("router_decisions".into(), self.decisions as f64));
        d.push(("router_sheds".into(), self.sheds as f64));
        d.push(("router_bad_assignments".into(), self.bad_assignments as f64));
        d
    }

    /// Requests the policy has shed so far.
    pub fn sheds(&self) -> u64 {
        self.sheds
    }

    /// Helper to build the ServiceRequest the scheduler expects from a raw
    /// serving request with the compat scalar deadline
    /// (completion-only contract).
    pub fn service_request(
        id: u64,
        class: ServiceClass,
        prompt_tokens: usize,
        output_tokens: usize,
        deadline_s: f64,
    ) -> ServiceRequest {
        Self::service_request_slo(
            id,
            class,
            prompt_tokens,
            output_tokens,
            SloSpec::completion_only(deadline_s),
        )
    }

    /// [`Self::service_request`] with a full SLO contract — the serving
    /// front door's entry into TTFT/energy-aware routing.
    pub fn service_request_slo(
        id: u64,
        class: ServiceClass,
        prompt_tokens: usize,
        output_tokens: usize,
        slo: SloSpec,
    ) -> ServiceRequest {
        ServiceRequest {
            id,
            class,
            arrival: 0.0,
            prompt_tokens: prompt_tokens as u32,
            output_tokens: output_tokens as u32,
            slo,
            payload_bytes: 4096 + prompt_tokens as u64 * 64,
            session: None,
        }
    }
}

impl ViewSource for Router {
    /// The unified-API entry point — same signature `ClusterSim`
    /// implements, fed by live telemetry instead of simulated state.
    fn view_into(&self, req: &ServiceRequest, out: &mut ClusterView) {
        self.fill_view(
            (req.prompt_tokens + req.output_tokens) as usize,
            req.session.as_ref(),
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::csucb::CsUcb;

    fn telemetry(kind: ServerKind) -> Arc<WorkerTelemetry> {
        Arc::new(WorkerTelemetry::new(kind, 4, 8))
    }

    #[test]
    fn routes_within_bounds_and_learns() {
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        let mut router = Router::new(Box::new(CsUcb::with_defaults(2)), workers);
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 5.0);
        for _ in 0..50 {
            let w = router.route(&req).worker().expect("placed");
            assert!(w < 2);
        }
    }

    /// Differential check: the scratch `view_into` fill and the allocating
    /// `view()` wrapper must produce identical snapshots, including after
    /// telemetry changes and with stale content in the scratch buffer.
    #[test]
    fn scratch_view_matches_collected_view() {
        use crate::scheduler::ViewSource;
        let workers = vec![
            telemetry(ServerKind::Edge),
            telemetry(ServerKind::Edge),
            telemetry(ServerKind::Cloud),
        ];
        workers[0].queued.store(6, Ordering::Relaxed);
        workers[0].active.store(4, Ordering::Relaxed);
        workers[0].record_step_time(5000.0);
        workers[2].active.store(2, Ordering::Relaxed);
        let router = Router::new(Box::new(CsUcb::with_defaults(3)), workers);
        // prompt 16 + output 32 = the 48 expected tokens view() is given.
        let req = Router::service_request(9, ServiceClass::Code, 16, 32, 5.0);
        let mut scratch = ClusterView::default();
        router.view_into(&req, &mut scratch);
        assert_eq!(scratch, router.view(48));
        // Refill after telemetry moved: the second fill must fully replace
        // the first.
        router.workers[1].queued.store(3, Ordering::Relaxed);
        router.workers[1].record_step_time(9000.0);
        router.view_into(&req, &mut scratch);
        assert_eq!(scratch, router.view(48));
    }

    /// A shed decision surfaces as `Routed::Shed`, counts in diagnostics,
    /// and delivers bandit feedback without involving any worker.
    #[test]
    fn shed_action_resolves_request_with_feedback() {
        use crate::scheduler::{Action, Scheduler, ShedReason};
        struct ShedAll {
            feedbacks: std::sync::Arc<std::sync::atomic::AtomicUsize>,
        }
        impl Scheduler for ShedAll {
            fn name(&self) -> &'static str {
                "shed-all"
            }
            fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
                Action::shed(ShedReason::Overloaded)
            }
            fn feedback(&mut self, o: &ServiceOutcome, _v: &ClusterView) {
                assert!(o.was_shed());
                self.feedbacks.fetch_add(1, Ordering::Relaxed);
            }
        }
        let feedbacks = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        let mut router = Router::new(
            Box::new(ShedAll {
                feedbacks: feedbacks.clone(),
            }),
            workers,
        );
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 5.0);
        for _ in 0..5 {
            let routed = router.route(&req);
            assert_eq!(routed, Routed::Shed { reason: ShedReason::Overloaded });
            assert_eq!(routed.worker(), None);
        }
        assert_eq!(router.sheds(), 5);
        assert_eq!(feedbacks.load(Ordering::Relaxed), 5, "feedback per shed");
        let d = router.diagnostics();
        assert!(d.iter().any(|(k, v)| k == "router_sheds" && *v == 5.0));
    }

    /// The old silent `server.min(len - 1)` clamp is gone: an out-of-range
    /// target is recovered via least-violating and surfaced in
    /// diagnostics.
    #[test]
    fn out_of_range_target_recovers_and_is_counted() {
        use crate::scheduler::{Action, Scheduler};
        struct Bad;
        impl Scheduler for Bad {
            fn name(&self) -> &'static str {
                "bad"
            }
            fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
                Action::assign(99)
            }
        }
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        let mut router = Router::new(Box::new(Bad), workers);
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 5.0);
        let w = router.route(&req).worker().expect("recovered placement");
        assert!(w < 2, "fallback must stay in range");
        let d = router.diagnostics();
        assert!(d
            .iter()
            .any(|(k, v)| k == "router_bad_assignments" && *v == 1.0));
    }

    /// A multi-tier topology projects onto the live substrate: one worker
    /// per topology server, kinds preserved, and routing works end to end
    /// on the 60-server fleet.
    #[test]
    fn from_topology_builds_matching_fleet() {
        use crate::sim::topology::TopologyConfig;
        use crate::sim::BandwidthMode;
        let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
        let mut router =
            Router::from_topology(Box::new(CsUcb::with_defaults(topo.n_servers())), &topo);
        assert_eq!(router.workers.len(), 60);
        let cfg = topo.build();
        for (w, s) in router.workers.iter().zip(&cfg.servers) {
            assert_eq!(w.kind, s.kind);
            assert_eq!(w.max_batch.load(Ordering::Relaxed), s.slots);
            assert_eq!(w.queue_cap.load(Ordering::Relaxed), s.queue_limit);
        }
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 5.0);
        for _ in 0..20 {
            let w = router.route(&req).worker().expect("placed");
            assert!(w < 60);
        }
    }

    /// The admission gate runs unchanged on the live substrate: hopeless
    /// load is shed at the door (`Routed::Shed`) after the token burst,
    /// the diagnostics carry `gate_sheds`, and advancing the router clock
    /// refills the bucket.
    #[test]
    fn gated_router_sheds_hopeless_load_at_the_door() {
        use crate::scheduler::admission::{GateParams, TokenBucketGate};
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Edge)];
        for w in &workers {
            // Saturated and slow: zero compute headroom, ~21 s predicted.
            w.queued.store(12, Ordering::Relaxed);
            w.record_step_time(50_000.0);
        }
        let gate = TokenBucketGate::new(
            Box::new(CsUcb::with_defaults(2)),
            GateParams {
                refill_per_s: 0.5,
                burst: 2.0,
                margin: 0.0,
            },
        );
        let mut router = Router::new(Box::new(gate), workers);
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 2.0);
        // Burst admissions pass (least-violating fallback inside CS-UCB)…
        assert!(router.route(&req).worker().is_some());
        assert!(router.route(&req).worker().is_some());
        // …then the door closes.
        for _ in 0..4 {
            assert_eq!(
                router.route(&req),
                Routed::Shed {
                    reason: ShedReason::Overloaded
                }
            );
        }
        assert_eq!(router.sheds(), 4);
        let d = router.diagnostics();
        assert!(d.iter().any(|(k, v)| k == "gate_sheds" && *v == 4.0));
        // Clock advance refills the bucket through the stamped view.
        router.set_now(10.0);
        assert!(router.route(&req).worker().is_some());
    }

    /// TTFT contracts route on the live substrate too: a worker that is
    /// fast end-to-end but slow to first token loses interactive traffic
    /// under the SLO-aware policy.
    #[test]
    fn slo_router_avoids_ttft_violating_worker() {
        use crate::scheduler::csucb::CsUcbSlo;
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        // Worker 1: a big backlog ahead of the first token.
        workers[1].queued.store(8, Ordering::Relaxed);
        workers[1].record_step_time(4000.0);
        let mut router = Router::new(Box::new(CsUcbSlo::with_defaults(2)), workers);
        let slo = SloSpec::completion_only(20.0).with_ttft(0.2);
        let req = Router::service_request_slo(1, ServiceClass::Chat, 16, 16, slo);
        // Few routes only: the router's own outstanding bookkeeping raises
        // worker 0's predicted TTFT as we pile work on it (that's the
        // feature), which would eventually push this request to the
        // fallback path.
        for _ in 0..3 {
            assert_eq!(router.route(&req).worker(), Some(0));
        }
    }

    /// The live substrate mirrors the DES prefix semantics: a routed
    /// session turn records residency in the registry, follow-up turns
    /// see warm `prefix_hit_tokens` on exactly that worker, and the
    /// cache-affinity policy sticks to it while the plain SLO policy
    /// (ties everywhere else) has no reason to.
    #[test]
    fn session_residency_prices_into_views_and_steers_affinity() {
        use crate::scheduler::csucb::CsUcbAffinity;
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Edge)];
        let mut router = Router::new(Box::new(CsUcbAffinity::with_defaults(2)), workers)
            .with_prefix_registry(100_000);
        // Warm both arms with identical outcomes so the bandit indices
        // tie exactly — any sustained preference below must then come
        // from the residency signal, not reward history.
        for w in 0..2usize {
            for _ in 0..5 {
                router.complete(&ServiceOutcome {
                    id: 1,
                    class: ServiceClass::Chat,
                    server: w,
                    tx_time: 0.01,
                    infer_time: 0.5,
                    processing_time: 0.51,
                    ttft_time: 0.05,
                    slo: SloSpec::completion_only(10.0),
                    energy_j: 1.0,
                    tokens: 96,
                    completed_at: 1.0,
                });
            }
        }
        // Turn 1: no prefix yet (cold everywhere); wherever the tie falls
        // becomes the session's home.
        let mut req = Router::service_request(1, ServiceClass::Chat, 64, 32, 10.0);
        req.session = Some(SessionRef {
            session_id: 42,
            turn: 1,
            prefix_tokens: 0,
            xfer_tokens: 0,
        });
        let home = router.route(&req).worker().expect("turn 1 placed");
        let reg = router.prefix_registry().expect("registry enabled");
        assert_eq!(reg.resident_on(42, home), 96, "prefix + prompt + output");
        // Turn 2 carries the grown context: the view prices the reusable
        // prefix on the home worker only.
        req.session = Some(SessionRef {
            session_id: 42,
            turn: 2,
            prefix_tokens: 96,
            xfer_tokens: 0,
        });
        let mut view = ClusterView::default();
        router.view_into(&req, &mut view);
        assert_eq!(view.servers[home].prefix_hit_tokens, 96.0);
        assert_eq!(view.servers[1 - home].prefix_hit_tokens, 0.0);
        assert!(view.servers[home].prefix_pressure > 0.0);
        // Follow-up turns chase the prefix: the affinity bonus breaks the
        // exact bandit tie toward the resident worker every time, even as
        // the router's outstanding bookkeeping piles load on it.
        for turn in 2..10u32 {
            req.session.as_mut().unwrap().turn = turn;
            assert_eq!(
                router.route(&req).worker(),
                Some(home),
                "turn {turn} should chase its prefix"
            );
            req.session.as_mut().unwrap().prefix_tokens += 96;
        }
        // Ending the session releases its tokens from the pressure proxy.
        router.end_session(42);
        assert_eq!(router.prefix_registry().unwrap().sessions(), 0);
    }

    #[test]
    fn view_reflects_telemetry() {
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Cloud)];
        workers[0].queued.store(6, Ordering::Relaxed);
        workers[0].active.store(4, Ordering::Relaxed);
        workers[0].record_step_time(5000.0);
        let router = Router::new(Box::new(CsUcb::with_defaults(2)), workers);
        let view = router.view(32);
        assert!(view.servers[0].predicted_time > view.servers[1].predicted_time);
        assert!(view.servers[0].occupancy > view.servers[1].occupancy);
        assert!(view.servers[0].compute_headroom < view.servers[1].compute_headroom);
    }

    #[test]
    fn ema_converges() {
        let w = telemetry(ServerKind::Edge);
        for _ in 0..100 {
            w.record_step_time(1000.0);
        }
        assert!((w.us_per_token() - 1000.0).abs() < 50.0);
    }

    #[test]
    fn loaded_worker_avoided_under_deadline() {
        let workers = vec![telemetry(ServerKind::Edge), telemetry(ServerKind::Edge)];
        // Worker 0 heavily loaded and slow.
        workers[0].queued.store(12, Ordering::Relaxed);
        workers[0].record_step_time(50_000.0);
        let mut router = Router::new(Box::new(CsUcb::with_defaults(2)), workers);
        let req = Router::service_request(1, ServiceClass::Chat, 16, 16, 2.0);
        let mut to_1 = 0;
        for _ in 0..20 {
            if router.route(&req).worker() == Some(1) {
                to_1 += 1;
            }
        }
        assert!(to_1 >= 18, "routed to loaded worker too often: {to_1}");
    }
}
