//! The serving leader: worker threads (one per model engine, each running
//! a continuous batcher) plus a router thread-free front door. std::thread
//! + mpsc channels — the offline crate set has no tokio, and the workload
//! (CPU-bound PJRT executions) wants one OS thread per engine anyway.
//!
//! Topology (mirrors the paper's Figure 3 workflow):
//!
//! ```text
//!   submit() ──► Router (CS-UCB over live telemetry)
//!                   │ per-worker mpsc
//!        ┌──────────┼──────────────┐
//!   Worker 0    Worker 1 …     Worker N   (Batcher<ModelEngine> each)
//!        └──────────┴──────┬───────┘
//!                          ▼ completion mpsc
//!                     recv_completion()
//! ```

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, GenRequest, StepModel};
use super::metrics::ServingMetrics;
use super::router::{Routed, Router, WorkerTelemetry};
use crate::scheduler::{Scheduler, ShedReason};
use crate::sim::server::ServerKind;
use crate::workload::service::{ServiceClass, ServiceOutcome, SloSpec};

/// A request entering the serving cluster.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub deadline_s: f64,
    /// Optional TTFT bound, seconds — the interactive half of the SLO
    /// contract. `None` = completion-bound only (the historical scalar).
    pub ttft_slo_s: Option<f64>,
    pub class: ServiceClass,
    pub temperature: f32,
    pub top_k: usize,
}

impl ServeRequest {
    /// The SLO contract this request carries into the router.
    pub fn slo(&self) -> SloSpec {
        let mut slo = SloSpec::completion_only(self.deadline_s);
        slo.ttft = self.ttft_slo_s;
        slo
    }
}

/// A finished generation leaving the cluster.
#[derive(Debug, Clone)]
pub struct ServeReply {
    pub id: u64,
    pub worker: usize,
    pub text: String,
    pub tokens: u64,
    pub latency_ms: f64,
    pub queue_wait_ms: f64,
    /// Realized time to first token, **measured**: wall clock from submit
    /// to the batcher sampling the request's first token at the end of
    /// its prefill step (`GenResult::first_token_at`) — mailbox wait,
    /// admission queueing, and the (possibly long) prefill iteration all
    /// included.
    pub ttft_ms: f64,
    pub deadline_s: f64,
    pub ttft_slo_s: Option<f64>,
    pub class: ServiceClass,
    pub prompt_tokens: usize,
}

impl ServeReply {
    pub fn met_deadline(&self) -> bool {
        self.latency_ms / 1000.0 <= self.deadline_s
    }

    /// Whether the TTFT bound held, if the request carried one.
    pub fn met_ttft(&self) -> Option<bool> {
        self.ttft_slo_s.map(|t| self.ttft_ms / 1000.0 <= t)
    }

    /// The SLO contract this reply is judged against — the one
    /// construction both the feedback outcome and external consumers
    /// share with [`ServeRequest::slo`].
    pub fn slo(&self) -> SloSpec {
        let mut slo = SloSpec::completion_only(self.deadline_s);
        slo.ttft = self.ttft_slo_s;
        slo
    }
}

struct WorkItem {
    req: ServeRequest,
    submitted: Instant,
}

enum WorkerMsg {
    Work(WorkItem),
    Shutdown,
}

struct Done {
    reply: ServeReply,
}

/// Result of submitting one request to the serving cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SubmitOutcome {
    /// Placed on this worker; a completion will arrive.
    Enqueued { worker: usize },
    /// Rejected by the scheduling policy; no completion will arrive.
    Shed { reason: ShedReason },
}

impl SubmitOutcome {
    /// The worker the request went to, if it was placed.
    pub fn worker(&self) -> Option<usize> {
        match *self {
            SubmitOutcome::Enqueued { worker } => Some(worker),
            SubmitOutcome::Shed { .. } => None,
        }
    }
}

/// One worker thread: drains its queue into the batcher and steps it.
fn worker_loop<M: StepModel>(
    idx: usize,
    mut batcher: Batcher<M>,
    rx: Receiver<WorkerMsg>,
    done_tx: Sender<Done>,
    telemetry: Arc<WorkerTelemetry>,
    metrics: Arc<ServingMetrics>,
) {
    let mut inflight: std::collections::HashMap<u64, (WorkItem, Instant)> =
        std::collections::HashMap::new();
    let mut shutdown = false;
    loop {
        // Drain the mailbox without blocking while there is work.
        loop {
            match rx.try_recv() {
                Ok(WorkerMsg::Work(item)) => {
                    telemetry.queued.fetch_add(1, Ordering::Relaxed);
                    let prompt = crate::runtime::tokenizer::encode(&item.req.prompt);
                    batcher.submit(GenRequest {
                        id: item.req.id,
                        prompt,
                        max_new_tokens: item.req.max_new_tokens,
                        temperature: item.req.temperature,
                        top_k: item.req.top_k,
                    });
                    inflight.insert(item.req.id, (item, Instant::now()));
                }
                Ok(WorkerMsg::Shutdown) => shutdown = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => shutdown = true,
            }
            if shutdown {
                break;
            }
        }

        if batcher.is_idle() {
            if shutdown {
                return;
            }
            // Block briefly for new work.
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(WorkerMsg::Work(item)) => {
                    telemetry.queued.fetch_add(1, Ordering::Relaxed);
                    let prompt = crate::runtime::tokenizer::encode(&item.req.prompt);
                    batcher.submit(GenRequest {
                        id: item.req.id,
                        prompt,
                        max_new_tokens: item.req.max_new_tokens,
                        temperature: item.req.temperature,
                        top_k: item.req.top_k,
                    });
                    inflight.insert(item.req.id, (item, Instant::now()));
                }
                Ok(WorkerMsg::Shutdown) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    shutdown = true
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            }
            continue;
        }

        // One batched iteration.
        let step_t0 = Instant::now();
        let queued_before = batcher.queued();
        let finished = match batcher.step() {
            Ok(f) => f,
            Err(e) => {
                log::error!("worker {idx}: batcher step failed: {e:#}");
                return;
            }
        };
        let step_dt = step_t0.elapsed().as_secs_f64();
        let active = batcher.active().max(1);
        telemetry
            .active
            .store(batcher.active(), Ordering::Relaxed);
        telemetry.queued.store(batcher.queued(), Ordering::Relaxed);
        let admitted = queued_before - batcher.queued().min(queued_before);
        let _ = admitted;
        // us per generated token this iteration (each active lane got one).
        telemetry.record_step_time(step_dt * 1.0e6 / active as f64);

        for result in finished {
            let Some((item, _)) = inflight.remove(&result.id) else {
                log::warn!("worker {idx}: unknown completion {}", result.id);
                continue;
            };
            let latency_ms = item.submitted.elapsed().as_secs_f64() * 1000.0;
            let queue_wait_ms = result.queued_iters as f64 * step_dt * 1000.0;
            // Measured first-token latency (see ServeReply::ttft_ms):
            // saturating, in case clock granularity puts the prefill
            // sample at the submit instant.
            let ttft_ms = result
                .first_token_at
                .saturating_duration_since(item.submitted)
                .as_secs_f64()
                * 1000.0;
            let text = crate::runtime::tokenizer::decode(&result.tokens);
            let reply = ServeReply {
                id: result.id,
                worker: idx,
                tokens: result.tokens.len() as u64,
                text,
                latency_ms,
                queue_wait_ms,
                ttft_ms,
                deadline_s: item.req.deadline_s,
                ttft_slo_s: item.req.ttft_slo_s,
                class: item.req.class,
                prompt_tokens: result.prompt_tokens,
            };
            metrics.record_completion(latency_ms, queue_wait_ms, reply.tokens);
            metrics.record_slo(reply.met_ttft(), Some(reply.met_deadline()), ttft_ms);
            if done_tx.send(Done { reply }).is_err() {
                return;
            }
        }
    }
}

/// The serving cluster facade.
pub struct ServingCluster {
    router: Router,
    work_txs: Vec<Sender<WorkerMsg>>,
    done_rx: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
    pub metrics: Arc<ServingMetrics>,
    outstanding: usize,
}

impl ServingCluster {
    /// Build a cluster from `(kind, engine-factory)` pairs and a scheduler.
    /// Engines are constructed *inside* their worker threads — PJRT handles
    /// are not `Send`, and per-thread clients mirror the paper's
    /// one-process-per-server deployment anyway.
    pub fn start<M, F>(
        engines: Vec<(ServerKind, F)>,
        scheduler: Box<dyn Scheduler>,
        seed: u64,
    ) -> Result<Self>
    where
        M: StepModel,
        F: FnOnce() -> Result<M> + Send + 'static,
    {
        assert!(!engines.is_empty());
        let metrics = Arc::new(ServingMetrics::new());
        let (done_tx, done_rx) = channel();
        let mut work_txs = Vec::new();
        let mut handles = Vec::new();
        let mut telemetry = Vec::new();
        for (i, (kind, factory)) in engines.into_iter().enumerate() {
            let tele = Arc::new(WorkerTelemetry::new(kind, 4, 8));
            telemetry.push(tele.clone());
            let (tx, rx) = channel();
            work_txs.push(tx);
            let done_tx = done_tx.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || {
                let model = match factory() {
                    Ok(m) => m,
                    Err(e) => {
                        log::error!("worker {i}: engine load failed: {e:#}");
                        return;
                    }
                };
                use std::sync::atomic::Ordering;
                tele.max_batch.store(model.max_batch(), Ordering::Relaxed);
                tele.queue_cap.store(model.max_batch() * 2, Ordering::Relaxed);
                let batcher = Batcher::new(model, seed ^ (i as u64));
                worker_loop(i, batcher, rx, done_tx, tele, metrics)
            }));
        }
        Ok(ServingCluster {
            router: Router::new(scheduler, telemetry),
            work_txs,
            done_rx,
            handles,
            metrics,
            outstanding: 0,
        })
    }

    /// Route and enqueue one request. A `Shed` resolves the request here:
    /// the bandit already received feedback inside the router, no
    /// completion will arrive, and the caller must not wait for one.
    pub fn submit(&mut self, req: ServeRequest) -> Result<SubmitOutcome> {
        // Keep the router's observation clock moving: time-dependent
        // policies (the admission gate's token refill, deferred-batching
        // windows) read it from the view, and a frozen clock would leave
        // a gate's bucket never refilling after the initial burst.
        self.router.set_now(self.metrics.elapsed_s());
        let sreq = Router::service_request_slo(
            req.id,
            req.class,
            req.prompt.len(),
            req.max_new_tokens,
            req.slo(),
        );
        match self.router.route(&sreq) {
            // A Defer degenerates to immediate dispatch on the live
            // substrate: the worker's continuous batcher *is* the batch
            // boundary a deferred-batching window approximates in the DES.
            Routed::Assign { worker } | Routed::Defer { worker, .. } => {
                // Arrival recorded only for placed requests: sheds never
                // produce a completion, and counting them here would leave
                // phantom in-flight entries in the metrics report (shed
                // counts live in the router diagnostics instead).
                self.metrics.record_arrival();
                self.work_txs[worker]
                    .send(WorkerMsg::Work(WorkItem {
                        req,
                        submitted: Instant::now(),
                    }))
                    .map_err(|_| anyhow::anyhow!("worker {worker} gone"))?;
                self.outstanding += 1;
                Ok(SubmitOutcome::Enqueued { worker })
            }
            Routed::Shed { reason } => Ok(SubmitOutcome::Shed { reason }),
        }
    }

    /// Blocking receive of the next completion (None on timeout).
    pub fn recv_completion(&mut self, timeout: Duration) -> Option<ServeReply> {
        match self.done_rx.recv_timeout(timeout) {
            Ok(done) => {
                self.outstanding -= 1;
                // Bandit feedback with the realized outcome.
                let outcome = ServiceOutcome {
                    id: done.reply.id,
                    class: done.reply.class,
                    server: done.reply.worker,
                    tx_time: 0.0,
                    infer_time: done.reply.latency_ms / 1000.0,
                    processing_time: done.reply.latency_ms / 1000.0,
                    ttft_time: done.reply.ttft_ms / 1000.0,
                    slo: done.reply.slo(),
                    energy_j: self.router.workers[done.reply.worker].j_per_token
                        * done.reply.tokens as f64,
                    tokens: done.reply.tokens,
                    completed_at: 0.0,
                };
                self.router.complete(&outcome);
                Some(done.reply)
            }
            Err(_) => None,
        }
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    pub fn diagnostics(&self) -> Vec<(String, f64)> {
        self.router.diagnostics()
    }

    /// Graceful shutdown: drain signals and join workers.
    pub fn shutdown(mut self) {
        for tx in &self.work_txs {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        self.work_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::tests_support::FakeModel;
    use crate::scheduler::csucb::CsUcb;

    fn fake_cluster(n_workers: usize) -> ServingCluster {
        type Factory = Box<dyn FnOnce() -> anyhow::Result<FakeModel> + Send>;
        let engines: Vec<(ServerKind, Factory)> = (0..n_workers)
            .map(|i| {
                let kind = if i == n_workers - 1 {
                    ServerKind::Cloud
                } else {
                    ServerKind::Edge
                };
                let f: Factory = Box::new(|| Ok(FakeModel::new()));
                (kind, f)
            })
            .collect();
        ServingCluster::start(engines, Box::new(CsUcb::with_defaults(n_workers)), 42).unwrap()
    }

    fn req(id: u64) -> ServeRequest {
        ServeRequest {
            id,
            prompt: "hello".into(),
            max_new_tokens: 8,
            deadline_s: 10.0,
            ttft_slo_s: None,
            class: ServiceClass::Chat,
            temperature: 0.0,
            top_k: 1,
        }
    }

    #[test]
    fn serves_requests_end_to_end_with_fake_models() {
        let mut cluster = fake_cluster(2);
        for i in 0..10 {
            let mut r = req(i);
            // Half the load carries an (easily met) interactive contract.
            if i % 2 == 0 {
                r.ttft_slo_s = Some(30.0);
            }
            let out = cluster.submit(r).unwrap();
            assert!(out.worker().is_some(), "idle cluster must not shed");
        }
        let mut got = 0;
        while got < 10 {
            let r = cluster
                .recv_completion(Duration::from_secs(5))
                .expect("completion");
            assert!(!r.text.is_empty() || r.tokens > 0);
            assert!(r.tokens as usize <= 8);
            // Realized TTFT: present, and never after completion.
            assert!(r.ttft_ms >= 0.0 && r.ttft_ms <= r.latency_ms + 1e-6);
            if r.id % 2 == 0 {
                assert_eq!(r.met_ttft(), Some(true), "ttft {} ms", r.ttft_ms);
            } else {
                assert_eq!(r.met_ttft(), None);
            }
            got += 1;
        }
        assert_eq!(cluster.outstanding(), 0);
        assert_eq!(
            cluster.metrics.slo_completion_violations(),
            0,
            "10 s deadline on fake models must hold"
        );
        cluster.shutdown();
    }

    #[test]
    fn load_spreads_across_workers() {
        let mut cluster = fake_cluster(3);
        let mut per_worker = [0usize; 3];
        for i in 0..60 {
            let w = cluster.submit(req(i)).unwrap().worker().expect("placed");
            per_worker[w] += 1;
        }
        let mut got = 0;
        while got < 60 {
            cluster.recv_completion(Duration::from_secs(5)).unwrap();
            got += 1;
        }
        cluster.shutdown();
        // With telemetry-aware routing, no single worker takes everything.
        assert!(per_worker.iter().all(|&c| c > 0), "{per_worker:?}");
    }
}
