//! Layer-3 coordinator: the serving-side realization of the paper's
//! framework — request routing (CS-UCB over live telemetry), continuous
//! batching over the AOT engines, paged KV admission control, and
//! metrics. The DES (sim/) replays the paper's evaluation at scale; this
//! module serves *real* tokens through the same scheduler.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batcher, GenRequest, GenResult, StepModel};
pub use kv::{KvPool, KvPoolConfig};
pub use metrics::ServingMetrics;
pub use router::{Routed, Router, WorkerTelemetry};
pub use server::{ServeReply, ServeRequest, ServingCluster, SubmitOutcome};
