//! Paged KV-cache block manager.
//!
//! The engine's caches are dense per-request blocks, but admission control
//! needs a memory model: this allocator tracks a fixed pool of KV pages
//! (PagedAttention-style) and decides how many concurrent sequences fit.
//! Sequences allocate pages lazily as they grow; freeing returns pages to
//! a free list. Fragmentation statistics feed the metrics endpoint.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Pool configuration.
#[derive(Debug, Clone, Copy)]
pub struct KvPoolConfig {
    /// Tokens per page.
    pub page_tokens: usize,
    /// Total pages in the pool.
    pub n_pages: usize,
}

impl KvPoolConfig {
    /// Pool sized for `n_seqs` full-length sequences of `max_seq` tokens.
    pub fn for_sequences(n_seqs: usize, max_seq: usize, page_tokens: usize) -> Self {
        let pages_per_seq = max_seq.div_ceil(page_tokens);
        KvPoolConfig {
            page_tokens,
            n_pages: n_seqs * pages_per_seq,
        }
    }
}

/// One sequence's page table.
#[derive(Debug, Clone)]
struct SeqAlloc {
    pages: Vec<usize>,
    tokens: usize,
}

/// The block allocator.
#[derive(Debug)]
pub struct KvPool {
    cfg: KvPoolConfig,
    free: Vec<usize>,
    seqs: HashMap<u64, SeqAlloc>,
    /// High-water mark of pages in use.
    peak_used: usize,
    allocs: u64,
    frees: u64,
}

impl KvPool {
    pub fn new(cfg: KvPoolConfig) -> Self {
        assert!(cfg.page_tokens > 0 && cfg.n_pages > 0);
        KvPool {
            cfg,
            free: (0..cfg.n_pages).rev().collect(),
            seqs: HashMap::new(),
            peak_used: 0,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub fn pages_used(&self) -> usize {
        self.cfg.n_pages - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    pub fn n_sequences(&self) -> usize {
        self.seqs.len()
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.page_tokens).max(1)
    }

    /// Can a sequence of `tokens` be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    /// Admit a new sequence with an initial `tokens` length (prompt).
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let need = self.pages_for(tokens);
        if need > self.free.len() {
            bail!(
                "kv pool exhausted: need {need} pages, {} free",
                self.free.len()
            );
        }
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.allocs += need as u64;
        self.seqs.insert(seq_id, SeqAlloc { pages, tokens });
        self.peak_used = self.peak_used.max(self.pages_used());
        Ok(())
    }

    /// Grow a sequence by `new_tokens` (decode steps). Allocates pages on
    /// page-boundary crossings only.
    pub fn extend(&mut self, seq_id: u64, new_tokens: usize) -> Result<()> {
        let page_tokens = self.cfg.page_tokens;
        let seq = match self.seqs.get_mut(&seq_id) {
            Some(s) => s,
            None => bail!("unknown sequence {seq_id}"),
        };
        let total = seq.tokens + new_tokens;
        let need_total = total.div_ceil(page_tokens).max(1);
        let extra = need_total.saturating_sub(seq.pages.len());
        if extra > self.free.len() {
            bail!("kv pool exhausted on extend: need {extra} more pages");
        }
        for _ in 0..extra {
            seq.pages.push(self.free.pop().unwrap());
        }
        self.allocs += extra as u64;
        seq.tokens = total;
        self.peak_used = self.peak_used.max(self.pages_used());
        Ok(())
    }

    /// Release a sequence's pages.
    pub fn release(&mut self, seq_id: u64) -> Result<usize> {
        let seq = match self.seqs.remove(&seq_id) {
            Some(s) => s,
            None => bail!("unknown sequence {seq_id}"),
        };
        let n = seq.pages.len();
        self.frees += n as u64;
        self.free.extend(seq.pages);
        Ok(n)
    }

    /// Internal fragmentation: fraction of allocated page capacity that is
    /// not holding tokens.
    pub fn fragmentation(&self) -> f64 {
        let mut cap = 0usize;
        let mut used = 0usize;
        // lint: order-insensitive commutative sums; visitation order cannot change the totals
        for s in self.seqs.values() {
            cap += s.pages.len() * self.cfg.page_tokens;
            used += s.tokens;
        }
        if cap == 0 {
            0.0
        } else {
            1.0 - used as f64 / cap as f64
        }
    }

    /// Invariant check used by property tests: every page is either free or
    /// owned by exactly one sequence.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.cfg.n_pages];
        for &p in &self.free {
            if seen[p] {
                bail!("page {p} double-listed in free list");
            }
            seen[p] = true;
        }
        // lint: order-insensitive pass/fail is order-free; order only selects which duplicate is reported first
        for (id, s) in &self.seqs {
            for &p in &s.pages {
                if seen[p] {
                    bail!("page {p} owned by seq {id} but also free/duplicated");
                }
                seen[p] = true;
            }
        }
        if !seen.iter().all(|&x| x) {
            bail!("leaked pages: {}", seen.iter().filter(|&&x| !x).count());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn admit_extend_release_roundtrip() {
        let mut pool = KvPool::new(KvPoolConfig {
            page_tokens: 16,
            n_pages: 8,
        });
        pool.admit(1, 20).unwrap(); // 2 pages
        assert_eq!(pool.pages_used(), 2);
        pool.extend(1, 12).unwrap(); // 32 tokens -> still 2 pages
        assert_eq!(pool.pages_used(), 2);
        pool.extend(1, 1).unwrap(); // 33 tokens -> 3 pages
        assert_eq!(pool.pages_used(), 3);
        assert_eq!(pool.release(1).unwrap(), 3);
        assert_eq!(pool.pages_free(), 8);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn admission_bounded_by_pool() {
        let mut pool = KvPool::new(KvPoolConfig::for_sequences(2, 64, 16));
        assert_eq!(pool.pages_free(), 8);
        pool.admit(1, 64).unwrap();
        pool.admit(2, 64).unwrap();
        assert!(!pool.can_admit(1));
        assert!(pool.admit(3, 1).is_err());
        pool.release(1).unwrap();
        assert!(pool.can_admit(64));
    }

    #[test]
    fn double_admit_rejected() {
        let mut pool = KvPool::new(KvPoolConfig {
            page_tokens: 4,
            n_pages: 4,
        });
        pool.admit(7, 4).unwrap();
        assert!(pool.admit(7, 4).is_err());
    }

    #[test]
    fn fragmentation_measured() {
        let mut pool = KvPool::new(KvPoolConfig {
            page_tokens: 16,
            n_pages: 4,
        });
        pool.admit(1, 1).unwrap(); // 1 token in a 16-token page
        assert!(pool.fragmentation() > 0.9);
        pool.extend(1, 15).unwrap();
        assert!(pool.fragmentation() < 1e-9);
    }

    #[test]
    fn prop_no_leaks_or_double_owns() {
        check("kv pool invariants", 128, |g: &mut Gen| {
            let page_tokens = g.usize(1, 32);
            let n_pages = g.usize(4, 64);
            let mut pool = KvPool::new(KvPoolConfig {
                page_tokens,
                n_pages,
            });
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(1, 200) {
                match g.usize(0, 2) {
                    0 => {
                        let toks = g.usize(1, 100);
                        if pool.can_admit(toks) {
                            pool.admit(next_id, toks).unwrap();
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len() - 1);
                            // Extends may fail when the pool is full — fine.
                            let _ = pool.extend(live[i], g.usize(1, 40));
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len() - 1);
                            let id = live.swap_remove(i);
                            pool.release(id).unwrap();
                        }
                    }
                }
                pool.check_invariants().unwrap();
            }
        });
    }
}
