//! Paged KV-cache block manager.
//!
//! The engine's caches are dense per-request blocks, but admission control
//! needs a memory model: this allocator tracks a fixed pool of KV pages
//! (PagedAttention-style) and decides how many concurrent sequences fit.
//! Sequences allocate pages lazily as they grow; freeing returns pages to
//! a free list. Fragmentation statistics feed the metrics endpoint.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Pool configuration.
#[derive(Debug, Clone, Copy)]
pub struct KvPoolConfig {
    /// Tokens per page.
    pub page_tokens: usize,
    /// Total pages in the pool.
    pub n_pages: usize,
}

impl KvPoolConfig {
    /// Pool sized for `n_seqs` full-length sequences of `max_seq` tokens.
    pub fn for_sequences(n_seqs: usize, max_seq: usize, page_tokens: usize) -> Self {
        let pages_per_seq = max_seq.div_ceil(page_tokens);
        KvPoolConfig {
            page_tokens,
            n_pages: n_seqs * pages_per_seq,
        }
    }
}

/// One sequence's page table.
#[derive(Debug, Clone)]
struct SeqAlloc {
    pages: Vec<usize>,
    tokens: usize,
}

/// The block allocator.
#[derive(Debug)]
pub struct KvPool {
    cfg: KvPoolConfig,
    free: Vec<usize>,
    seqs: HashMap<u64, SeqAlloc>,
    /// High-water mark of pages in use.
    peak_used: usize,
    allocs: u64,
    frees: u64,
}

impl KvPool {
    pub fn new(cfg: KvPoolConfig) -> Self {
        assert!(cfg.page_tokens > 0 && cfg.n_pages > 0);
        KvPool {
            cfg,
            free: (0..cfg.n_pages).rev().collect(),
            seqs: HashMap::new(),
            peak_used: 0,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub fn pages_used(&self) -> usize {
        self.cfg.n_pages - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    pub fn n_sequences(&self) -> usize {
        self.seqs.len()
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.page_tokens).max(1)
    }

    /// Can a sequence of `tokens` be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    /// Admit a new sequence with an initial `tokens` length (prompt).
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let need = self.pages_for(tokens);
        if need > self.free.len() {
            bail!(
                "kv pool exhausted: need {need} pages, {} free",
                self.free.len()
            );
        }
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.allocs += need as u64;
        self.seqs.insert(seq_id, SeqAlloc { pages, tokens });
        self.peak_used = self.peak_used.max(self.pages_used());
        Ok(())
    }

    /// Grow a sequence by `new_tokens` (decode steps). Allocates pages on
    /// page-boundary crossings only.
    pub fn extend(&mut self, seq_id: u64, new_tokens: usize) -> Result<()> {
        let page_tokens = self.cfg.page_tokens;
        let seq = match self.seqs.get_mut(&seq_id) {
            Some(s) => s,
            None => bail!("unknown sequence {seq_id}"),
        };
        let total = seq.tokens + new_tokens;
        let need_total = total.div_ceil(page_tokens).max(1);
        let extra = need_total.saturating_sub(seq.pages.len());
        if extra > self.free.len() {
            bail!("kv pool exhausted on extend: need {extra} more pages");
        }
        for _ in 0..extra {
            seq.pages.push(self.free.pop().unwrap());
        }
        self.allocs += extra as u64;
        seq.tokens = total;
        self.peak_used = self.peak_used.max(self.pages_used());
        Ok(())
    }

    /// Release a sequence's pages.
    pub fn release(&mut self, seq_id: u64) -> Result<usize> {
        let seq = match self.seqs.remove(&seq_id) {
            Some(s) => s,
            None => bail!("unknown sequence {seq_id}"),
        };
        let n = seq.pages.len();
        self.frees += n as u64;
        self.free.extend(seq.pages);
        Ok(n)
    }

    /// Internal fragmentation: fraction of allocated page capacity that is
    /// not holding tokens.
    pub fn fragmentation(&self) -> f64 {
        let mut cap = 0usize;
        let mut used = 0usize;
        // lint: order-insensitive commutative sums; visitation order cannot change the totals
        for s in self.seqs.values() {
            cap += s.pages.len() * self.cfg.page_tokens;
            used += s.tokens;
        }
        if cap == 0 {
            0.0
        } else {
            1.0 - used as f64 / cap as f64
        }
    }

    /// Invariant check used by property tests: every page is either free or
    /// owned by exactly one sequence.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.cfg.n_pages];
        for &p in &self.free {
            if seen[p] {
                bail!("page {p} double-listed in free list");
            }
            seen[p] = true;
        }
        // lint: order-insensitive pass/fail is order-free; order only selects which duplicate is reported first
        for (id, s) in &self.seqs {
            for &p in &s.pages {
                if seen[p] {
                    bail!("page {p} owned by seq {id} but also free/duplicated");
                }
                seen[p] = true;
            }
        }
        if !seen.iter().all(|&x| x) {
            bail!("leaked pages: {}", seen.iter().filter(|&&x| !x).count());
        }
        Ok(())
    }
}

/// Live-substrate mirror of the DES prefix-cache residency signal
/// (`sim::prefix::PrefixCache`): which worker holds how many reusable KV
/// tokens for each conversation. The router consults it when filling
/// `ServerView::prefix_hit_tokens` / `prefix_pressure` so the same
/// cache-affinity scheduler (`CsUcbAffinity`) runs unchanged against live
/// telemetry. Unlike the DES cache this is bookkeeping, not storage: the
/// workers own the actual KV pages (via [`KvPool`]); the registry only
/// records what `route()` placed where so follow-up turns can chase their
/// prefix. All operations are point lookups on the session id — no map
/// iteration anywhere (determinism lint D2 stays trivially satisfied).
#[derive(Debug, Clone)]
pub struct PrefixRegistry {
    /// session id -> (worker index, resident prefix tokens).
    resident: HashMap<u64, (usize, u64)>,
    /// Per-worker resident-token totals — numerator of the pressure proxy.
    per_worker: Vec<u64>,
    /// Per-worker KV capacity in tokens — denominator of the pressure
    /// proxy (mirrors `PrefixCache::capacity` on the DES side).
    capacity_tokens: u64,
}

impl PrefixRegistry {
    pub fn new(n_workers: usize, capacity_tokens: u64) -> Self {
        PrefixRegistry {
            resident: HashMap::new(),
            per_worker: vec![0; n_workers],
            capacity_tokens: capacity_tokens.max(1),
        }
    }

    /// Record that `worker` now holds `tokens` KV tokens for the session
    /// (the conversation context after the turn it just served). A session
    /// lives on exactly one worker — re-recording elsewhere moves the
    /// residency, matching the DES semantics where the turn's full context
    /// is (re)built wherever the turn actually ran.
    pub fn record(&mut self, session_id: u64, worker: usize, tokens: u64) {
        if worker >= self.per_worker.len() {
            return;
        }
        if let Some((old_w, old_t)) = self.resident.insert(session_id, (worker, tokens)) {
            self.per_worker[old_w] = self.per_worker[old_w].saturating_sub(old_t);
        }
        self.per_worker[worker] = self.per_worker[worker].saturating_add(tokens);
    }

    /// Reusable KV tokens `worker` holds for the session (0 if the
    /// session is resident elsewhere or unknown).
    pub fn resident_on(&self, session_id: u64, worker: usize) -> u64 {
        match self.resident.get(&session_id) {
            Some(&(w, tokens)) if w == worker => tokens,
            _ => 0,
        }
    }

    /// Drop the session's residency (conversation ended, or the worker
    /// reported it evicted the pages). Returns the tokens released.
    pub fn release(&mut self, session_id: u64) -> u64 {
        match self.resident.remove(&session_id) {
            Some((w, tokens)) => {
                self.per_worker[w] = self.per_worker[w].saturating_sub(tokens);
                tokens
            }
            None => 0,
        }
    }

    /// Prefix-cache occupancy proxy in [0, 1] for `worker` — the
    /// eviction-risk signal `CsUcbAffinity` uses to decay its stickiness
    /// bonus. Saturates at 1.0: the registry does not evict (the workers
    /// do), so brief overshoot past nominal capacity reads as "full".
    pub fn pressure(&self, worker: usize) -> f64 {
        match self.per_worker.get(worker) {
            Some(&t) => (t as f64 / self.capacity_tokens as f64).min(1.0),
            None => 0.0,
        }
    }

    /// Total KV tokens currently tracked for `worker`.
    pub fn worker_tokens(&self, worker: usize) -> u64 {
        self.per_worker.get(worker).copied().unwrap_or(0)
    }

    /// Sessions currently tracked.
    pub fn sessions(&self) -> usize {
        self.resident.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn registry_records_moves_and_releases() {
        let mut reg = PrefixRegistry::new(3, 1000);
        reg.record(7, 1, 300);
        assert_eq!(reg.resident_on(7, 1), 300);
        assert_eq!(reg.resident_on(7, 0), 0, "resident elsewhere reads 0");
        assert_eq!(reg.worker_tokens(1), 300);
        assert!((reg.pressure(1) - 0.3).abs() < 1e-12);
        // Turn 2 grows the context in place.
        reg.record(7, 1, 450);
        assert_eq!(reg.resident_on(7, 1), 450);
        assert_eq!(reg.worker_tokens(1), 450);
        // Turn 3 lands on a different worker: residency moves, totals follow.
        reg.record(7, 2, 600);
        assert_eq!(reg.resident_on(7, 1), 0);
        assert_eq!(reg.resident_on(7, 2), 600);
        assert_eq!(reg.worker_tokens(1), 0);
        assert_eq!(reg.worker_tokens(2), 600);
        assert_eq!(reg.release(7), 600);
        assert_eq!(reg.sessions(), 0);
        assert_eq!(reg.worker_tokens(2), 0);
        assert_eq!(reg.release(7), 0, "double release is a no-op");
    }

    #[test]
    fn registry_pressure_saturates_and_ignores_bad_indices() {
        let mut reg = PrefixRegistry::new(2, 100);
        reg.record(1, 0, 250);
        assert_eq!(reg.pressure(0), 1.0, "overshoot saturates at full");
        assert_eq!(reg.pressure(9), 0.0, "unknown worker reads empty");
        reg.record(2, 9, 50); // out-of-range worker: dropped, not panicked
        assert_eq!(reg.sessions(), 1);
        assert_eq!(reg.resident_on(2, 9), 0);
    }

    #[test]
    fn registry_per_worker_totals_stay_consistent() {
        // Property: after any record/release sequence, per-worker totals
        // equal the sum of resident sessions on that worker.
        check("prefix registry totals", 200, |g: &mut Gen| {
            let mut reg = PrefixRegistry::new(4, 10_000);
            for _ in 0..g.usize(1, 40) {
                let sid = g.u64(0, 7);
                if g.bool() {
                    reg.record(sid, g.usize(0, 3), g.u64(0, 500));
                } else {
                    reg.release(sid);
                }
            }
            for w in 0..4 {
                let sum: u64 = (0..8u64).map(|sid| reg.resident_on(sid, w)).sum();
                assert_eq!(sum, reg.worker_tokens(w), "worker {w} total drifted");
            }
        });
    }

    #[test]
    fn admit_extend_release_roundtrip() {
        let mut pool = KvPool::new(KvPoolConfig {
            page_tokens: 16,
            n_pages: 8,
        });
        pool.admit(1, 20).unwrap(); // 2 pages
        assert_eq!(pool.pages_used(), 2);
        pool.extend(1, 12).unwrap(); // 32 tokens -> still 2 pages
        assert_eq!(pool.pages_used(), 2);
        pool.extend(1, 1).unwrap(); // 33 tokens -> 3 pages
        assert_eq!(pool.pages_used(), 3);
        assert_eq!(pool.release(1).unwrap(), 3);
        assert_eq!(pool.pages_free(), 8);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn admission_bounded_by_pool() {
        let mut pool = KvPool::new(KvPoolConfig::for_sequences(2, 64, 16));
        assert_eq!(pool.pages_free(), 8);
        pool.admit(1, 64).unwrap();
        pool.admit(2, 64).unwrap();
        assert!(!pool.can_admit(1));
        assert!(pool.admit(3, 1).is_err());
        pool.release(1).unwrap();
        assert!(pool.can_admit(64));
    }

    #[test]
    fn double_admit_rejected() {
        let mut pool = KvPool::new(KvPoolConfig {
            page_tokens: 4,
            n_pages: 4,
        });
        pool.admit(7, 4).unwrap();
        assert!(pool.admit(7, 4).is_err());
    }

    #[test]
    fn fragmentation_measured() {
        let mut pool = KvPool::new(KvPoolConfig {
            page_tokens: 16,
            n_pages: 4,
        });
        pool.admit(1, 1).unwrap(); // 1 token in a 16-token page
        assert!(pool.fragmentation() > 0.9);
        pool.extend(1, 15).unwrap();
        assert!(pool.fragmentation() < 1e-9);
    }

    #[test]
    fn prop_no_leaks_or_double_owns() {
        check("kv pool invariants", 128, |g: &mut Gen| {
            let page_tokens = g.usize(1, 32);
            let n_pages = g.usize(4, 64);
            let mut pool = KvPool::new(KvPoolConfig {
                page_tokens,
                n_pages,
            });
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(1, 200) {
                match g.usize(0, 2) {
                    0 => {
                        let toks = g.usize(1, 100);
                        if pool.can_admit(toks) {
                            pool.admit(next_id, toks).unwrap();
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len() - 1);
                            // Extends may fail when the pool is full — fine.
                            let _ = pool.extend(live[i], g.usize(1, 40));
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = g.usize(0, live.len() - 1);
                            let id = live.swap_remove(i);
                            pool.release(id).unwrap();
                        }
                    }
                }
                pool.check_invariants().unwrap();
            }
        });
    }
}
