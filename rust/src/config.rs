//! Configuration: a TOML-subset parser (no serde offline) plus typed
//! experiment/serving configs assembled from key-value sections.
//!
//! Supported syntax — enough for real deployment files, nothing exotic:
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! n = 42
//! x = 3.5
//! flag = true
//! list = [1, 2, 4]
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Sectioned key-value config.
#[derive(Debug, Default, Clone)]
pub struct Config {
    /// section -> key -> value; top-level keys live under "".
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section {line:?}", ln + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {v:?}", ln + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let items: Result<Vec<Value>> = inner
            .split(',')
            .map(|x| x.trim())
            .filter(|x| !x.is_empty())
            .map(parse_value)
            .collect();
        return Ok(Value::List(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value {s:?}")
}

/// Typed experiment config assembled from a Config (or defaults).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub requests: usize,
    pub arrival_rate: f64,
    pub seed: u64,
    pub edge_model: String,
    pub deadline_lo: f64,
    pub deadline_hi: f64,
    pub fluctuating: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            requests: 10_000,
            arrival_rate: 15.0,
            seed: 42,
            edge_model: "llama2-7b".into(),
            deadline_lo: 2.0,
            deadline_hi: 6.0,
            fluctuating: false,
        }
    }
}

impl ExperimentConfig {
    pub fn from_config(cfg: &Config) -> Self {
        let d = ExperimentConfig::default();
        ExperimentConfig {
            requests: cfg.i64_or("experiment", "requests", d.requests as i64) as usize,
            arrival_rate: cfg.f64_or("experiment", "arrival_rate", d.arrival_rate),
            seed: cfg.i64_or("experiment", "seed", d.seed as i64) as u64,
            edge_model: cfg.str_or("experiment", "edge_model", &d.edge_model),
            deadline_lo: cfg.f64_or("experiment", "deadline_lo", d.deadline_lo),
            deadline_hi: cfg.f64_or("experiment", "deadline_hi", d.deadline_hi),
            fluctuating: cfg.bool_or("experiment", "fluctuating", d.fluctuating),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment definition
[experiment]
requests = 500
arrival_rate = 12.5
edge_model = "yi-6b"
fluctuating = true
seeds = [1, 2, 3]
note = "has # inside"
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.i64_or("experiment", "requests", 0), 500);
        assert_eq!(cfg.f64_or("experiment", "arrival_rate", 0.0), 12.5);
        assert_eq!(cfg.str_or("experiment", "edge_model", ""), "yi-6b");
        assert!(cfg.bool_or("experiment", "fluctuating", false));
        match cfg.get("experiment", "seeds") {
            Some(Value::List(xs)) => assert_eq!(xs.len(), 3),
            other => panic!("{other:?}"),
        }
        assert_eq!(
            cfg.str_or("experiment", "note", ""),
            "has # inside"
        );
    }

    #[test]
    fn typed_config_from_parsed() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let e = ExperimentConfig::from_config(&cfg);
        assert_eq!(e.requests, 500);
        assert_eq!(e.edge_model, "yi-6b");
        assert!(e.fluctuating);
        // Unset keys fall back to defaults.
        assert_eq!(e.deadline_lo, 2.0);
    }

    #[test]
    fn defaults_on_empty() {
        let cfg = Config::parse("").unwrap();
        let e = ExperimentConfig::from_config(&cfg);
        assert_eq!(e.requests, 10_000);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = @@").is_err());
    }

    #[test]
    fn int_vs_float() {
        let cfg = Config::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(cfg.get("", "a"), Some(&Value::Int(3)));
        assert_eq!(cfg.get("", "b"), Some(&Value::Float(3.5)));
        assert_eq!(cfg.f64_or("", "a", 0.0), 3.0);
    }
}
