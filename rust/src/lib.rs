//! # PerLLM
//!
//! Personalized inference scheduling with edge-cloud collaboration for
//! diverse LLM services — a full reproduction of Yang et al. (cs.DC 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   the CS-UCB constraint-satisfaction bandit scheduler (the paper's
//!   contribution), the published baselines, continuous batching, a KV
//!   cache manager, and the discrete-event edge-cloud cluster substrate
//!   that replays the paper's evaluation at 10 k-request scale.
//! * **Layer 2** — `python/compile/model.py`: a tiny LLaMA-style decoder
//!   (two deployment sizes), AOT-lowered to HLO text at build time.
//! * **Layer 1** — `python/compile/kernels/attention.py`: the Pallas
//!   flash-attention kernel inside that model.
//!
//! Python never runs on the request path: `runtime/` loads the AOT HLO
//! artifacts through the PJRT CPU client (`xla` crate) and serves real
//! tokens from Rust.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.
//!
//! ## Enforced invariants (`pallas-lint`)
//!
//! The reproducibility contracts below are machine-checked by the
//! in-tree static pass in [`analysis`] (`cargo run --bin pallas-lint`,
//! also run over `src/**` by `tests/lint.rs` inside tier-1
//! `cargo test`). Rule ids, long names, and the invariant each guards:
//!
//! * **D1 (`wall-clock`)** — no `Instant::now`, `SystemTime`, or
//!   ambient-entropy RNG outside `coordinator/` and `util/logging.rs`:
//!   the DES must be a pure function of config + seed.
//! * **D2 (`unordered-iter`)** — no `.iter()`/`.keys()`/`.values()`/
//!   `.drain()` (or `for .. in`) on `HashMap`/`HashSet` state in `sim/`,
//!   `scheduler/`, `workload/`, `coordinator/kv.rs` unless the use is
//!   annotated order-insensitive: iteration order must never reach a
//!   result.
//! * **D3 (`raw-seed`)** — `Rng::new` in feature code must derive
//!   side-streams as `seed ^ <X>_STREAM_SALT` (the PR-5/6 idiom), so
//!   adding a consumer never perturbs another stream.
//! * **A1 (`alloc`)** — regions bracketed by `no-alloc` markers (the
//!   `decide`/`view_into`/`advance`/reap hot paths) ban `Vec::new`,
//!   `vec![..]`, `.collect()`, `format!`, `.to_string()`, `Box::new` —
//!   the source-level twin of the `tests/router_alloc.rs` runtime check.
//! * **P1 (`panic`)** — every `unwrap`/`expect`/`panic!`/`unreachable!`
//!   in `sim/` + `scheduler/` carries a justification or was refactored
//!   into a recoverable path.
//! * **N1 (`nan-cmp`)** — `partial_cmp(..).unwrap()` and `min`/`max` on
//!   slack-typed values are flagged; slacks use the PR-5 `-inf`-not-NaN
//!   convention and each remaining site documents why NaN cannot occur.
//!
//! Annotation grammar (line comments, `#[cfg(test)]` code is exempt):
//!
//! * `lint: allow(<rule>[, <rule>..]) <reason>` after `//` — suppress on
//!   the same line (trailing) or the next code line (standalone). Rule
//!   names are the short or long ids above, case-insensitive; the reason
//!   is mandatory.
//! * `lint: order-insensitive <reason>` after `//` — shorthand for
//!   `allow(d2)`.
//! * `lint: no-alloc [reason]` / `lint: end-no-alloc` after `//` —
//!   open/close an A1 region.
//!
//! Malformed annotations are themselves diagnostics (`lint-syntax`) and
//! cannot be suppressed.

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
