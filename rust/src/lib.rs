//! # PerLLM
//!
//! Personalized inference scheduling with edge-cloud collaboration for
//! diverse LLM services — a full reproduction of Yang et al. (cs.DC 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   the CS-UCB constraint-satisfaction bandit scheduler (the paper's
//!   contribution), the published baselines, continuous batching, a KV
//!   cache manager, and the discrete-event edge-cloud cluster substrate
//!   that replays the paper's evaluation at 10 k-request scale.
//! * **Layer 2** — `python/compile/model.py`: a tiny LLaMA-style decoder
//!   (two deployment sizes), AOT-lowered to HLO text at build time.
//! * **Layer 1** — `python/compile/kernels/attention.py`: the Pallas
//!   flash-attention kernel inside that model.
//!
//! Python never runs on the request path: `runtime/` loads the AOT HLO
//! artifacts through the PJRT CPU client (`xla` crate) and serves real
//! tokens from Rust.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
